//! Platform comparison (§4.7): FPGA vs CPU vs GPU vs ASIC on the same
//! trained BNN — latency, power, energy/inference, cost, determinism.
//!
//! CPU numbers are measured live through the PJRT artifacts; FPGA numbers
//! come from the cycle-accurate simulator + power model; the GPU column is
//! the calibrated T4 batch-scaling model; the ASIC column reproduces the
//! paper's own YodaNN estimate arithmetic (all substitutions documented in
//! DESIGN.md).
//!
//! ```sh
//! cargo run --release --example platform_compare
//! ```

use std::sync::Arc;

use bnn_fpga::estimate::{asic, gpu_model::GpuModel, power};
use bnn_fpga::runtime::Engine;
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::bench::Bench;
use bnn_fpga::util::table::{Align, Table};
use bnn_fpga::{artifacts_dir, BNN_DIMS};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let (model, ds, _trained) = bnn_fpga::load_model_or_synth(10);
    let img = &ds.images[0];

    // FPGA design point (§4.5: 64× BRAM).
    let cfg = SimConfig::new(64, MemStyle::Bram);
    let mut acc = Accelerator::new(&model, cfg)?;
    let fpga = acc.run_image(img);
    let fpga_pow = power::estimate(&BNN_DIMS, &cfg);
    let fpga_ms = fpga.latency_ns / 1e6;

    // CPU batch-1 latency, measured through the AOT artifact; falls back to
    // the native blocked kernel when the PJRT runtime/artifacts are absent.
    let bench = Bench::quick();
    let (cpu_label, cpu_ms) = match Engine::load(&dir) {
        Ok(engine) => {
            let engine = Arc::new(engine);
            engine.prepare("bnn_b1")?;
            let input = img.to_u32_words();
            let cpu = bench.run("cpu-b1", || engine.run_u32_to_i32("bnn_b1", &input).unwrap());
            ("CPU (PJRT, measured)", cpu.summary.mean / 1e6)
        }
        Err(e) => {
            println!("(PJRT unavailable — CPU row measured via the native blocked kernel: {e})");
            let block = bnn_fpga::bnn::DEFAULT_BLOCK_ROWS;
            // allocation-free hot path, as the serving loop runs it
            let mut scratch = bnn_fpga::bnn::model::Scratch::default();
            let mut out = vec![0i32; 10];
            let cpu = bench.run("cpu-native-b1", || {
                model.logits_into_blocked(&img.words, &mut scratch, &mut out, block);
                out[0]
            });
            ("CPU (native, measured)", cpu.summary.mean / 1e6)
        }
    };

    // GPU + ASIC models.
    let gpu = GpuModel::default();
    let gpu_b1_ms = gpu.batch_latency_ms(1);

    let mut t = Table::new(&[
        "Platform", "Latency/img (ms)", "Power (W)", "Energy (µJ/inf)", "Cost (USD)",
        "Deterministic",
    ])
    .align(0, Align::Left);
    t.row(vec![
        "FPGA 64x BRAM (sim)".into(),
        format!("{fpga_ms:.4}"),
        format!("{:.3}", fpga_pow.total_w),
        format!("{:.1}", fpga_pow.uj_per_inference(fpga.latency_ns)),
        "~150".into(),
        "yes".into(),
    ]);
    t.row(vec![
        cpu_label.into(),
        format!("{cpu_ms:.4}"),
        "~15 (host share)".into(),
        format!("{:.1}", 15.0 * cpu_ms * 1e3),
        "-".into(),
        "no".into(),
    ]);
    t.row(vec![
        "GPU T4 (model)".into(),
        format!("{gpu_b1_ms:.4}"),
        format!("{:.0}", gpu.tdp_w),
        format!("{:.1}", gpu.tdp_w * gpu_b1_ms * 1e3),
        "400-900".into(),
        "no".into(),
    ]);
    for row in asic::comparison(fpga_ms, fpga_pow.total_w).into_iter().skip(1) {
        t.row(vec![
            row.platform.into(),
            format!("{:.4}", row.latency_ms),
            format!("{:.5}", row.power_w),
            format!("{:.1}", row.uj_per_inference),
            format!("{:.0}-{:.0} (+NRE)", row.unit_cost_usd.0, row.unit_cost_usd.1),
            "yes".into(),
        ]);
    }
    t.print();

    println!(
        "\npaper §4.7.3 headline: FPGA {:.4} ms/img at {:.3} W — faster than CPU batch-1 \
         ({:.2} ms) and only behind GPU at large batch; paper's figures: 0.0178 ms @ 0.617 W.",
        fpga_ms, fpga_pow.total_w, cpu_ms
    );
    Ok(())
}
