//! Parallelism × memory-style design-space sweep (the §4.2 study) through
//! the library API: cycle-accurate latency, speedup, resources, power,
//! thermal and timing for every synthesizable configuration — including
//! off-grid parallelism values the paper never measured.
//!
//! ```sh
//! cargo run --release --example fpga_sweep [-- --all]
//! ```
//! `--all` extends the sweep to every power of two plus off-grid points.

use bnn_fpga::estimate::{power, resources, timing};
use bnn_fpga::sim::{analytic_steps, Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::table::{fmt_thousands, Align, Table};
use bnn_fpga::BNN_DIMS;

fn main() -> anyhow::Result<()> {
    let all = std::env::args().any(|a| a == "--all");
    // Cycle counts are weight/input-independent, so the synthetic fallback
    // sweeps identically to the trained model.
    let (model, ds, _trained) = bnn_fpga::load_model_or_synth(10);
    let img = &ds.images[0];

    let configs: Vec<SimConfig> = if all {
        let mut v = Vec::new();
        for p in [1usize, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128] {
            for style in [MemStyle::Bram, MemStyle::Lut] {
                if resources::estimate(&BNN_DIMS, p, style).synthesizable {
                    v.push(SimConfig::new(p, style));
                }
            }
        }
        v
    } else {
        SimConfig::table1_rows()
    };

    let base_ns = analytic_steps(&BNN_DIMS, 1, MemStyle::Bram) as f64 * 10.0;
    let mut t = Table::new(&[
        "P", "Mem", "Latency (ns)", "Speedup", "LUT%", "FF%", "BRAM%", "Power(W)",
        "Tj(°C)", "WNS(ns)", "µJ/inf",
    ])
    .align(1, Align::Left);

    for cfg in &configs {
        let mut acc = Accelerator::new(&model, *cfg)?;
        let r = acc.run_image(img);
        let res = resources::best(&BNN_DIMS, cfg.parallelism, cfg.mem_style);
        let pow = power::estimate(&BNN_DIMS, cfg);
        let tim = timing::best(cfg.parallelism, cfg.mem_style);
        t.row(vec![
            cfg.parallelism.to_string(),
            cfg.mem_style.name().into(),
            fmt_thousands(r.latency_ns as u64),
            format!("{:.2}", base_ns / r.latency_ns),
            format!("{:.2}", res.lut_pct()),
            format!("{:.2}", res.ff_pct()),
            format!("{:.2}", res.bram_pct()),
            format!("{:.3}", pow.total_w),
            format!("{:.1}", pow.junction_c),
            format!("{:.3}", tim.wns_ns),
            format!("{:.1}", pow.uj_per_inference(r.latency_ns)),
        ]);
    }
    t.print();

    // §4.5 trade-off summary: find the paper's preferred design point.
    println!("\n§4.5 design-point selection:");
    let chosen = SimConfig::new(64, MemStyle::Bram);
    let mut acc = Accelerator::new(&model, chosen)?;
    let r = acc.run_image(img);
    let pow = power::estimate(&BNN_DIMS, &chosen);
    println!(
        "  64x BRAM: {} ns latency, {:.2}x speedup, {:.3} W → the paper's pick \
         (maximizes parallelism within the 132-block BRAM budget)",
        fmt_thousands(r.latency_ns as u64),
        base_ns / r.latency_ns,
        pow.total_w
    );
    Ok(())
}
