//! Quickstart: load the trained BNN, classify digits, inspect the
//! accelerator's view of one inference.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use bnn_fpga::data::{synth, Dataset};
use bnn_fpga::sim::{sevenseg, Accelerator, MemStyle, SimConfig};
use bnn_fpga::{artifacts_dir, mem};

fn main() -> anyhow::Result<()> {
    // 1. Load the folded, bit-packed model exported by `make artifacts`.
    let dir = artifacts_dir();
    let model = mem::load_model(&dir.join("weights.json"))?;
    println!(
        "loaded 784-128-64-10 BNN ({} packed weight words, thresholds folded per §3.1 Eq.4)",
        model.layers.iter().map(|l| l.weights.len()).sum::<usize>()
    );

    // 2. Software inference on the paper's §4.1 test subset.
    let ds = Dataset::load_mem_subset(&dir.join("mem"))?;
    let correct = ds
        .images
        .iter()
        .zip(&ds.labels)
        .filter(|(img, &l)| model.predict(&img.words) == l as usize)
        .count();
    println!("software path : {correct}/{} on the 100-image subset", ds.len());

    // 3. The same image through the cycle-accurate FPGA simulator at the
    //    paper's chosen design point (64× parallelism, BRAM weights).
    let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram))?;
    let r = acc.run_image(&ds.images[0]);
    println!(
        "fpga-sim      : digit {} in {} cycles = {} ns (paper Table 1: 17,845 ns)",
        r.digit, r.cycles, r.latency_ns
    );
    println!(
        "               {} XNOR ops, {} BRAM row reads, argmax in {} cycles",
        r.activity.xnor_ops, r.activity.bram_row_reads, r.breakdown.argmax
    );

    // 4. Seven-segment display output, as the Nexys A7 board would show it.
    println!("seven-segment display (active-low 0b{:07b}):", r.sevenseg);
    print!("{}", sevenseg::ascii(r.sevenseg));

    // 5. No artifacts? The library also ships a synthetic generator:
    let demo = synth::generate_dataset(1, 42);
    println!("\na synthetic digit (label {}):", demo.labels[0]);
    print!("{}", synth::ascii_digit(&demo.images[0]));
    println!("predicted: {}", model.predict(&demo.images[0].words));
    Ok(())
}
