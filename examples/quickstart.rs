//! Quickstart: load the BNN, classify digits, inspect the accelerator's
//! view of one inference.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Runs out of the box: with `make artifacts` it uses the trained model and
//! the paper's §4.1 subset; without artifacts it falls back to a
//! deterministic synthetic model + dataset (accuracy is chance, but every
//! mechanism — packing, kernels, simulator, display — behaves identically).

use bnn_fpga::coordinator::{BatcherConfig, Engine, InferOptions, Kernel};
use bnn_fpga::data::synth;
use bnn_fpga::sim::{sevenseg, Accelerator, MemStyle, SimConfig};

fn main() -> anyhow::Result<()> {
    // 1. Trained artifacts when present, synthetic stand-ins otherwise.
    let (model, ds, trained) = bnn_fpga::load_model_or_synth(100);
    println!(
        "loaded 784-128-64-10 BNN ({} packed weight words{})",
        model.layers.iter().map(|l| l.weights.len()).sum::<usize>(),
        if trained {
            ", thresholds folded per §3.1 Eq.4"
        } else {
            " — UNTRAINED synthetic fallback; run `make artifacts` for the real model"
        }
    );

    // 2. Software inference, scalar vs blocked kernel (bit-identical).
    let correct = ds
        .images
        .iter()
        .zip(&ds.labels)
        .filter(|(img, &l)| model.predict(&img.words) == l as usize)
        .count();
    println!("software path : {correct}/{} on the test subset", ds.len());
    let x = &ds.images[0];
    assert_eq!(
        model.logits_blocked(&x.words, bnn_fpga::bnn::DEFAULT_BLOCK_ROWS),
        model.logits(&x.words)
    );
    println!("blocked kernel: bit-identical to the scalar path (block_rows = {})",
        bnn_fpga::bnn::DEFAULT_BLOCK_ROWS);
    // ...and so is the weight-stationary batch-tiled kernel, over a batch.
    let batch = 5.min(ds.len());
    let inputs = ds.batch_words(0, batch);
    assert_eq!(
        model.logits_batch_tiled(
            &inputs,
            batch,
            bnn_fpga::bnn::DEFAULT_BLOCK_ROWS,
            bnn_fpga::bnn::DEFAULT_TILE_IMGS
        ),
        model.logits_batch(&inputs, batch)
    );
    println!(
        "tiled kernel  : bit-identical over a {batch}-image batch (tile_imgs = {})",
        bnn_fpga::bnn::DEFAULT_TILE_IMGS
    );
    // ...and the runtime-dispatched SIMD tier (AVX2/NEON when the host has
    // them, tiled fallback otherwise) — same logits on every path.
    assert_eq!(
        model.logits_batch_simd(
            &inputs,
            batch,
            bnn_fpga::bnn::DEFAULT_BLOCK_ROWS,
            bnn_fpga::bnn::DEFAULT_TILE_IMGS
        ),
        model.logits_batch(&inputs, batch)
    );
    println!(
        "simd kernel   : bit-identical at the '{}' vector level (--kernel simd)",
        bnn_fpga::bnn::simd_level().name()
    );
    // ...and the fused threshold-pack tier: weights re-laid into 64-row
    // panels once up front, then popcount → threshold-compare → activation
    // bit-pack happen in registers — hidden-layer sums never touch memory.
    let prepared = bnn_fpga::bnn::PreparedModel::new(&model)?;
    assert_eq!(
        prepared.logits_batch(&inputs, batch, bnn_fpga::bnn::DEFAULT_TILE_IMGS),
        model.logits_batch(&inputs, batch)
    );
    println!("fused kernel  : bit-identical on engine-prepared panel weights (--kernel fused)");

    // 3. Serving: Engine::builder() is the one construction path for every
    //    topology.  submit() returns a Ticket (no channel internals);
    //    per-request InferOptions select top-k / logits-on-off.
    let engine = Engine::builder()
        .native(&model)
        .kernel(Kernel::default())
        .workers(2)
        .batcher(BatcherConfig::default())
        .queue_cap(10_000)
        .build()?;
    let ticket = engine.submit(ds.images[0].clone())?;
    println!("serving       : submitted request {} through the engine", ticket.id());
    let resp = ticket.wait()?;
    assert_eq!(resp.digit as usize, model.predict(&ds.images[0].words));
    println!(
        "               ticket resolved: digit {} in {} µs (batch of {})",
        resp.digit,
        resp.latency_ns / 1000,
        resp.batch_size
    );
    let top3 = engine.infer_with(
        ds.images[1].clone(),
        InferOptions::digits_only().with_top_k(3),
    )?;
    println!(
        "               top-3 for the next digit: {:?} (no logits copied: {})",
        top3.top_k,
        top3.logits.is_empty()
    );
    println!("               {}", engine.summary_line());
    engine.shutdown();

    // 4. The same image through the cycle-accurate FPGA simulator at the
    //    paper's chosen design point (64× parallelism, BRAM weights).
    let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram))?;
    let r = acc.run_image(&ds.images[0]);
    println!(
        "fpga-sim      : digit {} in {} cycles = {} ns (paper Table 1: 17,845 ns)",
        r.digit, r.cycles, r.latency_ns
    );
    println!(
        "               {} XNOR ops, {} BRAM row reads, argmax in {} cycles",
        r.activity.xnor_ops, r.activity.bram_row_reads, r.breakdown.argmax
    );

    // 5. Seven-segment display output, as the Nexys A7 board would show it.
    println!("seven-segment display (active-low 0b{:07b}):", r.sevenseg);
    print!("{}", sevenseg::ascii(r.sevenseg));

    // 6. The synthetic generator also renders demo digits directly:
    let demo = synth::generate_dataset(1, 42);
    println!("\na synthetic digit (label {}):", demo.labels[0]);
    print!("{}", synth::ascii_digit(&demo.images[0]));
    println!("predicted: {}", model.predict(&demo.images[0].words));
    Ok(())
}
