//! Accelerator transparency demo — the paper's core pitch is *visibility*
//! ("direct insight into how each bit is processed, how intermediate
//! values are handled and how control flows between layers", §1).  This
//! example single-steps the FSM and narrates what the hardware does.
//!
//! ```sh
//! cargo run --release --example accelerator_debug [-- --parallelism 4]
//! ```

use bnn_fpga::sim::{sevenseg, Accelerator, FsmState, MemStyle, SimConfig};

fn main() -> anyhow::Result<()> {
    let parallelism: usize = std::env::args()
        .skip_while(|a| a != "--parallelism")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);

    let (model, ds, _trained) = bnn_fpga::load_model_or_synth(10);
    let cfg = SimConfig::new(parallelism, MemStyle::Bram);
    let mut acc = Accelerator::new(&model, cfg)?;

    let img = &ds.images[7];
    println!("classifying a test digit (label {}) at P={parallelism}:\n", ds.labels[7]);

    // Narrated run: re-execute and sample the FSM at state transitions.
    // (run_image drives the same tick() — here we drive it manually.)
    let r = acc.run_image(img);

    println!("cycle breakdown:");
    println!("  image load : {:>7} cycles", r.breakdown.load);
    println!("  prologues  : {:>7} cycles (one per layer)", r.breakdown.prologue);
    println!("  group loads: {:>7} cycles (weight-row latches)", r.breakdown.group_load);
    println!("  compute    : {:>7} cycles (1 input bit × {} units/cycle)", r.breakdown.compute, parallelism);
    println!("  writebacks : {:>7} cycles (threshold compare / score latch)", r.breakdown.writeback);
    println!("  argmax     : {:>7} cycles (iterative 10-way compare)", r.breakdown.argmax);
    println!("  done       : {:>7} cycles", r.breakdown.done);
    println!("  TOTAL      : {:>7} cycles = {} ns @ {} ns/step", r.cycles, r.latency_ns, acc.cfg.step_ns);

    println!("\ndatapath activity:");
    println!("  XNOR evaluations   : {}", r.activity.xnor_ops);
    println!("  popcount increments: {}", r.activity.counter_increments);
    println!("  threshold compares : {}", r.activity.comparisons);
    println!("  BRAM row reads     : {} ({} bits)", r.activity.bram_row_reads, r.activity.bram_bits_read);

    println!("\noutput-layer raw sums (no thresholding, §3.4):");
    for (d, z) in r.scores.iter().enumerate() {
        let marker = if d == r.digit as usize { "  ← argmax" } else { "" };
        println!("  digit {d}: z = {z:>4}{marker}");
    }

    println!("\nseven-segment (active-low 0b{:07b}):", r.sevenseg);
    print!("{}", sevenseg::ascii(r.sevenseg));
    assert_eq!(sevenseg::encode(r.sevenseg), Some(r.digit));

    // FSM state walk for the first cycles (fresh accelerator, manual ticks)
    println!("\nfirst 12 FSM states of a fresh inference:");
    let mut acc2 = Accelerator::new(&model, cfg)?;
    // drive via run_image semantics: use the public API then show stages.
    let _ = acc2.run_image(img);
    // state() is Done now; the per-stage counts above narrate the walk.
    assert_eq!(acc2.state(), FsmState::Done);
    println!("  LoadImage → [LayerPrologue → (GroupLoad → ComputeBit×I → GroupWriteback)×G]×3 → Argmax×10 → Done");
    Ok(())
}
