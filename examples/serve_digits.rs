//! END-TO-END driver (DESIGN.md §Validation): the full three-layer stack
//! serving a real workload, including the 1-vs-N worker-pool comparison.
//!
//! * build path (optional, ran beforehand by `make artifacts`): JAX STE
//!   training → threshold folding → `.mem`/JSON export → Pallas-kernel AOT
//!   → HLO text; without it a deterministic synthetic model/dataset is
//!   substituted (mechanics and throughput identical, accuracy ≈ chance);
//! * request path (this binary, no Python): classification requests are
//!   batched and served by
//!   - a single-worker scalar-kernel coordinator (the baseline),
//!   - the sharded multi-worker pool with the per-image blocked kernel,
//!   - the same pool on the weight-stationary batch-tiled kernel,
//!   - the same pool on the runtime-dispatched SIMD tier (AVX2/NEON),
//!   - the same pool on the fused threshold-pack tier (engine-prepared
//!     panel weights, sums never materialized),
//!   - the PJRT backend (when the runtime + artifacts are available),
//!   - a pool of cycle-accurate FPGA simulator replicas,
//!   reporting accuracy, latency percentiles and throughput per backend.
//!
//! ```sh
//! cargo run --release --example serve_digits -- --requests 2000 --workers 4 \
//!     --block-rows 16 --tile-imgs 8
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use bnn_fpga::cli::args::Args;
use bnn_fpga::coordinator::{BatcherConfig, Engine, InferService, Kernel, PjrtBackend};
use bnn_fpga::data::{synth, Dataset};
use bnn_fpga::runtime::Engine as PjrtRuntime;
use bnn_fpga::sim::{MemStyle, SimConfig};
use bnn_fpga::util::stats::LatencyHistogram;
use bnn_fpga::util::table::{Align, Table};
use bnn_fpga::{artifacts_dir, bnn};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let n_requests = args.usize_or("requests", 1000)?;
    let workers = args.usize_or("workers", 4)?;
    let block_rows = args.usize_or("block-rows", bnn::DEFAULT_BLOCK_ROWS)?;
    let tile_imgs = args.usize_or("tile-imgs", bnn::DEFAULT_TILE_IMGS)?;
    anyhow::ensure!(workers >= 1, "--workers must be ≥ 1");
    anyhow::ensure!(block_rows >= 1, "--block-rows must be ≥ 1");
    anyhow::ensure!(tile_imgs >= 1, "--tile-imgs must be ≥ 1");

    let dir = artifacts_dir();
    let (model, subset, trained) = bnn_fpga::load_model_or_synth(100);
    let test = match Dataset::load_idx_test(&dir.join("data")) {
        Ok(t) => t,
        Err(_) => {
            if trained {
                subset
            } else {
                synth::generate_dataset(200, 7)
            }
        }
    };
    println!(
        "model 784-128-64-10{}, test set {} images, {n_requests} requests/backend, \
         {workers} workers, block_rows {block_rows}, tile_imgs {tile_imgs}",
        if trained { "" } else { " (untrained synthetic fallback)" },
        test.len()
    );

    let mut table = Table::new(&[
        "Backend", "Workers", "Requests", "Accuracy", "Throughput (req/s)", "p50 (µs)",
        "p99 (µs)", "Mean batch",
    ])
    .align(0, Align::Left);

    // Serve `n` requests through `service`; returns (correct, wall_seconds).
    let run_load = |n: usize, service: &dyn InferService| -> anyhow::Result<(usize, f64)> {
        let images: Vec<_> = (0..n).map(|i| test.images[i % test.len()].clone()).collect();
        let labels: Vec<_> = (0..n).map(|i| test.labels[i % test.len()]).collect();
        let t0 = Instant::now();
        let responses = service.infer_many(images)?;
        let wall = t0.elapsed().as_secs_f64();
        let correct = responses
            .iter()
            .zip(&labels)
            .filter(|(r, &l)| r.digit == u16::from(l))
            .count();
        Ok((correct, wall))
    };
    let mut add_row = |name: &str,
                       svc_workers: usize,
                       n: usize,
                       correct: usize,
                       wall: f64,
                       lat: LatencyHistogram,
                       mean_batch: f64| {
        table.row(vec![
            name.into(),
            svc_workers.to_string(),
            n.to_string(),
            format!("{:.1}%", correct as f64 / n as f64 * 100.0),
            format!("{:.0}", n as f64 / wall),
            (lat.percentile_ns(50.0) / 1000).to_string(),
            (lat.percentile_ns(99.0) / 1000).to_string(),
            format!("{mean_batch:.1}"),
        ]);
    };

    let batcher = BatcherConfig {
        max_batch: 64,
        max_wait: Duration::from_micros(100),
    };

    // 1. Baseline: one worker, one shared queue, scalar kernel — every
    //    topology below comes from the same Engine::builder() call chain.
    {
        let engine = Engine::builder()
            .native(&model)
            .kernel(Kernel::Scalar)
            .workers(1)
            .batcher(batcher)
            .build()?;
        let (correct, wall) = run_load(n_requests, &engine)?;
        add_row(
            "native scalar",
            1,
            n_requests,
            correct,
            wall,
            engine.latency_snapshot(),
            engine.metrics().mean_batch_size(),
        );
        engine.shutdown();
    }

    // 2. The sharded worker pool with the per-image blocked kernel.
    {
        let engine = Engine::builder()
            .native(&model)
            .kernel(Kernel::Blocked { block_rows })
            .workers(workers)
            .batcher(batcher)
            .build()?;
        let (correct, wall) = run_load(n_requests, &engine)?;
        add_row(
            &format!("native blocked x{workers}"),
            workers,
            n_requests,
            correct,
            wall,
            engine.latency_snapshot(),
            engine.metrics().mean_batch_size(),
        );
        engine.shutdown();
    }

    // 3. The weight-stationary batch-tiled kernel — the serving hot path:
    //    each weight-row block is loaded once per tile of images.
    let per_worker_report = {
        let engine = Engine::builder()
            .native(&model)
            .kernel(Kernel::Tiled {
                block_rows,
                tile_imgs,
            })
            .workers(workers)
            .batcher(batcher)
            .build()?;
        let (correct, wall) = run_load(n_requests, &engine)?;
        add_row(
            &format!("native tiled x{workers}"),
            workers,
            n_requests,
            correct,
            wall,
            engine.latency_snapshot(),
            engine.metrics().mean_batch_size(),
        );
        let report = engine.per_worker_report().unwrap_or_default();
        engine.shutdown();
        report
    };

    // 4. The runtime-dispatched SIMD tier on the same pool: AVX2/NEON when
    //    the host reports them, the tiled kernel otherwise (or under
    //    BNN_FORCE_SCALAR=1) — logits are bit-identical either way.
    {
        let engine = Engine::builder()
            .native(&model)
            .kernel(Kernel::Simd {
                block_rows,
                tile_imgs,
            })
            .workers(workers)
            .batcher(batcher)
            .build()?;
        let (correct, wall) = run_load(n_requests, &engine)?;
        add_row(
            &format!("native simd[{}] x{workers}", bnn::simd_level().name()),
            workers,
            n_requests,
            correct,
            wall,
            engine.latency_snapshot(),
            engine.metrics().mean_batch_size(),
        );
        engine.shutdown();
    }

    // 5. The fused threshold-pack tier: panel weights prepared once at
    //    engine build, hidden-layer popcount → threshold → bit-pack fused
    //    in registers (no i32 tile arena, no repack pass).
    {
        let engine = Engine::builder()
            .native(&model)
            .kernel(Kernel::Fused { tile_imgs })
            .workers(workers)
            .batcher(batcher)
            .build()?;
        let (correct, wall) = run_load(n_requests, &engine)?;
        add_row(
            &format!("native fused x{workers}"),
            workers,
            n_requests,
            correct,
            wall,
            engine.latency_snapshot(),
            engine.metrics().mean_batch_size(),
        );
        engine.shutdown();
    }

    // 6. PJRT over the AOT artifact ladder, when runtime + artifacts exist
    //    — one shared backend behind a single queue (the PJRT engine
    //    serializes dispatch; PJRT-CPU parallelizes inside).
    match PjrtRuntime::load(&dir) {
        Ok(runtime) => {
            let runtime = Arc::new(runtime);
            println!("PJRT platform: {}", runtime.platform());
            runtime.warm("bnn")?; // compile the artifact ladder up front
            let engine = Engine::builder()
                .shared(Arc::new(PjrtBackend::new(runtime)?))
                .workers(1)
                .batcher(BatcherConfig {
                    max_batch: 128,
                    max_wait: Duration::from_micros(300),
                })
                .build()?;
            let (correct, wall) = run_load(n_requests, &engine)?;
            add_row(
                "pjrt",
                1,
                n_requests,
                correct,
                wall,
                engine.latency_snapshot(),
                engine.metrics().mean_batch_size(),
            );
            engine.shutdown();
        }
        Err(e) => println!("pjrt backend skipped: {e:#}"),
    }

    // 7. A pool of cycle-accurate simulator replicas (deliberately slow —
    //    each request pays the full simulated hardware latency; the builder
    //    clamps max_batch to the hardware's single-image limit).
    {
        let sim_workers = workers.min(2);
        let engine = Engine::builder()
            .fpga_sim(&model, SimConfig::new(64, MemStyle::Bram))
            .workers(sim_workers)
            .batcher(BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(10),
            })
            .build()?;
        let n = n_requests.min(300);
        let (correct, wall) = run_load(n, &engine)?;
        add_row(
            &format!("fpga-sim x{sim_workers}"),
            sim_workers,
            n,
            correct,
            wall,
            engine.latency_snapshot(),
            engine.metrics().mean_batch_size(),
        );
        engine.shutdown();
    }

    table.print();
    println!("\nper-worker metrics (native tiled pool):\n{per_worker_report}");
    println!("all paths produce identical logits — see rust/tests/integration.rs");
    Ok(())
}
