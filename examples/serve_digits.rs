//! END-TO-END driver (DESIGN.md §Validation): the full three-layer stack
//! serving a real workload.
//!
//! * build path (ran beforehand by `make artifacts`): JAX STE training →
//!   threshold folding → `.mem`/JSON export → Pallas-kernel AOT → HLO text;
//! * request path (this binary, no Python): the Rust coordinator batches
//!   incoming classification requests and routes them to all three
//!   backends — native bit-packed, PJRT-compiled AOT artifacts, and the
//!   cycle-accurate FPGA simulator — reporting accuracy, latency
//!   percentiles and throughput per backend.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_digits [-- --requests 2000]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use bnn_fpga::coordinator::{
    BatcherConfig, Coordinator, NativeBackend, PjrtBackend, Router, SimBackend,
};
use bnn_fpga::data::Dataset;
use bnn_fpga::runtime::Engine;
use bnn_fpga::sim::{MemStyle, SimConfig};
use bnn_fpga::util::table::{Align, Table};
use bnn_fpga::{artifacts_dir, mem};

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .skip_while(|a| a != "--requests")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);

    let dir = artifacts_dir();
    let model = mem::load_model(&dir.join("weights.json"))?;
    let test = Dataset::load_idx_test(&dir.join("data"))?;
    println!(
        "model 784-128-64-10, test set {} images, {n_requests} requests/backend",
        test.len()
    );

    // --- assemble the router over all three backends -----------------------
    let engine = Arc::new(Engine::load(&dir)?);
    println!("PJRT platform: {}", engine.platform());
    engine.warm("bnn")?; // compile the artifact ladder up front

    let mut router = Router::new();
    router.register(
        "native",
        Coordinator::start(
            Arc::new(NativeBackend::new(model.clone())),
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(100),
            },
            2,
        )?,
    );
    router.register(
        "pjrt",
        Coordinator::start(
            Arc::new(PjrtBackend::new(engine)?),
            BatcherConfig {
                max_batch: 128,
                max_wait: Duration::from_micros(300),
            },
            1, // the engine serializes dispatch; PJRT-CPU parallelizes inside
        )?,
    );
    router.register(
        "fpga-sim",
        Coordinator::start(
            Arc::new(SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram))?),
            BatcherConfig {
                max_batch: 1, // the hardware is single-image
                max_wait: Duration::from_micros(10),
            },
            1,
        )?,
    );

    // --- drive each backend with the same workload -------------------------
    let mut table = Table::new(&[
        "Backend", "Requests", "Accuracy", "Throughput (req/s)", "p50 (µs)", "p99 (µs)",
        "Mean batch",
    ])
    .align(0, Align::Left);

    for name in ["native", "pjrt", "fpga-sim"] {
        let coord = router.get(name)?;
        let n = if name == "fpga-sim" {
            n_requests.min(300) // cycle-accurate sim is deliberately slow
        } else {
            n_requests
        };
        let images: Vec<_> = (0..n).map(|i| test.images[i % test.len()].clone()).collect();
        let labels: Vec<_> = (0..n).map(|i| test.labels[i % test.len()]).collect();

        let t0 = Instant::now();
        let responses = coord.infer_many(images)?;
        let wall = t0.elapsed().as_secs_f64();

        let correct = responses
            .iter()
            .zip(&labels)
            .filter(|(r, &l)| r.digit == l)
            .count();
        let lat = coord.metrics.latency_snapshot();
        table.row(vec![
            name.into(),
            n.to_string(),
            format!("{:.1}%", correct as f64 / n as f64 * 100.0),
            format!("{:.0}", n as f64 / wall),
            (lat.percentile_ns(50.0) / 1000).to_string(),
            (lat.percentile_ns(99.0) / 1000).to_string(),
            format!("{:.1}", coord.metrics.mean_batch_size()),
        ]);
    }
    table.print();

    println!("\nper-backend metrics:\n{}", router.metrics_report());
    println!("all three backends agree with the trained model — see rust/tests/integration.rs");
    Ok(())
}
