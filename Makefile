# Build-path driver. The Rust request path never needs Python at runtime;
# `make artifacts` runs the L1 pipeline once (requires JAX) and everything
# else picks the artifacts up from ./artifacts (see DESIGN.md).

ARTIFACTS ?= artifacts

.PHONY: artifacts build test bench doc clean

artifacts:
	cd python && python3 -m compile.train --out ../$(ARTIFACTS)
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS)

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench hotpath -- --quick

doc:
	cargo doc --no-deps

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
