# Build-path driver. The Rust request path never needs Python at runtime;
# `make artifacts` runs the L1 pipeline once (requires JAX) and everything
# else picks the artifacts up from ./artifacts (see DESIGN.md).

ARTIFACTS ?= artifacts

.PHONY: artifacts build test bench bench-json bench-serving bench-check chaos doc clean

artifacts:
	cd python && python3 -m compile.train --out ../$(ARTIFACTS)
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS)

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench hotpath -- --quick

# The committed perf trajectory: run the hotpath kernel sweep and refresh
# BENCH_hotpath.json at the repo root (kernel -> ns/image, images/sec,
# simd_level), then assert the fused tier produced rows.  CI runs this on
# every push so kernel regressions diff against a baseline.
bench-json:
	cargo bench --bench hotpath -- --quick
	@test -f BENCH_hotpath.json || { echo "BENCH_hotpath.json missing at repo root"; exit 1; }
	@grep -q '"fused' BENCH_hotpath.json || { echo "BENCH_hotpath.json has no fused rows"; exit 1; }
	@echo "BENCH_hotpath.json refreshed (fused rows present)"

# The committed serving-latency trajectory: drive the async wire server
# with the open-loop load generator across the arrival-rate ladder and
# refresh BENCH_serving.json at the repo root (rate -> p50/p99/p999 +
# achieved images/sec, plus max sustained).  `--quick` keeps the CI run
# short; drop it locally for the full 5-rung ladder.
bench-serving:
	cargo bench --bench serving -- --quick
	@test -f BENCH_serving.json || { echo "BENCH_serving.json missing at repo root"; exit 1; }
	@grep -q '"max_sustained_ips"' BENCH_serving.json || { echo "BENCH_serving.json has no max_sustained_ips"; exit 1; }
	@echo "BENCH_serving.json refreshed"

# Gate the committed trajectories: BENCH_hotpath.json must carry a row for
# every Kernel::registry() tier, and BENCH_serving.json must carry an
# ordered p50 <= p99 <= p999 latency ladder (so neither baseline can go
# stale silently).  The heavy lifting is tests/bench_trajectory.rs.
bench-check:
	@test -f BENCH_hotpath.json || { echo "BENCH_hotpath.json missing at repo root; run 'make bench-json' and commit the result"; exit 1; }
	@test -f BENCH_serving.json || { echo "BENCH_serving.json missing at repo root; run 'make bench-serving' and commit the result"; exit 1; }
	cargo test --release --test bench_trajectory -q
	@echo "BENCH_hotpath.json covers every registry kernel tier; BENCH_serving.json trajectory is sane"

# Fault-tolerance soak (DESIGN.md §Fault tolerance): the seeded chaos
# acceptance test (panic/latency/error faults through the async server,
# every request typed-resolved, ledger balanced, restarts observed), then
# a self-contained CLI soak with fault injection on 5% of backend calls.
chaos:
	cargo test --release --test chaos_serve -q
	cargo run --release -- loadgen --chaos-rate 5 --rate 4000 --duration-ms 2000 --connections 4

doc:
	cargo doc --no-deps

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
