# Build-path driver. The Rust request path never needs Python at runtime;
# `make artifacts` runs the L1 pipeline once (requires JAX) and everything
# else picks the artifacts up from ./artifacts (see DESIGN.md).

ARTIFACTS ?= artifacts

.PHONY: artifacts build test bench bench-json bench-check doc clean

artifacts:
	cd python && python3 -m compile.train --out ../$(ARTIFACTS)
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS)

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench hotpath -- --quick

# The committed perf trajectory: run the hotpath kernel sweep and refresh
# BENCH_hotpath.json at the repo root (kernel -> ns/image, images/sec,
# simd_level), then assert the fused tier produced rows.  CI runs this on
# every push so kernel regressions diff against a baseline.
bench-json:
	cargo bench --bench hotpath -- --quick
	@test -f BENCH_hotpath.json || { echo "BENCH_hotpath.json missing at repo root"; exit 1; }
	@grep -q '"fused' BENCH_hotpath.json || { echo "BENCH_hotpath.json has no fused rows"; exit 1; }
	@echo "BENCH_hotpath.json refreshed (fused rows present)"

# Gate the committed trajectory: BENCH_hotpath.json must exist at the
# repo root and carry a row for every Kernel::registry() tier (so a new
# tier cannot land without refreshing the baseline).  The heavy lifting
# is tests/bench_trajectory.rs.
bench-check:
	@test -f BENCH_hotpath.json || { echo "BENCH_hotpath.json missing at repo root; run 'make bench-json' and commit the result"; exit 1; }
	cargo test --release --test bench_trajectory -q
	@echo "BENCH_hotpath.json covers every registry kernel tier"

doc:
	cargo doc --no-deps

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
