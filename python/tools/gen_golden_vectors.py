#!/usr/bin/env python3
"""Generate rust/tests/golden/golden_vectors.json — the committed
golden-vector fixture the kernel-conformance suite pins every kernel tier
against.

This is a line-for-line Python port of the Rust pieces the fixture depends
on (util::prng::{SplitMix64, Xoshiro256}, bnn::model::random_model and the
scalar forward pass), so the expected logits can be authored — and audited
— without a Rust toolchain.  The canonical regeneration path is the
ignored Rust test:

    cargo test --release --test kernel_conformance regenerate -- --ignored

which must produce a byte-identical file (both writers emit compact JSON
with sorted keys and a trailing newline).

The script also differentially checks the port itself: the blocked /
batch-tiled / SIMD row-pair tile schedules (including a word-level model
of the AVX2 nibble-LUT popcount) are simulated here and asserted equal to
the scalar reference before anything is written.
"""

import json
import os
import sys

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Port of rust/src/util/prng.rs SplitMix64."""

    def __init__(self, seed):
        self.state = seed & MASK64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Xoshiro256:
    """Port of rust/src/util/prng.rs Xoshiro256 (xoshiro256**)."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def bool(self):
        return self.next_u64() & 1 == 1


def random_model(dims, seed):
    """Port of bnn::model::random_model: per layer, n_out rows × n_in
    rng.bool() draws (+1 for True, packed as bit 1), zero thresholds on
    hidden layers, raw output layer.  Returns [(rows_bits, has_threshold)]
    where rows_bits is a list of per-neuron {0,1} weight-bit lists."""
    rng = Xoshiro256(seed)
    layers = []
    for li in range(len(dims) - 1):
        n_in, n_out = dims[li], dims[li + 1]
        rows = [[1 if rng.bool() else 0 for _ in range(n_in)] for _ in range(n_out)]
        thresholded = li + 2 < len(dims)
        layers.append((rows, thresholded))
    return layers


def dot_z(x_bits, w_bits):
    """z = Σ ±1·±1 = n − 2·popcount(x ⊕ w) on {0,1} bit lists."""
    return sum(1 if a == b else -1 for a, b in zip(x_bits, w_bits))


def forward(layers, x_bits):
    """Scalar reference forward pass (bnn::model::logits_into)."""
    a = list(x_bits)
    for rows, thresholded in layers:
        z = [dot_z(a, w) for w in rows]
        if thresholded:
            a = [1 if zi >= 0 else 0 for zi in z]  # zero thresholds
        else:
            return z
    raise AssertionError("model has no output layer")


def gen_inputs(n_in, n_inputs, seed):
    rng = Xoshiro256(seed)
    return [[1 if rng.bool() else 0 for _ in range(n_in)] for _ in range(n_inputs)]


# --- differential self-checks of the tile schedules ------------------------


def pack_u64(bits):
    words = [0] * ((len(bits) + 63) // 64)
    for i, b in enumerate(bits):
        words[i // 64] |= (b & 1) << (i % 64)
    return words


def mula_popcount_4words(v_words):
    """Word-level model of the AVX2 nibble-LUT popcount of a 256-bit value
    (4 × u64): vpshufb on low/high nibbles + vpsadbw per-64-bit lane sums.
    Must equal the plain popcount for every input."""
    lut = [bin(i).count("1") for i in range(16)]
    total = 0
    for w in v_words:  # one u64 lane each
        lane = 0
        for byte in range(8):
            b = (w >> (8 * byte)) & 0xFF
            lane += lut[b & 0x0F] + lut[(b >> 4) & 0x0F]
        total += lane  # vpsadbw then lane sum
    return total


def simd_tile_rowpair(imgs_words, n_imgs, rows_words, wpr, n_bits, stride):
    """Port of packing.rs avx2::tile / neon::tile: row pairs share each
    image load; 4-word vector groups use the Mula popcount model, the
    remainder words scalar popcount."""
    n_rows = len(rows_words) // wpr
    out = [0] * (n_imgs * stride)

    def xor_pop(x, w):
        c = 0
        i = 0
        while i + 4 <= wpr:
            c += mula_popcount_4words([x[i + k] ^ w[i + k] for k in range(4)])
            i += 4
        while i < wpr:
            c += bin(x[i] ^ w[i]).count("1")
            i += 1
        return c

    r = 0
    while r + 2 <= n_rows:
        w0 = rows_words[r * wpr:(r + 1) * wpr]
        w1 = rows_words[(r + 1) * wpr:(r + 2) * wpr]
        for i in range(n_imgs):
            x = imgs_words[i * wpr:(i + 1) * wpr]
            out[i * stride + r] = n_bits - 2 * xor_pop(x, w0)
            out[i * stride + r + 1] = n_bits - 2 * xor_pop(x, w1)
        r += 2
    if r < n_rows:
        w = rows_words[r * wpr:(r + 1) * wpr]
        for i in range(n_imgs):
            x = imgs_words[i * wpr:(i + 1) * wpr]
            out[i * stride + r] = n_bits - 2 * xor_pop(x, w)
    return out


def self_check():
    """The SIMD row-pair schedule (with the word-level AVX2 popcount
    model) must equal the ±1 scalar definition at edge widths."""
    rng = Xoshiro256(0xC0FFEE)
    for n in [1, 37, 63, 64, 65, 128, 129, 256, 784]:
        wpr = (n + 63) // 64
        for n_imgs in range(4):
            for n_rows in range(6):
                img_bits = [[1 if rng.bool() else 0 for _ in range(n)] for _ in range(n_imgs)]
                row_bits = [[1 if rng.bool() else 0 for _ in range(n)] for _ in range(n_rows)]
                imgs = [w for b in img_bits for w in pack_u64(b)]
                rows = [w for b in row_bits for w in pack_u64(b)]
                stride = max(n_rows, 1)
                got = simd_tile_rowpair(imgs, n_imgs, rows, wpr, n, stride)
                for i in range(n_imgs):
                    for r in range(n_rows):
                        want = dot_z(img_bits[i], row_bits[r])
                        assert got[i * stride + r] == want, (n, n_imgs, n_rows, i, r)
    print("self-check: SIMD row-pair tile schedule == scalar at all edge widths")


# --- binary convolution (model format v2) ----------------------------------
#
# Three independent implementations of the same binary conv layer are
# cross-checked before anything is written:
#
#   naive_conv   — nested-loop ±1 definition with explicit bounds checks
#                  (padding contributes −1, i.e. packs as bit 0);
#   im2col_conv  — gather each receptive field into a (ky*k + kx)*C_in + c
#                  bit vector and reuse the dense dot_z per output channel;
#   packed_conv  — big-int model of the Rust lowering: contiguous-run bit
#                  copies into packed words, XNOR-popcount per core row,
#                  64-row threshold-pack, splice into the flat packed
#                  output at bit pos*C_out + 64*panel.
#
# Activation bit layout everywhere: (y*W + x)*C + c (pixel-major,
# channel-minor), so a 1×28×28 first layer consumes the existing 784-bit
# row-major MNIST packing unchanged.


def conv_out_dim(n, k, s, p):
    return (n + 2 * p - k) // s + 1


def random_conv_model(in_shape, convs, dense, seed):
    """Mirror of bnn::conv::random_conv_model — one PRNG stream, conv
    layers first (row-major rng.bool() per weight bit, zero thresholds),
    then the dense stack exactly like random_model."""
    rng = Xoshiro256(seed)
    c, h, w = in_shape
    conv_layers = []
    for out_ch, k, stride, pad in convs:
        patch = k * k * c
        rows = [[1 if rng.bool() else 0 for _ in range(patch)] for _ in range(out_ch)]
        conv_layers.append(
            {
                "rows": rows,
                "in_ch": c,
                "in_h": h,
                "in_w": w,
                "out_ch": out_ch,
                "k": k,
                "s": stride,
                "p": pad,
            }
        )
        h, w, c = conv_out_dim(h, k, stride, pad), conv_out_dim(w, k, stride, pad), out_ch
    dims = [c * h * w] + list(dense)
    dense_layers = []
    for li in range(len(dims) - 1):
        rows = [[1 if rng.bool() else 0 for _ in range(dims[li])] for _ in range(dims[li + 1])]
        dense_layers.append((rows, li + 2 < len(dims)))
    return conv_layers, dense_layers


def naive_conv(layer, x_bits):
    """Independent nested-loop reference: ±1 products, explicit bounds
    checks, out-of-image pixels are −1, sign activation at threshold 0."""
    C, H, W = layer["in_ch"], layer["in_h"], layer["in_w"]
    k, s, p, OC = layer["k"], layer["s"], layer["p"], layer["out_ch"]
    OH, OW = conv_out_dim(H, k, s, p), conv_out_dim(W, k, s, p)

    def pm(y, x, c):
        if 0 <= y < H and 0 <= x < W:
            return 1 if x_bits[(y * W + x) * C + c] else -1
        return -1  # padding packs as bit 0

    out = []
    for oy in range(OH):
        for ox in range(OW):
            for co in range(OC):
                wrow = layer["rows"][co]
                z = 0
                for ky in range(k):
                    for kx in range(k):
                        for c in range(C):
                            wv = 1 if wrow[(ky * k + kx) * C + c] else -1
                            z += pm(oy * s - p + ky, ox * s - p + kx, c) * wv
                out.append(1 if z >= 0 else 0)
    return out, (OC, OH, OW)


def im2col_conv(layer, x_bits):
    """im2col lowering at the bit-list level: each patch becomes a
    k*k*C_in bit vector (padding = bit 0) fed to the dense dot_z."""
    C, H, W = layer["in_ch"], layer["in_h"], layer["in_w"]
    k, s, p, OC = layer["k"], layer["s"], layer["p"], layer["out_ch"]
    OH, OW = conv_out_dim(H, k, s, p), conv_out_dim(W, k, s, p)
    out = []
    for oy in range(OH):
        for ox in range(OW):
            patch = [0] * (k * k * C)
            for ky in range(k):
                for kx in range(k):
                    y, x = oy * s - p + ky, ox * s - p + kx
                    if 0 <= y < H and 0 <= x < W:
                        for c in range(C):
                            patch[(ky * k + kx) * C + c] = x_bits[(y * W + x) * C + c]
            for co in range(OC):
                out.append(1 if dot_z(patch, layer["rows"][co]) >= 0 else 0)
    return out, (OC, OH, OW)


def packed_conv(layer, x_bits):
    """Big-int model of the Rust packed lowering (bnn::conv):

    * per kernel row ky, the receptive field (iy, ix0..ix1)×C_in is one
      contiguous run of bits at source offset (iy*W + ix0)*C_in, copied
      to patch offset (ky*k + (ix0 − base_x))*C_in — edge rows clip the
      run, padding stays 0;
    * per core row: z = patch_bits − 2·popcount(patch ⊕ row);
    * per 64-channel panel: threshold-pack (bit j = z ≥ 0) and splice the
      u64 into the flat output at bit pos*C_out + 64·panel."""
    C, H, W = layer["in_ch"], layer["in_h"], layer["in_w"]
    k, s, p, OC = layer["k"], layer["s"], layer["p"], layer["out_ch"]
    OH, OW = conv_out_dim(H, k, s, p), conv_out_dim(W, k, s, p)
    n_patch = k * k * C
    x_int = sum(b << i for i, b in enumerate(x_bits))
    rows_int = [sum(b << i for i, b in enumerate(r)) for r in layer["rows"]]
    out_int = 0
    for oy in range(OH):
        for ox in range(OW):
            pos = oy * OW + ox
            base_y, base_x = oy * s - p, ox * s - p
            patch = 0
            for ky in range(k):
                iy = base_y + ky
                if not 0 <= iy < H:
                    continue
                ix0, ix1 = max(base_x, 0), min(base_x + k, W)
                if ix0 >= ix1:
                    continue
                run = (ix1 - ix0) * C
                src_off = (iy * W + ix0) * C
                dst_off = (ky * k + (ix0 - base_x)) * C
                patch |= ((x_int >> src_off) & ((1 << run) - 1)) << dst_off
            for panel in range((OC + 63) // 64):
                word = 0
                for j in range(min(64, OC - 64 * panel)):
                    z = n_patch - 2 * bin(patch ^ rows_int[64 * panel + j]).count("1")
                    word |= (1 if z >= 0 else 0) << j
                out_int |= word << (pos * OC + 64 * panel)
    return [(out_int >> i) & 1 for i in range(OH * OW * OC)], (OC, OH, OW)


def forward_conv_model(conv_layers, dense_layers, x_bits):
    """Full mixed conv→dense forward pass (packed-lowering conv fronts,
    then the scalar dense reference)."""
    a = list(x_bits)
    for layer in conv_layers:
        a, _ = packed_conv(layer, a)
    return forward(dense_layers, a)


def conv_self_check():
    """naive ≡ im2col ≡ packed over kernel sizes {1,3,5} × strides {1,2}
    × paddings {0,1} × odd channel counts (incl. a 64-panel straddle)."""
    rng = Xoshiro256(0xBEEF)
    checked = 0
    for k in [1, 3, 5]:
        for s in [1, 2]:
            for p in [0, 1]:
                for C, OC in [(1, 5), (3, 7), (2, 66)]:
                    H = W = max(k - 2 * p, 5)
                    layer_rows = [
                        [1 if rng.bool() else 0 for _ in range(k * k * C)] for _ in range(OC)
                    ]
                    layer = {
                        "rows": layer_rows,
                        "in_ch": C,
                        "in_h": H,
                        "in_w": W,
                        "out_ch": OC,
                        "k": k,
                        "s": s,
                        "p": p,
                    }
                    x = [1 if rng.bool() else 0 for _ in range(C * H * W)]
                    a, sa = naive_conv(layer, x)
                    b, sb = im2col_conv(layer, x)
                    c, sc = packed_conv(layer, x)
                    assert sa == sb == sc, (k, s, p, C, OC)
                    assert a == b == c, (k, s, p, C, OC)
                    checked += 1
    print(f"self-check: naive == im2col == packed conv over {checked} geometries")


# --- fixture ---------------------------------------------------------------

# Keep in sync with CASES in rust/tests/common/mod.rs (the regeneration
# test re-derives everything from these seeds).
CASES = [
    ("paper-784-128-64-10", [784, 128, 64, 10], 2601, 9001, 8),
    ("edge-65-63-5-3", [65, 63, 5, 3], 2602, 9002, 8),
    ("edge-37-19-11-3", [37, 19, 11, 3], 2603, 9003, 8),
    ("aligned-128-64-10", [128, 64, 10], 2604, 9004, 4),
    ("single-layer-64-10", [64, 10], 2605, 9005, 4),
]


# Keep in sync with CONV_CASES in rust/tests/common/mod.rs.  Each case is
# (name, (in_ch, in_h, in_w), [(out_ch, k, stride, pad)...], dense_dims,
# model_seed, input_seed, n_inputs).  Geometries cover the MNIST shape,
# stride 2, a two-conv chain with C_in > 1, and a 1×1 conv whose 66
# output channels straddle the 64-row panel boundary.
CONV_CASES = [
    ("mnist-conv3x3-8ch", (1, 28, 28), [(8, 3, 1, 1)], [64, 10], 3601, 9101, 4),
    ("conv5x5-stride2", (1, 28, 28), [(6, 5, 2, 0)], [32, 10], 3602, 9102, 4),
    ("conv-stack-3ch", (3, 9, 9), [(5, 3, 1, 1), (7, 3, 2, 0)], [33, 10], 3603, 9103, 4),
    ("conv1x1-panel-straddle", (2, 6, 6), [(66, 1, 1, 0)], [17, 5], 3604, 9104, 4),
]


def build_conv_fixture():
    cases = []
    for name, in_shape, convs, dense, model_seed, input_seed, n_inputs in CONV_CASES:
        conv_layers, dense_layers = random_conv_model(in_shape, convs, dense, model_seed)
        n_in = in_shape[0] * in_shape[1] * in_shape[2]
        inputs = gen_inputs(n_in, n_inputs, input_seed)
        logits = []
        for x in inputs:
            # the committed logits go through the independent naive conv;
            # the packed-lowering pass must agree bit-for-bit
            a = list(x)
            b = list(x)
            for layer in conv_layers:
                a, _ = naive_conv(layer, a)
                b, _ = packed_conv(layer, b)
                assert a == b, f"{name}: packed lowering diverged from naive conv"
            logits.append(forward(dense_layers, a))
        cases.append(
            {
                "convs": [list(c) for c in convs],
                "dense": list(dense),
                "in_shape": list(in_shape),
                "input_seed": input_seed,
                "logits": logits,
                "model_seed": model_seed,
                "n_inputs": n_inputs,
                "name": name,
            }
        )
    return {
        "cases": cases,
        "generator": "python/tools/gen_golden_vectors.py",
        "version": 1,
    }


def build_fixture():
    cases = []
    for name, dims, model_seed, input_seed, n_inputs in CASES:
        layers = random_model(dims, model_seed)
        inputs = gen_inputs(dims[0], n_inputs, input_seed)
        logits = [forward(layers, x) for x in inputs]
        cases.append(
            {
                "dims": dims,
                "input_seed": input_seed,
                "logits": logits,
                "model_seed": model_seed,
                "n_inputs": n_inputs,
                "name": name,
            }
        )
    return {
        "cases": cases,
        "generator": "python/tools/gen_golden_vectors.py",
        "version": 1,
    }


def write_fixture(fixture, filename):
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "golden", filename
    )
    out_path = os.path.normpath(out_path)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    # Byte-compatible with util::json's writer: compact separators, sorted
    # keys, trailing newline.
    text = json.dumps(fixture, sort_keys=True, separators=(",", ":")) + "\n"
    with open(out_path, "w") as f:
        f.write(text)
    n_inputs = sum(c["n_inputs"] for c in fixture["cases"])
    print(f"wrote {out_path}: {len(fixture['cases'])} cases, {n_inputs} inputs")


def main():
    self_check()
    conv_self_check()
    write_fixture(build_fixture(), "golden_vectors.json")
    write_fixture(build_conv_fixture(), "conv_golden_vectors.json")


if __name__ == "__main__":
    sys.exit(main())
