#!/usr/bin/env python3
"""Generate rust/tests/golden/golden_vectors.json — the committed
golden-vector fixture the kernel-conformance suite pins every kernel tier
against.

This is a line-for-line Python port of the Rust pieces the fixture depends
on (util::prng::{SplitMix64, Xoshiro256}, bnn::model::random_model and the
scalar forward pass), so the expected logits can be authored — and audited
— without a Rust toolchain.  The canonical regeneration path is the
ignored Rust test:

    cargo test --release --test kernel_conformance regenerate -- --ignored

which must produce a byte-identical file (both writers emit compact JSON
with sorted keys and a trailing newline).

The script also differentially checks the port itself: the blocked /
batch-tiled / SIMD row-pair tile schedules (including a word-level model
of the AVX2 nibble-LUT popcount) are simulated here and asserted equal to
the scalar reference before anything is written.
"""

import json
import os
import sys

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Port of rust/src/util/prng.rs SplitMix64."""

    def __init__(self, seed):
        self.state = seed & MASK64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Xoshiro256:
    """Port of rust/src/util/prng.rs Xoshiro256 (xoshiro256**)."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def bool(self):
        return self.next_u64() & 1 == 1


def random_model(dims, seed):
    """Port of bnn::model::random_model: per layer, n_out rows × n_in
    rng.bool() draws (+1 for True, packed as bit 1), zero thresholds on
    hidden layers, raw output layer.  Returns [(rows_bits, has_threshold)]
    where rows_bits is a list of per-neuron {0,1} weight-bit lists."""
    rng = Xoshiro256(seed)
    layers = []
    for li in range(len(dims) - 1):
        n_in, n_out = dims[li], dims[li + 1]
        rows = [[1 if rng.bool() else 0 for _ in range(n_in)] for _ in range(n_out)]
        thresholded = li + 2 < len(dims)
        layers.append((rows, thresholded))
    return layers


def dot_z(x_bits, w_bits):
    """z = Σ ±1·±1 = n − 2·popcount(x ⊕ w) on {0,1} bit lists."""
    return sum(1 if a == b else -1 for a, b in zip(x_bits, w_bits))


def forward(layers, x_bits):
    """Scalar reference forward pass (bnn::model::logits_into)."""
    a = list(x_bits)
    for rows, thresholded in layers:
        z = [dot_z(a, w) for w in rows]
        if thresholded:
            a = [1 if zi >= 0 else 0 for zi in z]  # zero thresholds
        else:
            return z
    raise AssertionError("model has no output layer")


def gen_inputs(n_in, n_inputs, seed):
    rng = Xoshiro256(seed)
    return [[1 if rng.bool() else 0 for _ in range(n_in)] for _ in range(n_inputs)]


# --- differential self-checks of the tile schedules ------------------------


def pack_u64(bits):
    words = [0] * ((len(bits) + 63) // 64)
    for i, b in enumerate(bits):
        words[i // 64] |= (b & 1) << (i % 64)
    return words


def mula_popcount_4words(v_words):
    """Word-level model of the AVX2 nibble-LUT popcount of a 256-bit value
    (4 × u64): vpshufb on low/high nibbles + vpsadbw per-64-bit lane sums.
    Must equal the plain popcount for every input."""
    lut = [bin(i).count("1") for i in range(16)]
    total = 0
    for w in v_words:  # one u64 lane each
        lane = 0
        for byte in range(8):
            b = (w >> (8 * byte)) & 0xFF
            lane += lut[b & 0x0F] + lut[(b >> 4) & 0x0F]
        total += lane  # vpsadbw then lane sum
    return total


def simd_tile_rowpair(imgs_words, n_imgs, rows_words, wpr, n_bits, stride):
    """Port of packing.rs avx2::tile / neon::tile: row pairs share each
    image load; 4-word vector groups use the Mula popcount model, the
    remainder words scalar popcount."""
    n_rows = len(rows_words) // wpr
    out = [0] * (n_imgs * stride)

    def xor_pop(x, w):
        c = 0
        i = 0
        while i + 4 <= wpr:
            c += mula_popcount_4words([x[i + k] ^ w[i + k] for k in range(4)])
            i += 4
        while i < wpr:
            c += bin(x[i] ^ w[i]).count("1")
            i += 1
        return c

    r = 0
    while r + 2 <= n_rows:
        w0 = rows_words[r * wpr:(r + 1) * wpr]
        w1 = rows_words[(r + 1) * wpr:(r + 2) * wpr]
        for i in range(n_imgs):
            x = imgs_words[i * wpr:(i + 1) * wpr]
            out[i * stride + r] = n_bits - 2 * xor_pop(x, w0)
            out[i * stride + r + 1] = n_bits - 2 * xor_pop(x, w1)
        r += 2
    if r < n_rows:
        w = rows_words[r * wpr:(r + 1) * wpr]
        for i in range(n_imgs):
            x = imgs_words[i * wpr:(i + 1) * wpr]
            out[i * stride + r] = n_bits - 2 * xor_pop(x, w)
    return out


def self_check():
    """The SIMD row-pair schedule (with the word-level AVX2 popcount
    model) must equal the ±1 scalar definition at edge widths."""
    rng = Xoshiro256(0xC0FFEE)
    for n in [1, 37, 63, 64, 65, 128, 129, 256, 784]:
        wpr = (n + 63) // 64
        for n_imgs in range(4):
            for n_rows in range(6):
                img_bits = [[1 if rng.bool() else 0 for _ in range(n)] for _ in range(n_imgs)]
                row_bits = [[1 if rng.bool() else 0 for _ in range(n)] for _ in range(n_rows)]
                imgs = [w for b in img_bits for w in pack_u64(b)]
                rows = [w for b in row_bits for w in pack_u64(b)]
                stride = max(n_rows, 1)
                got = simd_tile_rowpair(imgs, n_imgs, rows, wpr, n, stride)
                for i in range(n_imgs):
                    for r in range(n_rows):
                        want = dot_z(img_bits[i], row_bits[r])
                        assert got[i * stride + r] == want, (n, n_imgs, n_rows, i, r)
    print("self-check: SIMD row-pair tile schedule == scalar at all edge widths")


# --- fixture ---------------------------------------------------------------

# Keep in sync with CASES in rust/tests/common/mod.rs (the regeneration
# test re-derives everything from these seeds).
CASES = [
    ("paper-784-128-64-10", [784, 128, 64, 10], 2601, 9001, 8),
    ("edge-65-63-5-3", [65, 63, 5, 3], 2602, 9002, 8),
    ("edge-37-19-11-3", [37, 19, 11, 3], 2603, 9003, 8),
    ("aligned-128-64-10", [128, 64, 10], 2604, 9004, 4),
    ("single-layer-64-10", [64, 10], 2605, 9005, 4),
]


def build_fixture():
    cases = []
    for name, dims, model_seed, input_seed, n_inputs in CASES:
        layers = random_model(dims, model_seed)
        inputs = gen_inputs(dims[0], n_inputs, input_seed)
        logits = [forward(layers, x) for x in inputs]
        cases.append(
            {
                "dims": dims,
                "input_seed": input_seed,
                "logits": logits,
                "model_seed": model_seed,
                "n_inputs": n_inputs,
                "name": name,
            }
        )
    return {
        "cases": cases,
        "generator": "python/tools/gen_golden_vectors.py",
        "version": 1,
    }


def main():
    self_check()
    fixture = build_fixture()
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "..", "rust", "tests", "golden", "golden_vectors.json"
    )
    out_path = os.path.normpath(out_path)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    # Byte-compatible with util::json's writer: compact separators, sorted
    # keys, trailing newline.
    text = json.dumps(fixture, sort_keys=True, separators=(",", ":")) + "\n"
    with open(out_path, "w") as f:
        f.write(text)
    n_inputs = sum(c["n_inputs"] for c in fixture["cases"])
    print(f"wrote {out_path}: {len(fixture['cases'])} cases, {n_inputs} inputs")


if __name__ == "__main__":
    sys.exit(main())
