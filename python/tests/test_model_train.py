"""L2 tests: STE semantics, batch-norm threshold folding, training smoke."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import data as data_mod
from compile import model as model_mod
from compile import train as train_mod
from compile.kernels import packing


# --- STE (paper Eq. 1 + Eq. 2) ----------------------------------------------

def test_ste_sign_forward():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    assert np.array_equal(np.asarray(model_mod.ste_sign(x)), [-1, -1, 1, 1, 1])


def test_ste_sign_gradient_clip():
    g = jax.grad(lambda x: jnp.sum(model_mod.ste_sign(x)))(
        jnp.asarray([-2.0, -0.99, 0.0, 0.99, 2.0])
    )
    assert np.array_equal(np.asarray(g), [0.0, 1.0, 1.0, 1.0, 0.0])


# --- threshold folding (Eq. 4, sign-aware) ----------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_folding_matches_batchnorm_sign(seed):
    """For random BN params and every reachable integer z, the folded
    comparator must reproduce sign(BN(z)) exactly — the paper's core
    numerical transformation."""
    rng = np.random.default_rng(seed)
    n_in = 16
    n_out = 8
    params = {
        "w0": rng.normal(size=(n_out, n_in)).astype(np.float32),
        "bn0": {
            "gamma": rng.normal(scale=1.0, size=n_out).astype(np.float32),
            "beta": rng.normal(scale=2.0, size=n_out).astype(np.float32),
        },
        # unused layers to satisfy the folder's dims walk
        "w1": rng.normal(size=(4, n_out)).astype(np.float32),
        "bn1": {"gamma": np.ones(4, np.float32), "beta": np.zeros(4, np.float32)},
        "w2": rng.normal(size=(2, 4)).astype(np.float32),
        "bn2": {"gamma": np.ones(2, np.float32), "beta": np.zeros(2, np.float32)},
    }
    state = {
        "bn0": {
            "mean": rng.normal(scale=3.0, size=n_out).astype(np.float32),
            "var": rng.uniform(0.1, 4.0, size=n_out).astype(np.float32),
        },
        "bn1": {"mean": np.zeros(4, np.float32), "var": np.ones(4, np.float32)},
        "bn2": {"mean": np.zeros(2, np.float32), "var": np.ones(2, np.float32)},
    }
    import compile.model as m

    old_dims = m.BNN_DIMS
    m.BNN_DIMS = (n_in, n_out, 4, 2)
    try:
        ip = train_mod.fold_thresholds(params, state)
    finally:
        m.BNN_DIMS = old_dims

    w_signed = np.sign(params["w0"]).astype(np.float64)
    w_signed[w_signed == 0] = 1
    g = params["bn0"]["gamma"].astype(np.float64)
    b = params["bn0"]["beta"].astype(np.float64)
    mu = state["bn0"]["mean"].astype(np.float64)
    sig = np.sqrt(state["bn0"]["var"].astype(np.float64) + model_mod.BN_EPS)
    w_folded, thr = ip.hidden[0]
    # every reachable z has parity of n_in; check all of them per neuron
    for j in range(n_out):
        for z in range(-n_in, n_in + 1, 2):
            bn = g[j] * (z - mu[j]) / sig[j] + b[j]
            want = 1 if bn >= 0 else 0
            # folded comparator acts on z' = z·flip where flip = sign
            z_folded = z * (-1 if g[j] < 0 else 1)
            got = 1 if z_folded >= thr[j] else 0
            if bn != 0.0:  # exact-zero BN output is sign-convention territory
                assert got == want, (j, z, bn, thr[j])


def test_folding_flips_rows_for_negative_gamma():
    rng = np.random.default_rng(3)
    params = model_mod.bnn_init(jax.random.PRNGKey(0))
    params["bn0"]["gamma"] = params["bn0"]["gamma"].at[0].set(-1.0)
    state = model_mod.bnn_init_state()
    ip = train_mod.fold_thresholds(params, state)
    w0 = np.sign(np.asarray(params["w0"][0]))
    w0[w0 == 0] = 1
    assert np.array_equal(ip.hidden[0][0][0], -w0)


def test_threshold_11bit_range():
    params = model_mod.bnn_init(jax.random.PRNGKey(1))
    state = model_mod.bnn_init_state()
    # inflate moving means to force clamping
    state["bn0"]["mean"] = state["bn0"]["mean"] + 5000.0
    ip = train_mod.fold_thresholds(params, state)
    for _, thr in ip.hidden:
        assert thr.min() >= -1024 and thr.max() <= 1023


# --- end-to-end folded-path agreement ----------------------------------------

def test_eval_folded_matches_apply_eval_on_trained_net():
    tr_i, tr_l = data_mod.generate(600, 11)
    te_i, te_l = data_mod.generate(200, 12)
    params, state, _ = train_mod.train_bnn(tr_i, tr_l, te_i, te_l, epochs=2, log=lambda *_: None)
    ip = train_mod.fold_thresholds(params, state)
    x = te_i.reshape(len(te_i), -1)
    soft = np.asarray(model_mod.bnn_apply_eval(params, state, jnp.asarray(x)))
    packed = packing.pack_bits_np(data_mod.binarize(x))
    hw = np.asarray(model_mod.bnn_infer_fused(ip, jnp.asarray(packed)))
    # hidden activations are bit-exact; only the output BN (absent in hw)
    # may flip argmax near ties — the paper's own §4.1 software/hardware gap.
    agreement = np.mean(np.argmax(soft, 1) == np.argmax(hw, 1))
    assert agreement > 0.9


def test_training_smoke_loss_decreases_and_learns():
    tr_i, tr_l = data_mod.generate(1200, 21)
    te_i, te_l = data_mod.generate(300, 22)
    _, _, stats = train_mod.train_bnn(tr_i, tr_l, te_i, te_l, epochs=4, log=lambda *_: None)
    assert stats["loss_curve"][-1] < stats["loss_curve"][0]
    assert stats["accuracy"] > 0.4  # 10-class chance = 0.1; smoke-scale run


def test_staircase_lr():
    # float32 arithmetic → compare with relative tolerance
    def lr(step):
        return float(train_mod.staircase_lr(jnp.asarray(step)))

    assert abs(lr(0.0) - 1e-3) < 1e-8
    assert abs(lr(999.0) - 1e-3) < 1e-8
    assert abs(lr(1000.0) - 0.96e-3) < 1e-8
    assert abs(lr(2500.0) - 1e-3 * 0.96**2) < 1e-8


def test_cnn_shapes_and_smoke():
    params = model_mod.cnn_init(jax.random.PRNGKey(0))
    x = jnp.zeros((3, 784), jnp.float32)
    logits = model_mod.cnn_apply(params, x)
    assert logits.shape == (3, 10)
    tr_i, tr_l = data_mod.generate(900, 31)
    te_i, te_l = data_mod.generate(200, 32)
    _, stats = train_mod.train_cnn(tr_i, tr_l, te_i, te_l, epochs=1, log=lambda *_: None)
    assert stats["accuracy"] > 0.25  # smoke-scale run; full build reaches 99 %


def test_adam_matches_reference_step():
    """One Adam step against a hand-computed reference."""
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, -0.25])}
    opt = train_mod.adam_init(p)
    new_p, _ = train_mod.adam_update(g, opt, p, lr=0.1)
    # t=1: corr = sqrt(1-b2)/(1-b1) = sqrt(0.001)/0.1; m=(1-b1)g; v=(1-b2)g²
    # step = lr * corr * m / (sqrt(v)+eps) = lr * g/|g| (approx, eps small)
    np.testing.assert_allclose(np.asarray(new_p["w"]), [0.9, -1.9], atol=1e-4)
