"""L1 correctness: Pallas kernels vs the two independent oracles.

Hypothesis sweeps shapes (batch, widths), operand bit patterns, and
threshold placement — including the z == θ boundary the paper's comparator
semantics (`z ≥ θ`, Algorithm 1 line 14) make load-bearing.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import packing, ref, xnor_dense


def _rand_pm1(rng, shape):
    return rng.choice([-1.0, 1.0], shape).astype(np.float32)


def _case(rng, b, n_in, n_out):
    x = _rand_pm1(rng, (b, n_in))
    w = _rand_pm1(rng, (n_out, n_in))
    return x, w, packing.pack_pm1_np(x), packing.pack_pm1_np(w)


# --- identity: the paper's z = 2m − n == ±1 dot product ---------------------

@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=9),
    st.integers(min_value=1, max_value=260),
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_popcount_identity_property(b, n_in, n_out, seed):
    rng = np.random.default_rng(seed)
    x, w, xp, wp = _case(rng, b, n_in, n_out)
    z_float = np.asarray(ref.binary_dense_ref_float(jnp.asarray(x), jnp.asarray(w)))
    z_packed = np.asarray(ref.binary_dense_ref_packed(jnp.asarray(xp), jnp.asarray(wp), n_in))
    assert np.array_equal(z_float.astype(np.int32), z_packed)
    # parity invariant: z ≡ n (mod 2)
    assert np.all((z_packed - n_in) % 2 == 0)
    assert np.all(np.abs(z_packed) <= n_in)


# --- Pallas hidden kernel vs oracles ----------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=200),
    st.sampled_from([32, 64, 96, 128]),
    st.integers(min_value=1, max_value=790),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_pallas_hidden_vs_ref(b, n_out, n_in, seed):
    rng = np.random.default_rng(seed)
    x, w, xp, wp = _case(rng, b, n_in, n_out)
    thr = rng.integers(-n_in, n_in + 1, n_out).astype(np.int32)
    out = xnor_dense.binary_dense_hidden(
        jnp.asarray(xp), jnp.asarray(wp), jnp.asarray(thr), n_bits=n_in
    )
    got = packing.unpack_bits_np(np.asarray(out), n_out)
    want = np.asarray(
        ref.binary_dense_ref_packed(jnp.asarray(xp), jnp.asarray(wp), n_in, jnp.asarray(thr))
    ).astype(np.uint8)
    assert np.array_equal(got, want)


def test_threshold_boundary_exact():
    """z == θ must fire (comparator is ≥, not >)."""
    n_in = 64
    n_out = 32  # hidden layers must be word-aligned (packed activations)
    x = np.ones((1, n_in), np.float32)
    w = np.ones((n_out, n_in), np.float32)  # z = 64 for every neuron
    for thr, expect in [(64, 1), (65, 0), (63, 1), (-64, 1)]:
        out = xnor_dense.binary_dense_hidden(
            jnp.asarray(packing.pack_pm1_np(x)),
            jnp.asarray(packing.pack_pm1_np(w)),
            jnp.asarray(np.full(n_out, thr, np.int32)),
            n_bits=n_in,
        )
        bits = packing.unpack_bits_np(np.asarray(out), n_out)
        assert np.all(bits == expect), f"thr={thr}"


def test_extreme_z_values():
    n_in = 784
    x = np.ones((2, n_in), np.float32)
    w = np.stack([np.ones(n_in), -np.ones(n_in)]).astype(np.float32)
    z = np.asarray(
        xnor_dense.binary_dense_logits(
            jnp.asarray(packing.pack_pm1_np(x)), jnp.asarray(packing.pack_pm1_np(w)), n_bits=n_in
        )
    )
    assert np.all(z[:, 0] == n_in) and np.all(z[:, 1] == -n_in)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=150),
    st.integers(min_value=1, max_value=33),
    st.integers(min_value=1, max_value=790),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_pallas_logits_vs_ref(b, n_out, n_in, seed):
    rng = np.random.default_rng(seed)
    x, w, xp, wp = _case(rng, b, n_in, n_out)
    got = np.asarray(
        xnor_dense.binary_dense_logits(jnp.asarray(xp), jnp.asarray(wp), n_bits=n_in)
    )
    want = np.asarray(ref.binary_dense_ref_packed(jnp.asarray(xp), jnp.asarray(wp), n_in))
    assert np.array_equal(got, want)


# --- batch-tile padding must be invisible -----------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 3, 127, 128, 129, 255]), st.integers(min_value=0, max_value=2**31))
def test_batch_padding_invariance(b, seed):
    rng = np.random.default_rng(seed)
    x, w, xp, wp = _case(rng, b, 784, 128)
    thr = rng.integers(-100, 100, 128).astype(np.int32)
    small = xnor_dense.binary_dense_hidden(
        jnp.asarray(xp), jnp.asarray(wp), jnp.asarray(thr), n_bits=784, block_b=32
    )
    big = xnor_dense.binary_dense_hidden(
        jnp.asarray(xp), jnp.asarray(wp), jnp.asarray(thr), n_bits=784, block_b=128
    )
    assert np.array_equal(np.asarray(small), np.asarray(big))
    assert small.shape == (b, 4)


# --- fused whole-network kernel vs layered composition ----------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=70), st.integers(min_value=0, max_value=2**32 - 1))
def test_fused_equals_layered(b, seed):
    rng = np.random.default_rng(seed)
    from compile.model import InferenceParams

    hidden = [
        (_rand_pm1(rng, (128, 784)), rng.integers(-60, 60, 128).astype(np.int32)),
        (_rand_pm1(rng, (64, 128)), rng.integers(-30, 30, 64).astype(np.int32)),
    ]
    ip = InferenceParams(hidden=hidden, out_w=_rand_pm1(rng, (10, 64))).pack()
    from compile import model as model_mod

    xp = jnp.asarray(packing.pack_bits_np(rng.integers(0, 2, (b, 784)).astype(np.uint8)))
    fused = np.asarray(model_mod.bnn_infer_fused(ip, xp))
    layered = np.asarray(model_mod.bnn_infer_packed(ip, xp))
    assert np.array_equal(fused, layered)
    # and both against the float oracle
    x_pm1 = packing.unpack_pm1_np(np.asarray(xp), 784)
    want = np.asarray(ref.bnn_forward_ref(ip, jnp.asarray(x_pm1)))
    assert np.array_equal(fused, want.astype(np.int32))


def test_vmem_footprint_budget():
    """The fused kernel's per-grid-step working set must stay ≪ 16 MiB VMEM."""
    fp = xnor_dense.vmem_footprint_bytes((784, 128, 64), 10, block_b=128)
    assert fp["total"] < 256 * 1024  # ~0.25 MiB — tiny vs 16 MiB VMEM
    assert fp["weights"] == 4 * (128 * 25 + 64 * 4 + 10 * 2)
