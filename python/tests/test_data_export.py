"""Dataset generator, idx codec, and .mem export tests."""

import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import data as data_mod
from compile import export as export_mod


# --- synthetic dataset -------------------------------------------------------

def test_generator_deterministic_and_balanced():
    i1, l1 = data_mod.generate(50, 7)
    i2, l2 = data_mod.generate(50, 7)
    assert np.array_equal(i1, i2) and np.array_equal(l1, l2)
    i3, _ = data_mod.generate(50, 8)
    assert not np.array_equal(i1, i3)
    counts = np.bincount(l1, minlength=10)
    assert counts.min() == 5 and counts.max() == 5


def test_images_look_like_digits():
    imgs, _ = data_mod.generate(40, 3)
    assert imgs.shape == (40, 28, 28)
    assert imgs.dtype in (np.float32, np.float64)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    ink = data_mod.binarize(imgs).reshape(40, -1).sum(axis=1)
    assert (ink > 15).all(), "some image nearly empty"
    assert (ink < 500).all(), "some image nearly solid"


def test_binarize_threshold_semantics():
    # p >= 0.5  ⇔  2p−1 >= 0 (Eq. 1 with sign(0)=+1)
    x = np.array([[0.0, 0.499, 0.5, 1.0]])
    assert np.array_equal(data_mod.binarize(x), [[0, 0, 1, 1]])


def test_idx_roundtrip(tmp_path):
    imgs = (np.random.default_rng(0).random((7, 28, 28)) * 255).astype(np.uint8)
    labels = np.arange(7, dtype=np.uint8)
    pi = str(tmp_path / "imgs")
    pl = str(tmp_path / "labels")
    data_mod.write_idx_images(pi, imgs)
    data_mod.write_idx_labels(pl, labels)
    assert np.array_equal(data_mod.read_idx(pi), imgs)
    assert np.array_equal(data_mod.read_idx(pl), labels)


def test_load_or_generate_idempotent(tmp_path):
    d = str(tmp_path / "data")
    a = data_mod.load_or_generate(d, n_train=60, n_test=20, seed=5)
    b = data_mod.load_or_generate(d, n_train=999, n_test=999, seed=99)  # reuses files
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    assert len(a[0]) == 60 and len(a[2]) == 20


# --- hex-row codec (the .mem format) ----------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=800), st.integers(min_value=0, max_value=2**32 - 1))
def test_hex_row_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    row = export_mod.bits_to_hex_row(bits)
    assert len(row) == (n + 3) // 4
    assert np.array_equal(export_mod.hex_row_to_bits(row, n), bits)


def test_hex_row_msb_first():
    # bit n−1 must be the leftmost hex digit's high bit
    bits = np.zeros(8, np.uint8)
    bits[7] = 1
    assert export_mod.bits_to_hex_row(bits) == "80"


def test_threshold_mem_roundtrip(tmp_path):
    p = str(tmp_path / "t.mem")
    thr = np.array([-1024, -1, 0, 1, 1023], np.int32)
    export_mod.write_threshold_mem(p, thr)
    assert np.array_equal(export_mod.read_threshold_mem(p), thr)
    lines = open(p).read().splitlines()
    assert lines[0] == "400" and lines[1] == "7ff" and lines[2] == "000"


def test_weight_mem_format(tmp_path):
    p = str(tmp_path / "w.mem")
    w = np.array([[1.0, -1.0, 1.0], [-1.0, -1.0, -1.0]], np.float32)
    export_mod.write_weight_mem(p, w)
    lines = open(p).read().splitlines()
    assert len(lines) == 2  # neuron-major: one row per neuron
    # row 0 bits LSB-first [1,0,1] = 0b101 = '5'
    assert lines[0] == "5" and lines[1] == "0"


def test_select_subset_interleaved():
    labels = np.array([d for d in range(10) for _ in range(12)], np.uint8)
    idx = export_mod.select_subset(labels)
    assert len(idx) == 100
    # paper order: 0..9, 0..9, ... and exactly 10 per class
    assert np.array_equal(labels[idx][:10], np.arange(10))
    assert np.bincount(labels[idx], minlength=10).tolist() == [10] * 10


def test_export_all_and_reload(tmp_path):
    from compile.model import InferenceParams

    rng = np.random.default_rng(1)
    hidden = [
        (rng.choice([-1.0, 1.0], (128, 784)).astype(np.float32),
         rng.integers(-100, 100, 128).astype(np.int32)),
        (rng.choice([-1.0, 1.0], (64, 128)).astype(np.float32),
         rng.integers(-50, 50, 64).astype(np.int32)),
    ]
    ip = InferenceParams(hidden=hidden, out_w=rng.choice([-1.0, 1.0], (10, 64)).astype(np.float32)).pack()
    imgs, labels = data_mod.generate(120, 4)
    export_mod.export_all(str(tmp_path), ip, {"dummy": np.zeros(3)}, imgs, labels)

    for f in ["weights.json", "params_bnn.npz", "params_cnn.npz",
              "mem/weights_l1.mem", "mem/weights_l2.mem", "mem/weights_l3.mem",
              "mem/thresholds_l1.mem", "mem/thresholds_l2.mem",
              "mem/images_100.mem", "mem/labels_100.mem"]:
        assert os.path.exists(tmp_path / f), f

    ip2 = export_mod.load_inference_params(str(tmp_path))
    for (w1, t1), (w2, t2) in zip(ip.hidden, ip2.hidden):
        assert np.array_equal(w1, w2) and np.array_equal(t1, t2)
    assert np.array_equal(ip.out_w, ip2.out_w)

    # weights.json packed rows must round-trip against the packing module
    import json

    from compile.kernels import packing

    j = json.load(open(tmp_path / "weights.json"))
    assert j["dims"] == [784, 128, 64, 10]
    w_packed = np.array(j["layers"][0]["w_packed"], np.uint32)
    assert np.array_equal(w_packed, packing.pack_pm1_np(hidden[0][0]))
    assert j["layers"][2]["thresholds"] is None


def test_mem_images_match_binarized_pixels(tmp_path):
    imgs, labels = data_mod.generate(100, 6)
    from compile.model import InferenceParams

    rng = np.random.default_rng(2)
    hidden = [
        (rng.choice([-1.0, 1.0], (128, 784)).astype(np.float32), np.zeros(128, np.int32)),
        (rng.choice([-1.0, 1.0], (64, 128)).astype(np.float32), np.zeros(64, np.int32)),
    ]
    ip = InferenceParams(hidden=hidden, out_w=rng.choice([-1.0, 1.0], (10, 64)).astype(np.float32)).pack()
    export_mod.export_all(str(tmp_path), ip, {"d": np.zeros(1)}, imgs, labels)

    rows = open(tmp_path / "mem/images_100.mem").read().splitlines()
    idx = export_mod.select_subset(labels)
    bits = data_mod.binarize(imgs.reshape(len(imgs), -1))
    for row, i in zip(rows, idx):
        assert np.array_equal(export_mod.hex_row_to_bits(row, 784), bits[i])


def test_hex_row_wrong_length_raises():
    with pytest.raises(ValueError):
        export_mod.hex_row_to_bits("zz", 8)
