"""Unit + property tests for the bit-packing substrate."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import packing


def test_packed_words():
    assert packing.packed_words(1) == 1
    assert packing.packed_words(32) == 1
    assert packing.packed_words(33) == 2
    assert packing.packed_words(784) == 25
    assert packing.packed_words(128) == 4
    assert packing.packed_words(64) == 2


def test_pack_known_pattern():
    bits = np.zeros(32, np.uint8)
    bits[0] = 1  # LSB-first: bit 0 → word bit 0
    assert packing.pack_bits_np(bits)[0] == 1
    bits = np.zeros(33, np.uint8)
    bits[32] = 1
    words = packing.pack_bits_np(bits)
    assert list(words) == [0, 1]


def test_pack_all_ones_padding():
    bits = np.ones(784, np.uint8)
    words = packing.pack_bits_np(bits)
    assert words.shape == (25,)
    # last word: 784 = 24*32 + 16 → low 16 bits set
    assert words[-1] == 0xFFFF
    assert all(w == 0xFFFFFFFF for w in words[:-1])


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=300), st.integers(min_value=0, max_value=2**32 - 1))
def test_roundtrip_property(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.uint8)
    assert np.array_equal(packing.unpack_bits_np(packing.pack_bits_np(bits), n), bits)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=200),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_roundtrip_batched(b, n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (b, n)).astype(np.uint8)
    assert np.array_equal(packing.unpack_bits_np(packing.pack_bits_np(bits), n), bits)


def test_pm1_roundtrip():
    rng = np.random.default_rng(7)
    x = rng.choice([-1.0, 1.0], 784).astype(np.float32)
    words = packing.pack_pm1_np(x)
    assert np.array_equal(packing.unpack_pm1_np(words, 784), x)


def test_pm1_sign_zero_is_plus_one():
    # Eq. 1: sign(0) = +1
    assert packing.pack_pm1_np(np.array([0.0]))[0] & 1 == 1


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=200), st.integers(min_value=0, max_value=2**32 - 1))
def test_jnp_matches_np(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (3, n)).astype(np.uint8)
    np_words = packing.pack_bits_np(bits)
    j_words = np.asarray(packing.pack_bits_jnp(jnp.asarray(bits)))
    assert np.array_equal(np_words, j_words)
    j_bits = np.asarray(packing.unpack_bits_jnp(jnp.asarray(np_words), n))
    assert np.array_equal(j_bits, bits)


def test_pack_rejects_scalar():
    with pytest.raises(ValueError):
        packing.pack_bits_np(np.uint8(1))
