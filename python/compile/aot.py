"""AOT compile path: lower the L2 inference graphs to HLO **text** artifacts.

Python runs only here (``make artifacts``); the Rust coordinator loads the
emitted ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
executes them on the PJRT CPU client (see ``rust/src/runtime``).

HLO *text* — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (weights baked as constants so the request path ships activations
only):

* ``bnn_b{B}.hlo.txt``  — fused Pallas BNN forward, packed uint32 input
  ``[B, 25]`` → int32 logits ``[B, 10]``; B covers the dynamic batcher's
  ladder plus the Table 5 batch sweep.
* ``cnn_b{B}.hlo.txt``  — CNN baseline, float32 ``[B, 784]`` → ``[B, 10]``.
* ``manifest.json``     — artifact registry the Rust runtime consumes.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import export as export_mod
from . import model as model_mod
from .kernels import packing

# Dynamic-batcher ladder ∪ Table 5 batch sizes.
BNN_BATCHES = (1, 2, 4, 8, 10, 16, 32, 64, 100, 128, 256, 1000, 10000)
CNN_BATCHES = (1, 10, 100)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser).

    CRITICAL: the default ``as_hlo_text()`` elides large constants as
    ``{...}`` — the baked weight matrices would silently become zeros on
    the Rust side.  Print with ``print_large_constants=True`` (and without
    metadata noise) so the artifact is self-contained.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower_bnn(ip: model_mod.InferenceParams, batch: int) -> str:
    """Lower the fused packed forward for a fixed batch size."""
    block_b = min(batch, 128)

    def fn(x_packed):
        return (model_mod.bnn_infer_fused(ip, x_packed, interpret=True),)

    spec = jax.ShapeDtypeStruct((batch, packing.packed_words(ip.n_in)), jnp.uint32)
    return to_hlo_text(jax.jit(fn).lower(spec)), block_b


def lower_cnn(params: dict, batch: int) -> str:
    def fn(images):
        return (model_mod.cnn_apply(params, images),)

    spec = jax.ShapeDtypeStruct((batch, 784), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-cnn", action="store_true")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    ip = export_mod.load_inference_params(out)
    cnn_raw = np.load(os.path.join(out, "params_cnn.npz"))
    cnn_params = {k: jnp.asarray(cnn_raw[k]) for k in cnn_raw.files}

    manifest = {"artifacts": []}
    for b in BNN_BATCHES:
        text, _ = lower_bnn(ip, b)
        name = f"bnn_b{b}"
        path = os.path.join(out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "model": "bnn",
                "batch": b,
                "file": f"{name}.hlo.txt",
                "input": {"shape": [b, packing.packed_words(ip.n_in)], "dtype": "u32"},
                "output": {"shape": [b, 10], "dtype": "i32"},
            }
        )
        print(f"[aot] wrote {name} ({len(text)} chars)")

    if not args.skip_cnn:
        for b in CNN_BATCHES:
            text = lower_cnn(cnn_params, b)
            name = f"cnn_b{b}"
            path = os.path.join(out, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "model": "cnn",
                    "batch": b,
                    "file": f"{name}.hlo.txt",
                    "input": {"shape": [b, 784], "dtype": "f32"},
                    "output": {"shape": [b, 10], "dtype": "f32"},
                }
            )
            print(f"[aot] wrote {name} ({len(text)} chars)")

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
