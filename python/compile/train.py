"""Build-time training: STE quantization-aware BNN + CNN baseline.

Reproduces §3.1's recipe in JAX (the TensorFlow/Larq substitution —
DESIGN.md): Adam, sparse categorical cross-entropy, batch 64, 15 epochs,
exponential staircase LR decay (0.001 × 0.96^⌊step/1000⌋), and the
batch-norm → threshold folding of Eq. 4 (in its sign-aware exact form).

Run as ``python -m compile.train --out ../artifacts`` (driven by ``make
artifacts``); also importable by pytest for smoke-scale runs.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .model import BN_EPS, InferenceParams

BATCH = 64
BASE_LR = 1e-3
DECAY = 0.96
DECAY_STEPS = 1000
THRESH_BITS = 11  # paper §3.1: thresholds quantized as 11-bit signed integers


# ---------------------------------------------------------------------------
# Hand-rolled Adam (optax unavailable offline)

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": zeros, "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, opt, params, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
    tf = t.astype(jnp.float32)
    corr = jnp.sqrt(1 - b2**tf) / (1 - b1**tf)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * corr * m_ / (jnp.sqrt(v_) + eps), params, m, v
    )
    return new_params, {"m": m, "v": v, "t": t}


def staircase_lr(step: jnp.ndarray) -> jnp.ndarray:
    """§3.1: 0.001 decayed ×0.96 every 1000 steps, staircase."""
    return BASE_LR * DECAY ** jnp.floor(step / DECAY_STEPS)


def xent(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1))


# ---------------------------------------------------------------------------
# BNN training

# NOTE: no donate_argnums — freshly-initialized zero buffers alias under
# XLA's constant dedup, and donating an aliased buffer twice is an error.
@jax.jit
def _bnn_step(params, state, opt, images, labels):
    def loss_fn(p):
        logits, new_state = model_mod.bnn_apply_train(p, state, images)
        return xent(logits, labels), new_state

    (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    lr = staircase_lr(opt["t"].astype(jnp.float32))
    params, opt = adam_update(grads, opt, params, lr)
    return params, new_state, opt, loss


@jax.jit
def _bnn_eval_batch(params, state, images, labels):
    logits = model_mod.bnn_apply_eval(params, state, images)
    return jnp.sum(jnp.argmax(logits, axis=1) == labels)


def eval_bnn(params, state, images, labels, batch=1000) -> float:
    correct = 0
    for i in range(0, len(images), batch):
        correct += int(
            _bnn_eval_batch(params, state, images[i : i + batch], labels[i : i + batch])
        )
    return correct / len(images)


def train_bnn(
    train_images,
    train_labels,
    test_images,
    test_labels,
    epochs: int = 15,
    seed: int = 0,
    log=print,
):
    """Train the 784-128-64-10 BNN; returns (params, state, stats dict)."""
    params = model_mod.bnn_init(jax.random.PRNGKey(seed))
    state = model_mod.bnn_init_state()
    opt = adam_init(params)
    x = train_images.reshape(len(train_images), -1)
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    losses = []
    for epoch in range(epochs):
        order = rng.permutation(len(x))
        epoch_loss, batches = 0.0, 0
        for i in range(0, len(x) - BATCH + 1, BATCH):
            idx = order[i : i + BATCH]
            params, state, opt, loss = _bnn_step(
                params, state, opt, jnp.asarray(x[idx]), jnp.asarray(train_labels[idx])
            )
            epoch_loss += float(loss)
            batches += 1
        losses.append(epoch_loss / batches)
        log(f"[bnn] epoch {epoch + 1}/{epochs} loss={losses[-1]:.4f}")
    train_s = time.perf_counter() - t0
    acc = eval_bnn(params, state, test_images.reshape(len(test_images), -1), test_labels)
    log(f"[bnn] test accuracy {acc:.4f} ({train_s:.1f}s)")
    return params, state, {"accuracy": acc, "train_seconds": train_s, "loss_curve": losses}


# ---------------------------------------------------------------------------
# Threshold folding (paper Eq. 4, sign-aware exact form)

def fold_thresholds(params, state) -> InferenceParams:
    """Fold each hidden batch-norm + sign into an integer threshold.

    A hidden activation fires (bit = 1) iff γ(z − μ)/√(σ²+ε) + β ≥ 0, i.e.

    * γ > 0:  z ≥ μ − β·√(σ²+ε)/γ   → θ = ⌈μ − βσ'/γ⌉, row unchanged;
    * γ < 0:  z ≤ μ − β·√(σ²+ε)/γ   → flip the neuron's weight row
      (z → −z), θ = ⌈−(μ − βσ'/γ)⌉;
    * γ = 0:  activation is constant sign(β) → θ = ∓(n+1) (always/never).

    Thresholds are clamped to the 11-bit signed range (§3.1); the output
    layer keeps raw sums (no threshold), matching the FSM's classification
    stage (§3.4).
    """
    hidden = []
    n_layers = len(model_mod.BNN_DIMS) - 1
    for i in range(n_layers - 1):
        w = np.sign(np.asarray(params[f"w{i}"], np.float64))
        w[w == 0] = 1.0
        g = np.asarray(params[f"bn{i}"]["gamma"], np.float64)
        b = np.asarray(params[f"bn{i}"]["beta"], np.float64)
        mu = np.asarray(state[f"bn{i}"]["mean"], np.float64)
        sig = np.sqrt(np.asarray(state[f"bn{i}"]["var"], np.float64) + BN_EPS)
        n_in = w.shape[1]
        t_real = np.where(g != 0, mu - b * sig / np.where(g != 0, g, 1.0), 0.0)
        theta = np.where(
            g > 0,
            np.ceil(t_real),
            np.where(g < 0, np.ceil(-t_real), np.where(b >= 0, -(n_in + 1), n_in + 1)),
        )
        w = np.where((g < 0)[:, None], -w, w)
        lim = 2 ** (THRESH_BITS - 1)
        theta = np.clip(theta, -lim, lim - 1).astype(np.int32)
        hidden.append((w.astype(np.float32), theta))
    w_out = np.sign(np.asarray(params[f"w{n_layers - 1}"], np.float64))
    w_out[w_out == 0] = 1.0
    return InferenceParams(hidden=hidden, out_w=w_out.astype(np.float32)).pack()


def eval_folded(ip: InferenceParams, images, labels, batch=1000) -> float:
    """Hardware-path accuracy: packed kernels + raw-sum argmax (§4.1)."""
    from .kernels import packing

    bits = data_mod.binarize(images.reshape(len(images), -1))
    packed = packing.pack_bits_np(bits)
    correct = 0
    for i in range(0, len(packed), batch):
        logits = model_mod.bnn_infer_fused(ip, jnp.asarray(packed[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == jnp.asarray(labels[i : i + batch])))
    return correct / len(images)


# ---------------------------------------------------------------------------
# CNN baseline training (§4.6)

@jax.jit
def _cnn_step(params, opt, images, labels, key):
    def loss_fn(p):
        logits = model_mod.cnn_apply(p, images, dropout_key=key)
        return xent(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt = adam_update(grads, opt, params, BASE_LR)
    return params, opt, loss


@jax.jit
def _cnn_eval_batch(params, images, labels):
    logits = model_mod.cnn_apply(params, images)
    return jnp.sum(jnp.argmax(logits, axis=1) == labels)


def eval_cnn(params, images, labels, batch=500) -> float:
    correct = 0
    x = images.reshape(len(images), -1)
    for i in range(0, len(x), batch):
        correct += int(_cnn_eval_batch(params, x[i : i + batch], labels[i : i + batch]))
    return correct / len(images)


def train_cnn(train_images, train_labels, test_images, test_labels, epochs=3, seed=0, log=print):
    """Train the CNN baseline; paper used 10 epochs — the synthetic task
    saturates earlier, so the default is 3 (configurable via --cnn-epochs)."""
    params = model_mod.cnn_init(jax.random.PRNGKey(seed + 100))
    opt = adam_init(params)
    x = train_images.reshape(len(train_images), -1)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 200)
    t0 = time.perf_counter()
    for epoch in range(epochs):
        order = rng.permutation(len(x))
        epoch_loss, batches = 0.0, 0
        for i in range(0, len(x) - BATCH + 1, BATCH):
            idx = order[i : i + BATCH]
            key, sub = jax.random.split(key)
            params, opt, loss = _cnn_step(
                params, opt, jnp.asarray(x[idx]), jnp.asarray(train_labels[idx]), sub
            )
            epoch_loss += float(loss)
            batches += 1
        log(f"[cnn] epoch {epoch + 1}/{epochs} loss={epoch_loss / batches:.4f}")
    train_s = time.perf_counter() - t0
    acc = eval_cnn(params, test_images, test_labels)
    log(f"[cnn] test accuracy {acc:.4f} ({train_s:.1f}s)")
    return params, {"accuracy": acc, "train_seconds": train_s}


# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--cnn-epochs", type=int, default=3)
    ap.add_argument("--train-size", type=int, default=20000)
    ap.add_argument("--test-size", type=int, default=4000)
    ap.add_argument("--seed", type=int, default=2025)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    tr_i, tr_l, te_i, te_l = data_mod.load_or_generate(
        os.path.join(args.out, "data"), args.train_size, args.test_size, args.seed
    )
    params, state, bnn_stats = train_bnn(tr_i, tr_l, te_i, te_l, args.epochs, args.seed)
    ip = fold_thresholds(params, state)
    bnn_stats["folded_accuracy"] = eval_folded(ip, te_i, te_l)
    print(f"[bnn] folded (hardware-path) accuracy {bnn_stats['folded_accuracy']:.4f}")
    cnn_params, cnn_stats = train_cnn(tr_i, tr_l, te_i, te_l, args.cnn_epochs, args.seed)

    from . import export

    export.export_all(args.out, ip, cnn_params, te_i, te_l)
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump({"bnn": bnn_stats, "cnn": cnn_stats}, f, indent=2)
    print(f"[train] artifacts written to {args.out}")


if __name__ == "__main__":
    main()
