"""Model export: hardware-ready `.mem` files + `weights.json` + npz params.

Mirrors the paper's §3.2 export path:

* binarized weight matrices are **transposed to neuron-major rows** (one ROM
  row = one neuron's full input weight vector) and written as `.mem` files
  — one hex row per line, MSB-first, exactly the `$readmemh` layout the
  Verilog design consumes;
* folded batch-norm thresholds are 11-bit signed integers, one 3-hex-digit
  two's-complement value per line;
* the §4.1 correctness subset (100 binarized test images, 10 per digit) is
  exported the same way, plus its label file.

Additionally (for this reproduction's Rust layers):

* ``weights.json`` — packed uint32 operands + thresholds for the Rust
  native backend and the FPGA simulator (parsed by ``rust/src/mem``);
* ``params_bnn.npz`` / ``params_cnn.npz`` — consumed by ``aot.py`` when
  baking the AOT HLO artifacts.
"""

from __future__ import annotations

import json
import os

import numpy as np

from . import data as data_mod
from .kernels import packing
from .model import InferenceParams


def bits_to_hex_row(bits: np.ndarray) -> str:
    """{0,1} vector → MSB-first hex string (bit n−1 is the leftmost bit)."""
    n = len(bits)
    pad = (-n) % 4
    padded = np.concatenate([np.zeros(pad, dtype=np.uint8), bits[::-1]])
    digits = padded.reshape(-1, 4)
    vals = digits[:, 0] * 8 + digits[:, 1] * 4 + digits[:, 2] * 2 + digits[:, 3]
    return "".join("0123456789abcdef"[v] for v in vals)


def hex_row_to_bits(row: str, n_bits: int) -> np.ndarray:
    """Inverse of :func:`bits_to_hex_row`."""
    val = int(row, 16)
    return np.array([(val >> i) & 1 for i in range(n_bits)], dtype=np.uint8)


def write_weight_mem(path: str, w_pm1: np.ndarray) -> None:
    """Write a ±1 weight matrix ``[N, I]`` as N hex rows (neuron-major)."""
    bits = (w_pm1 >= 0).astype(np.uint8)
    with open(path, "w") as f:
        for row in bits:
            f.write(bits_to_hex_row(row) + "\n")


def write_threshold_mem(path: str, thresholds: np.ndarray, bits: int = 11) -> None:
    """Write thresholds as two's-complement hex, one per line (11-bit §3.1)."""
    mask = (1 << bits) - 1
    width = (bits + 3) // 4
    with open(path, "w") as f:
        for t in np.asarray(thresholds, np.int64):
            f.write(format(int(t) & mask, f"0{width}x") + "\n")


def read_threshold_mem(path: str, bits: int = 11) -> np.ndarray:
    """Read a threshold `.mem` back into signed integers."""
    sign_bit = 1 << (bits - 1)
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            v = int(line, 16)
            out.append(v - (1 << bits) if v & sign_bit else v)
    return np.array(out, dtype=np.int32)


def write_image_mem(path: str, image_bits: np.ndarray) -> None:
    """Write binarized images ``[N, 784]`` as hex rows (one image per line)."""
    with open(path, "w") as f:
        for row in image_bits:
            f.write(bits_to_hex_row(row) + "\n")


def select_subset(labels: np.ndarray, per_class: int = 10, classes: int = 10) -> np.ndarray:
    """First ``per_class`` indices of each class, interleaved 0..9,0..9,…
    (the paper's '10 representative samples for each digit')."""
    buckets = [np.where(labels == c)[0][:per_class] for c in range(classes)]
    return np.array([buckets[c][i] for i in range(per_class) for c in range(classes)])


def export_all(
    out_dir: str,
    ip: InferenceParams,
    cnn_params: dict,
    test_images: np.ndarray,
    test_labels: np.ndarray,
) -> None:
    mem_dir = os.path.join(out_dir, "mem")
    os.makedirs(mem_dir, exist_ok=True)

    # --- .mem files (paper's hardware format) -----------------------------
    layer_ws = [w for w, _ in ip.hidden] + [ip.out_w]
    for i, w in enumerate(layer_ws, start=1):
        write_weight_mem(os.path.join(mem_dir, f"weights_l{i}.mem"), w)
    for i, (_, thr) in enumerate(ip.hidden, start=1):
        write_threshold_mem(os.path.join(mem_dir, f"thresholds_l{i}.mem"), thr)

    flat = test_images.reshape(len(test_images), -1)
    bits = data_mod.binarize(flat)
    idx = select_subset(test_labels)
    write_image_mem(os.path.join(mem_dir, "images_100.mem"), bits[idx])
    with open(os.path.join(mem_dir, "labels_100.mem"), "w") as f:
        for i in idx:
            f.write(f"{int(test_labels[i]):x}\n")

    # --- weights.json (Rust native backend + simulator) -------------------
    layers = []
    dims_in = [ip.n_in] + [w.shape[0] for w, _ in ip.hidden]
    for li, w_packed in enumerate(ip.packed["w"]):
        thr = ip.packed["t"][li].tolist() if li < len(ip.packed["t"]) else None
        layers.append(
            {
                "n_in": dims_in[li],
                "n_out": int(layer_ws[li].shape[0]),
                "w_packed": [[int(v) for v in row] for row in w_packed],
                "thresholds": thr,
            }
        )
    with open(os.path.join(out_dir, "weights.json"), "w") as f:
        json.dump({"dims": [ip.n_in] + [w.shape[0] for w in layer_ws], "layers": layers}, f)

    # --- npz params for aot.py --------------------------------------------
    bnn_npz = {}
    for i, (w, t) in enumerate(ip.hidden):
        bnn_npz[f"w{i}"] = w
        bnn_npz[f"t{i}"] = t
    bnn_npz["w_out"] = ip.out_w
    np.savez(os.path.join(out_dir, "params_bnn.npz"), **bnn_npz)
    np.savez(os.path.join(out_dir, "params_cnn.npz"), **{k: np.asarray(v) for k, v in cnn_params.items()})


def load_inference_params(out_dir: str) -> InferenceParams:
    """Reload folded parameters from ``params_bnn.npz`` (used by aot.py/tests)."""
    z = np.load(os.path.join(out_dir, "params_bnn.npz"))
    hidden, i = [], 0
    while f"w{i}" in z:
        hidden.append((z[f"w{i}"], z[f"t{i}"]))
        i += 1
    return InferenceParams(hidden=hidden, out_w=z["w_out"]).pack()


def model_file_sizes(out_dir: str) -> dict:
    """§4.6 model-size comparison: packed BNN payload vs float CNN payload."""
    bnn = os.path.getsize(os.path.join(out_dir, "params_bnn.npz"))
    cnn = os.path.getsize(os.path.join(out_dir, "params_cnn.npz"))
    return {"bnn_bytes": bnn, "cnn_bytes": cnn}
