"""Pure-``jnp`` correctness oracles for the Pallas XNOR-popcount kernels.

Two independent references:

* :func:`binary_dense_ref_float` — the mathematically transparent one: the
  ±1 dot product ``z = Σ x_i·w_i`` computed as a float matmul over unpacked
  ±1 values, then (optionally) the threshold activation.  This is the
  "what the paper means" oracle (§2.1: z = 2·popcount(XNOR(x,w)) − n is an
  identity for the ±1 dot product).

* :func:`binary_dense_ref_packed` — the same computation done on the packed
  words with ``lax.population_count`` but *without* Pallas, exercising the
  identical integer path the kernel uses.  Agreement of all three is the
  core L1 correctness signal (pytest + hypothesis in ``python/tests``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import packing


def binary_dense_ref_float(
    x_pm1: jnp.ndarray,
    w_pm1: jnp.ndarray,
    thresholds: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """±1 dense layer oracle on unpacked values.

    Args:
      x_pm1: ``[B, I]`` float ±1 activations.
      w_pm1: ``[N, I]`` float ±1 weights (neuron-major, the paper's
        transposed ROM layout).
      thresholds: optional ``[N]`` int/float folded thresholds.  When given,
        returns {0,1} activations ``(z >= θ)`` (paper Algorithm 1 line 14);
        otherwise returns the integer-valued float sums ``z``.

    Returns:
      ``[B, N]`` float32: sums or {0,1} activations.
    """
    z = x_pm1.astype(jnp.float32) @ w_pm1.astype(jnp.float32).T
    if thresholds is None:
        return z
    return (z >= thresholds.astype(jnp.float32)[None, :]).astype(jnp.float32)


def binary_dense_ref_packed(
    x_packed: jnp.ndarray,
    w_packed: jnp.ndarray,
    n_bits: int,
    thresholds: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Packed-word oracle: ``z = n − 2·popcount(x ^ w)`` without Pallas.

    Args:
      x_packed: ``[B, W]`` uint32 packed activations.
      w_packed: ``[N, W]`` uint32 packed weights.
      n_bits: true (unpadded) vector length ``n``.
      thresholds: optional ``[N]`` int32 folded thresholds.

    Returns:
      ``[B, N]`` int32 sums, or {0,1} int32 activations when thresholded.
    """
    xor = x_packed[:, None, :] ^ w_packed[None, :, :]
    mismatches = jnp.sum(
        jax.lax.population_count(xor).astype(jnp.int32), axis=-1, dtype=jnp.int32
    )
    z = jnp.int32(n_bits) - 2 * mismatches
    if thresholds is None:
        return z
    return (z >= thresholds.astype(jnp.int32)[None, :]).astype(jnp.int32)


def bnn_forward_ref(params, x_pm1: jnp.ndarray) -> jnp.ndarray:
    """Full-network float oracle: three ±1 dense layers, folded thresholds.

    ``params`` is the exported inference parameter struct (see
    ``export.InferenceParams``): per hidden layer a ±1 weight matrix and an
    integer threshold vector; the output layer keeps raw integer sums
    (paper §3.4: "no thresholding is applied ... raw sums are retained").

    Returns ``[B, 10]`` float32 logits (integer-valued).
    """
    a = x_pm1
    for w_pm1, thr in params.hidden:
        bits = binary_dense_ref_float(a, w_pm1, thr)
        a = bits * 2.0 - 1.0  # {0,1} → ±1 for the next layer's XNOR input
    return binary_dense_ref_float(a, params.out_w)


def bnn_forward_ref_packed(params, x_packed: jnp.ndarray) -> jnp.ndarray:
    """Full-network packed oracle (non-Pallas integer path)."""
    a = x_packed
    n = params.n_in
    for w_pm1, thr in params.hidden:
        w_packed = jnp.asarray(packing.pack_pm1_np(jax.device_get(w_pm1)))
        bits = binary_dense_ref_packed(a, w_packed, n, thr)
        a = packing.pack_bits_jnp(bits.astype(jnp.uint8))
        n = w_pm1.shape[0]
    w_packed = jnp.asarray(packing.pack_pm1_np(jax.device_get(params.out_w)))
    return binary_dense_ref_packed(a, w_packed, n)
