"""L1 Pallas kernels: bit-packed XNOR-popcount binary dense layers.

This is the paper's compute hot-spot (§2.1, Algorithm 1) re-thought for a
TPU-style memory hierarchy instead of FPGA BRAM/LUT fabric
(DESIGN.md §Hardware-Adaptation):

* The FPGA packs one neuron's weight row per BRAM row; we pack 32 binary
  (±1) values per ``uint32`` lane and keep the same neuron-major layout —
  ``w_packed[N, W]`` — so a whole layer is a ``popcount(x ^ w)`` reduction
  over lane words, the VPU analogue of the paper's P parallel XNOR units.
* The FPGA FSM's address generator walking BRAM rows becomes the
  ``BlockSpec`` grid: each grid step stages one ``[TILE_B, W]`` activation
  slab and the full ``[N, W]`` weight slab in VMEM (N ≤ 128 here, so the
  weight slab is at most 128 × 25 × 4 B = 12.5 KiB — far under VMEM).
* The FPGA threshold comparators (folded batch norm, §3.1 Eq. 4) are fused
  into the same kernel: hidden activations are thresholded *and re-packed
  to words* before they ever leave VMEM, so layer-to-layer traffic is
  ``N/32`` words per sample, exactly like the accelerator's activation
  registers.

All kernels run under ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example
README).  Numerics are identical either way; structure (tiling, fusion,
VMEM footprint) is what we optimize here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import packing

WORD_BITS = packing.WORD_BITS

# Batch tile: 128 samples × 25 words × 4 B = 12.5 KiB activation slab per
# grid step; together with the ≤12.5 KiB weight slab this keeps each grid
# step's VMEM working set ≈ 25 KiB (see DESIGN.md §Perf).
DEFAULT_BLOCK_B = 128


def _xnor_popcount_z(x_words: jnp.ndarray, w_words: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Core identity: signed ±1 dot product from packed words.

    ``z = 2m − n`` with ``m = popcount(XNOR)`` (§2.1) is computed in the
    complementary form ``z = n − 2·popcount(XOR)`` — padding bits are 0 in
    both operands, so XOR never counts them and the true ``n`` corrects the
    sum exactly.
    """
    xor = x_words[:, None, :] ^ w_words[None, :, :]
    mismatches = jnp.sum(
        jax.lax.population_count(xor).astype(jnp.int32), axis=-1, dtype=jnp.int32
    )
    return jnp.int32(n_bits) - 2 * mismatches


def _pack_rows(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a ``[B, N]`` {0,1} int32 array into ``[B, N/32]`` uint32 (N % 32 == 0)."""
    b, n = bits.shape
    grouped = bits.astype(jnp.uint32).reshape(b, n // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)


def _hidden_kernel(x_ref, w_ref, t_ref, o_ref, *, n_bits: int):
    """Hidden-layer grid step: XNOR-popcount → threshold → packed activations."""
    z = _xnor_popcount_z(x_ref[...], w_ref[...], n_bits)
    bits = (z >= t_ref[...][None, :]).astype(jnp.int32)
    o_ref[...] = _pack_rows(bits)


def _logits_kernel(x_ref, w_ref, o_ref, *, n_bits: int):
    """Output-layer grid step: raw integer sums, no thresholding (§3.4)."""
    o_ref[...] = _xnor_popcount_z(x_ref[...], w_ref[...], n_bits)


def _pad_batch(x: jnp.ndarray, block_b: int) -> tuple[jnp.ndarray, int]:
    b = x.shape[0]
    padded = pl.cdiv(b, block_b) * block_b
    if padded != b:
        x = jnp.pad(x, ((0, padded - b), (0, 0)))
    return x, b


@functools.partial(jax.jit, static_argnames=("n_bits", "block_b", "interpret"))
def binary_dense_hidden(
    x_packed: jnp.ndarray,
    w_packed: jnp.ndarray,
    thresholds: jnp.ndarray,
    *,
    n_bits: int,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = True,
) -> jnp.ndarray:
    """Packed hidden binary dense layer: ``pack(z(x,w) >= θ)``.

    Args:
      x_packed: ``[B, ceil(n_bits/32)]`` uint32 packed ±1 activations.
      w_packed: ``[N, ceil(n_bits/32)]`` uint32 packed ±1 weights
        (neuron-major — the paper's transposed ROM layout, §3.2).
      thresholds: ``[N]`` int32 folded batch-norm thresholds (11-bit range).
      n_bits: true input width ``n`` (784 or the previous layer's N).

    Returns:
      ``[B, N/32]`` uint32 packed {0,1} activations (N must divide by 32).
    """
    n_out, w_words = w_packed.shape
    if n_out % WORD_BITS:
        raise ValueError(f"hidden layer width {n_out} must be a multiple of {WORD_BITS}")
    if x_packed.shape[-1] != w_words:
        raise ValueError(f"word-count mismatch: x {x_packed.shape[-1]} vs w {w_words}")
    x_packed, b = _pad_batch(x_packed, block_b)
    grid = (x_packed.shape[0] // block_b,)
    out = pl.pallas_call(
        functools.partial(_hidden_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, w_words), lambda i: (i, 0)),
            pl.BlockSpec((n_out, w_words), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, n_out // WORD_BITS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x_packed.shape[0], n_out // WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(x_packed, w_packed, thresholds.astype(jnp.int32))
    return out[:b]


@functools.partial(jax.jit, static_argnames=("n_bits", "block_b", "interpret"))
def binary_dense_logits(
    x_packed: jnp.ndarray,
    w_packed: jnp.ndarray,
    *,
    n_bits: int,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = True,
) -> jnp.ndarray:
    """Packed output binary dense layer: raw integer sums ``z`` (argmax'd by L3).

    Returns ``[B, N]`` int32 integer logits.
    """
    n_out, w_words = w_packed.shape
    if x_packed.shape[-1] != w_words:
        raise ValueError(f"word-count mismatch: x {x_packed.shape[-1]} vs w {w_words}")
    x_packed, b = _pad_batch(x_packed, block_b)
    grid = (x_packed.shape[0] // block_b,)
    out = pl.pallas_call(
        functools.partial(_logits_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, w_words), lambda i: (i, 0)),
            pl.BlockSpec((n_out, w_words), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x_packed.shape[0], n_out), jnp.int32),
        interpret=interpret,
    )(x_packed, w_packed)
    return out[:b]


def _fused_kernel(x_ref, w1_ref, t1_ref, w2_ref, t2_ref, w3_ref, o_ref, *, dims):
    """Whole-network grid step: three layers without leaving VMEM.

    The FPGA keeps inter-layer activations in registers between FSM stages;
    the fused kernel is the same idea — only the 784-bit input slab enters
    and only the 10 int32 logits leave per sample.
    """
    n_in, n_h1, n_h2 = dims
    z1 = _xnor_popcount_z(x_ref[...], w1_ref[...], n_in)
    a1 = _pack_rows((z1 >= t1_ref[...][None, :]).astype(jnp.int32))
    z2 = _xnor_popcount_z(a1, w2_ref[...], n_h1)
    a2 = _pack_rows((z2 >= t2_ref[...][None, :]).astype(jnp.int32))
    o_ref[...] = _xnor_popcount_z(a2, w3_ref[...], n_h2)


@functools.partial(jax.jit, static_argnames=("dims", "block_b", "interpret"))
def bnn_fused_forward(
    x_packed: jnp.ndarray,
    w1: jnp.ndarray,
    t1: jnp.ndarray,
    w2: jnp.ndarray,
    t2: jnp.ndarray,
    w3: jnp.ndarray,
    *,
    dims: tuple[int, int, int],
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused 784→128→64→10 forward pass as a single Pallas kernel.

    Args:
      x_packed: ``[B, ceil(n_in/32)]`` uint32 packed input bits.
      w1/w2/w3: packed neuron-major weights per layer.
      t1/t2: int32 folded thresholds for the hidden layers.
      dims: ``(n_in, n_h1, n_h2)`` true bit widths feeding each layer.

    Returns ``[B, 10]`` int32 logits.
    """
    n_in, n_h1, n_h2 = dims
    n_out = w3.shape[0]
    x_packed, b = _pad_batch(x_packed, block_b)
    grid = (x_packed.shape[0] // block_b,)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    out = pl.pallas_call(
        functools.partial(_fused_kernel, dims=dims),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, x_packed.shape[1]), lambda i: (i, 0)),
            full(w1.shape),
            full(t1.shape),
            full(w2.shape),
            full(t2.shape),
            full(w3.shape),
        ],
        out_specs=pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x_packed.shape[0], n_out), jnp.int32),
        interpret=interpret,
    )(x_packed, w1, t1.astype(jnp.int32), w2, t2.astype(jnp.int32), w3)
    return out[:b]


def vmem_footprint_bytes(dims: tuple[int, int, int], n_out: int, block_b: int) -> dict:
    """Static VMEM-footprint estimate per grid step (the L1 perf metric we
    can measure honestly under interpret=True — see DESIGN.md §Perf)."""
    n_in, n_h1, n_h2 = dims
    w = packing.packed_words
    weights = 4 * (n_h1 * w(n_in) + n_h2 * w(n_h1) + n_out * w(n_h2))
    thresholds = 4 * (n_h1 + n_h2)
    act_in = 4 * block_b * w(n_in)
    inter = 4 * block_b * max(n_h1, w(n_h1) + n_h2)  # widest live intermediate
    logits = 4 * block_b * n_out
    total = weights + thresholds + act_in + inter + logits
    return {
        "weights": weights,
        "thresholds": thresholds,
        "activations_in": act_in,
        "intermediates": inter,
        "logits_out": logits,
        "total": total,
    }
