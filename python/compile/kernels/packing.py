"""Bit-packing utilities for binary (±1) tensors.

The paper stores binary weights/activations as single bits: +1 ↦ 1, −1 ↦ 0
(§2.1).  We pack 32 of those bits LSB-first into a ``uint32`` lane word so
the XNOR-popcount dot product becomes a vectorized
``popcount(x ^ w)`` reduction (see ``xnor_dense.py``).

Padding convention: when ``n`` is not a multiple of 32 the tail bits of the
last word are 0 in *both* operands, so they XOR to 0 and never contribute a
mismatch.  The signed dot product is recovered as ``z = n − 2·mismatches``
with the *true* ``n`` (§2.1: z = 2m − n with m = n − mismatches).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def packed_words(n_bits: int) -> int:
    """Number of uint32 words needed to hold ``n_bits`` bits."""
    return (n_bits + WORD_BITS - 1) // WORD_BITS


def pack_bits_np(bits: np.ndarray) -> np.ndarray:
    """Pack a {0,1} uint8/bool array ``[..., n]`` into ``[..., ceil(n/32)]`` uint32.

    Bit ``i`` of the flattened last axis lands in word ``i // 32`` at
    position ``i % 32`` (LSB-first), matching the Rust ``bnn::packing``
    module and the ``.mem`` export layout.
    """
    bits = np.asarray(bits)
    if bits.ndim == 0:
        raise ValueError("pack_bits_np requires at least 1-D input")
    n = bits.shape[-1]
    w = packed_words(n)
    pad = w * WORD_BITS - n
    if pad:
        bits = np.concatenate(
            [bits, np.zeros(bits.shape[:-1] + (pad,), dtype=bits.dtype)], axis=-1
        )
    bits = bits.reshape(bits.shape[:-1] + (w, WORD_BITS)).astype(np.uint64)
    shifts = np.arange(WORD_BITS, dtype=np.uint64)
    words = np.sum(bits << shifts, axis=-1)
    return words.astype(np.uint32)


def unpack_bits_np(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Inverse of :func:`pack_bits_np`; returns a {0,1} uint8 array ``[..., n_bits]``."""
    words = np.asarray(words, dtype=np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[..., None] >> shifts) & np.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    return bits[..., :n_bits].astype(np.uint8)


def pack_pm1_np(x: np.ndarray) -> np.ndarray:
    """Pack a ±1 (or sign-of-float) array into uint32 words: +1 ↦ bit 1, −1 ↦ bit 0.

    Zero is treated as +1 per the paper's sign convention (Eq. 1: sign(0) = +1).
    """
    return pack_bits_np((np.asarray(x) >= 0).astype(np.uint8))


def unpack_pm1_np(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Unpack uint32 words into a ±1 ``float32`` array."""
    bits = unpack_bits_np(words, n_bits).astype(np.float32)
    return bits * 2.0 - 1.0


def pack_bits_jnp(bits: jnp.ndarray) -> jnp.ndarray:
    """JAX version of :func:`pack_bits_np` (traceable; used inside models).

    ``bits`` is a {0,1} integer array ``[..., n]`` with n a multiple of 32
    NOT required — zero padding is applied exactly as in the numpy path.
    """
    n = bits.shape[-1]
    w = packed_words(n)
    pad = w * WORD_BITS - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), dtype=bits.dtype)], axis=-1
        )
    bits = bits.reshape(bits.shape[:-1] + (w, WORD_BITS)).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits_jnp(words: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """JAX version of :func:`unpack_bits_np`."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    return bits[..., :n_bits].astype(jnp.uint8)
