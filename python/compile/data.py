"""Synthetic-MNIST generator (the dataset substitution — DESIGN.md).

The build environment has no network access, so the real MNIST idx files
cannot be fetched.  This module procedurally renders a seeded, deterministic
10-class 28×28 handwritten-digit-like dataset:

* each digit class has a stroke-template (polyline skeleton on a 28×28
  canvas, hand-designed to match the topology of the digit);
* per sample the skeleton is perturbed with a random affine map (rotation,
  anisotropic scale, shear, translation), per-vertex jitter, variable
  stroke thickness, intensity variation and pixel noise — the same axes of
  variation MNIST exhibits;
* images are exported in the real MNIST **idx** container format
  (magic 0x803/0x801) so the Rust `mem::idx` codec reads them unchanged.

What this preserves for the reproduction: every hardware-side number in the
paper (latency, resources, power, timing) depends only on tensor *shapes*;
the accuracy experiments depend on having a 10-class 784-bit task where a
binarized MLP lands in the high-80s/low-90s and a small CNN near-saturates —
which this task reproduces (see EXPERIMENTS.md §4.1/§4.6).
"""

from __future__ import annotations

import gzip
import os
import struct

import numpy as np

IMG = 28

# Polyline skeletons per digit on a [0,1]² canvas, y down.  Multiple strokes
# per digit; tuples are (x, y) vertices.
_T = {
    0: [[(0.50, 0.08), (0.78, 0.22), (0.82, 0.50), (0.76, 0.78), (0.50, 0.92),
         (0.24, 0.78), (0.18, 0.50), (0.22, 0.22), (0.50, 0.08)]],
    1: [[(0.35, 0.25), (0.55, 0.10), (0.55, 0.90)], [(0.35, 0.90), (0.75, 0.90)]],
    2: [[(0.22, 0.28), (0.35, 0.12), (0.62, 0.10), (0.78, 0.26), (0.74, 0.45),
         (0.45, 0.65), (0.22, 0.88), (0.80, 0.88)]],
    3: [[(0.24, 0.16), (0.55, 0.10), (0.76, 0.24), (0.66, 0.44), (0.45, 0.50),
         (0.68, 0.56), (0.78, 0.76), (0.55, 0.92), (0.24, 0.84)]],
    4: [[(0.62, 0.90), (0.62, 0.10), (0.20, 0.62), (0.82, 0.62)]],
    5: [[(0.76, 0.12), (0.30, 0.12), (0.26, 0.46), (0.58, 0.42), (0.78, 0.58),
         (0.74, 0.82), (0.48, 0.92), (0.24, 0.82)]],
    6: [[(0.68, 0.10), (0.40, 0.26), (0.26, 0.52), (0.28, 0.78), (0.50, 0.92),
         (0.72, 0.80), (0.74, 0.60), (0.54, 0.48), (0.32, 0.56)]],
    7: [[(0.20, 0.12), (0.80, 0.12), (0.48, 0.90)], [(0.34, 0.52), (0.66, 0.52)]],
    8: [[(0.50, 0.10), (0.72, 0.20), (0.70, 0.40), (0.50, 0.50), (0.30, 0.40),
         (0.28, 0.20), (0.50, 0.10)],
        [(0.50, 0.50), (0.74, 0.62), (0.72, 0.84), (0.50, 0.92), (0.28, 0.84),
         (0.26, 0.62), (0.50, 0.50)]],
    9: [[(0.72, 0.40), (0.52, 0.50), (0.30, 0.40), (0.28, 0.20), (0.50, 0.10),
         (0.70, 0.18), (0.72, 0.40), (0.70, 0.66), (0.56, 0.90), (0.36, 0.88)]],
}


def _affine(rng: np.random.Generator) -> np.ndarray:
    """Random 2×3 affine map (rotation/scale/shear/translate) around canvas center.

    Ranges are tuned (EXPERIMENTS.md §dataset-calibration) so a binarized
    784-128-64-10 MLP lands in the paper's high-80s accuracy band while the
    CNN baseline stays ≈99 % — preserving the §4.6 accuracy gap."""
    ang = rng.uniform(-0.40, 0.40)  # ≈ ±23°
    sx, sy = rng.uniform(0.62, 1.1, size=2)
    shear = rng.uniform(-0.27, 0.27)
    ca, sa = np.cos(ang), np.sin(ang)
    rot = np.array([[ca, -sa], [sa, ca]])
    sc = np.array([[sx, shear * sx], [0.0, sy]])
    m = rot @ sc
    t = rng.uniform(-0.11, 0.11, size=2)
    out = np.zeros((2, 3))
    out[:, :2] = m
    out[:, 2] = t + 0.5 - m @ np.array([0.5, 0.5])
    return out


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Rasterize one perturbed digit to a float32 [28,28] image in [0,1]."""
    aff = _affine(rng)
    thick = rng.uniform(0.7, 2.1)
    img = np.zeros((IMG, IMG), dtype=np.float32)
    yy, xx = np.mgrid[0:IMG, 0:IMG]
    px = (xx.astype(np.float32) + 0.5) / IMG
    py = (yy.astype(np.float32) + 0.5) / IMG
    for stroke in _T[digit]:
        pts = np.array(stroke, dtype=np.float32)
        pts = pts + rng.normal(0.0, 0.028, size=pts.shape)  # per-vertex jitter
        pts = (aff[:, :2] @ pts.T).T + aff[:, 2]
        for a, b in zip(pts[:-1], pts[1:]):
            # distance from every pixel center to segment ab
            ab = b - a
            denom = float(ab @ ab) + 1e-9
            t = ((px - a[0]) * ab[0] + (py - a[1]) * ab[1]) / denom
            t = np.clip(t, 0.0, 1.0)
            dx = px - (a[0] + t * ab[0])
            dy = py - (a[1] + t * ab[1])
            d = np.sqrt(dx * dx + dy * dy) * IMG  # in pixels
            img = np.maximum(img, np.clip(1.6 * thick - d, 0.0, 1.0))
    img *= rng.uniform(0.6, 1.0)
    img += rng.normal(0.0, 0.095, size=img.shape).astype(np.float32)
    # occasional occlusion bar — MNIST-style stroke breakage
    if rng.random() < 0.22:
        r0 = rng.integers(0, IMG - 3)
        c0 = rng.integers(0, IMG - 3)
        img[r0 : r0 + 2, c0 : c0 + rng.integers(4, 12)] *= rng.uniform(0.0, 0.4)
    return np.clip(img, 0.0, 1.0)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` samples: returns (images ``[n,28,28]`` float32 in [0,1],
    labels ``[n]`` uint8).  Classes are balanced round-robin then shuffled."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.uint8) % 10
    rng.shuffle(labels)
    imgs = np.stack([_render(int(l), rng) for l in labels])
    return imgs, labels


def binarize(imgs: np.ndarray) -> np.ndarray:
    """Paper §3.1: normalize to [−1, 1] then sign-binarize → {0,1} bits.

    Pixel p ∈ [0,1] → 2p−1 ∈ [−1,1] → bit = 1 iff 2p−1 ≥ 0 iff p ≥ 0.5.
    """
    return (imgs >= 0.5).astype(np.uint8)


# ---------------------------------------------------------------------------
# idx container codec (real MNIST file format) — mirrored by rust mem::idx.

def write_idx_images(path: str, imgs_u8: np.ndarray) -> None:
    """Write ``[n, 28, 28]`` uint8 images as an idx3-ubyte file."""
    n, r, c = imgs_u8.shape
    with open(path, "wb") as f:
        f.write(struct.pack(">IIII", 0x803, n, r, c))
        f.write(imgs_u8.astype(np.uint8).tobytes())


def write_idx_labels(path: str, labels: np.ndarray) -> None:
    """Write ``[n]`` uint8 labels as an idx1-ubyte file."""
    with open(path, "wb") as f:
        f.write(struct.pack(">II", 0x801, len(labels)))
        f.write(labels.astype(np.uint8).tobytes())


def read_idx(path: str) -> np.ndarray:
    """Read an idx1/idx3 ubyte file (transparently gunzips ``.gz``)."""
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rb") as f:
        magic = struct.unpack(">I", f.read(4))[0]
        ndim = magic & 0xFF
        dims = struct.unpack(f">{ndim}I", f.read(4 * ndim))
        data = np.frombuffer(f.read(), dtype=np.uint8)
    return data.reshape(dims)


def load_or_generate(
    out_dir: str,
    n_train: int = 20000,
    n_test: int = 4000,
    seed: int = 2025,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Idempotent dataset builder: writes idx files under ``out_dir`` on first
    call, reads them back afterwards.  If real MNIST idx files are dropped
    into ``out_dir`` (same names), they are used instead — the substitution
    is transparent."""
    os.makedirs(out_dir, exist_ok=True)
    paths = {
        "ti": os.path.join(out_dir, "train-images-idx3-ubyte"),
        "tl": os.path.join(out_dir, "train-labels-idx1-ubyte"),
        "vi": os.path.join(out_dir, "t10k-images-idx3-ubyte"),
        "vl": os.path.join(out_dir, "t10k-labels-idx1-ubyte"),
    }
    if not all(os.path.exists(p) for p in paths.values()):
        tr_i, tr_l = generate(n_train, seed)
        te_i, te_l = generate(n_test, seed + 1)
        write_idx_images(paths["ti"], (tr_i * 255).astype(np.uint8))
        write_idx_labels(paths["tl"], tr_l)
        write_idx_images(paths["vi"], (te_i * 255).astype(np.uint8))
        write_idx_labels(paths["vl"], te_l)
    tr_i = read_idx(paths["ti"]).astype(np.float32) / 255.0
    tr_l = read_idx(paths["tl"])
    te_i = read_idx(paths["vi"]).astype(np.float32) / 255.0
    te_l = read_idx(paths["vl"])
    return tr_i, tr_l, te_i, te_l
