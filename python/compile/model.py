"""L2 — JAX model definitions.

Three graphs live here:

* **BNN training graph** (:func:`bnn_apply_train` / :func:`bnn_apply_eval`)
  — the paper's §3.1 architecture (784→128→64→10, no biases, batch-norm
  after every layer) with ste_sign quantization of weights and activations
  (§2.2).  Used only at build time by ``train.py``.
* **BNN packed inference graph** (:func:`bnn_infer_packed` /
  :func:`bnn_infer_fused`) — the deployed forward pass over folded
  thresholds and bit-packed operands, calling the L1 Pallas kernels.  This
  is what ``aot.py`` lowers to HLO for the Rust runtime.
* **CNN baseline** (§4.6: conv3×3×32 → pool → conv3×3×64 → pool →
  dense128+ReLU(+dropout in training) → dense10) for the Table 4/5 and
  Fig. 1 comparisons.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import packing, xnor_dense

BNN_DIMS = (784, 128, 64, 10)
BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Straight-through estimator (paper §2.2, Eq. 2)

def ste_sign(x: jnp.ndarray) -> jnp.ndarray:
    """sign(x) in the forward pass, clipped-identity gradient in the backward.

    Forward: +1 for x ≥ 0, −1 otherwise (Eq. 1, sign(0) = +1 as in Larq).
    Backward: dy/dx = 1 for |x| ≤ 1, else 0 (Eq. 2) — implemented as the
    gradient of clip(x, −1, 1) with the sign value straight-through.
    """
    clipped = jnp.clip(x, -1.0, 1.0)
    signed = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    return clipped + jax.lax.stop_gradient(signed - clipped)


# ---------------------------------------------------------------------------
# BNN training-time parameters

def bnn_init(key: jax.Array, dims=BNN_DIMS) -> dict:
    """Glorot-uniform latent weights + identity batch-norm state per layer."""
    params = {}
    keys = jax.random.split(key, len(dims) - 1)
    for i, (fan_in, fan_out) in enumerate(zip(dims[:-1], dims[1:])):
        limit = float(np.sqrt(6.0 / (fan_in + fan_out)))
        params[f"w{i}"] = jax.random.uniform(
            keys[i], (fan_out, fan_in), jnp.float32, -limit, limit
        )
        params[f"bn{i}"] = {
            "gamma": jnp.ones((fan_out,), jnp.float32),
            "beta": jnp.zeros((fan_out,), jnp.float32),
        }
    return params


def bnn_init_state(dims=BNN_DIMS) -> dict:
    """Non-trainable batch-norm moving statistics."""
    return {
        f"bn{i}": {
            "mean": jnp.zeros((n,), jnp.float32),
            "var": jnp.ones((n,), jnp.float32),
        }
        for i, n in enumerate(dims[1:])
    }


def _bn_train(x, p, s):
    mu = jnp.mean(x, axis=0)
    var = jnp.var(x, axis=0)
    new_s = {
        "mean": BN_MOMENTUM * s["mean"] + (1 - BN_MOMENTUM) * mu,
        "var": BN_MOMENTUM * s["var"] + (1 - BN_MOMENTUM) * var,
    }
    y = p["gamma"] * (x - mu) * jax.lax.rsqrt(var + BN_EPS) + p["beta"]
    return y, new_s


def _bn_eval(x, p, s):
    return p["gamma"] * (x - s["mean"]) * jax.lax.rsqrt(s["var"] + BN_EPS) + p["beta"]


def bnn_apply_train(params: dict, state: dict, images: jnp.ndarray):
    """Training forward pass on ``[B, 784]`` images in [0, 1].

    Returns ``(logits [B,10], new_state)``.  Inputs are rescaled to [−1, 1]
    and sign-binarized (§3.1); hidden activations are ste_sign(BN(z)); the
    output layer keeps the real-valued BN output as logits.
    """
    a = ste_sign(images * 2.0 - 1.0)
    new_state = {}
    n_layers = len(BNN_DIMS) - 1
    for i in range(n_layers):
        z = a @ ste_sign(params[f"w{i}"]).T
        h, new_state[f"bn{i}"] = _bn_train(z, params[f"bn{i}"], state[f"bn{i}"])
        a = ste_sign(h) if i < n_layers - 1 else h
    return a, new_state


def bnn_apply_eval(params: dict, state: dict, images: jnp.ndarray) -> jnp.ndarray:
    """Evaluation forward pass using moving batch-norm statistics."""
    a = ste_sign(images * 2.0 - 1.0)
    n_layers = len(BNN_DIMS) - 1
    for i in range(n_layers):
        z = a @ ste_sign(params[f"w{i}"]).T
        h = _bn_eval(z, params[f"bn{i}"], state[f"bn{i}"])
        a = ste_sign(h) if i < n_layers - 1 else h
    return a


# ---------------------------------------------------------------------------
# Deployed (folded, packed) inference parameters

@dataclass
class InferenceParams:
    """Hardware-ready parameters: packed ±1 weights + folded int thresholds.

    ``hidden``: list of (w_pm1 [N, I] float ±1, thresholds [N] int32) —
    weight rows already sign-flipped where the folded batch-norm γ was
    negative (see ``train.fold_thresholds``).  ``out_w``: [10, 64] ±1.
    """

    hidden: list  # [(w_pm1, thresholds)]
    out_w: np.ndarray
    n_in: int = 784
    packed: dict = field(default_factory=dict)

    def pack(self) -> "InferenceParams":
        """Precompute packed uint32 operands for the kernels."""
        self.packed = {
            "w": [packing.pack_pm1_np(w) for w, _ in self.hidden]
            + [packing.pack_pm1_np(self.out_w)],
            "t": [np.asarray(t, np.int32) for _, t in self.hidden],
        }
        return self

    @property
    def dims(self) -> tuple[int, int, int]:
        return (self.n_in, self.hidden[0][0].shape[0], self.hidden[1][0].shape[0])


def bnn_infer_packed(ip: InferenceParams, x_packed: jnp.ndarray, *, interpret=True) -> jnp.ndarray:
    """Layer-by-layer packed inference via the L1 Pallas kernels.

    ``x_packed``: [B, 25] uint32 packed input bits → [B, 10] int32 logits.
    """
    w = ip.packed["w"]
    t = ip.packed["t"]
    n_in, n_h1, n_h2 = ip.dims
    a = xnor_dense.binary_dense_hidden(
        x_packed, jnp.asarray(w[0]), jnp.asarray(t[0]), n_bits=n_in, interpret=interpret
    )
    a = xnor_dense.binary_dense_hidden(
        a, jnp.asarray(w[1]), jnp.asarray(t[1]), n_bits=n_h1, interpret=interpret
    )
    return xnor_dense.binary_dense_logits(
        a, jnp.asarray(w[2]), n_bits=n_h2, interpret=interpret
    )


def bnn_infer_fused(ip: InferenceParams, x_packed: jnp.ndarray, *, interpret=True) -> jnp.ndarray:
    """Single fused Pallas kernel for the whole network (hot path)."""
    w = ip.packed["w"]
    t = ip.packed["t"]
    return xnor_dense.bnn_fused_forward(
        x_packed,
        jnp.asarray(w[0]), jnp.asarray(t[0]),
        jnp.asarray(w[1]), jnp.asarray(t[1]),
        jnp.asarray(w[2]),
        dims=ip.dims,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# CNN baseline (§4.6)

CNN_SPEC = dict(c1=32, c2=64, dense=128, classes=10)


def cnn_init(key: jax.Array) -> dict:
    """He-normal initialized CNN parameters matching the paper's baseline."""
    k = jax.random.split(key, 4)
    he = lambda kk, shape, fan_in: jax.random.normal(kk, shape, jnp.float32) * np.sqrt(
        2.0 / fan_in
    )
    # After two 'valid' conv3x3 + pool2 stages: 28→26→13→11→5; 5*5*64 = 1600.
    return {
        "conv1": he(k[0], (3, 3, 1, CNN_SPEC["c1"]), 9),
        "b1": jnp.zeros((CNN_SPEC["c1"],)),
        "conv2": he(k[1], (3, 3, CNN_SPEC["c1"], CNN_SPEC["c2"]), 9 * CNN_SPEC["c1"]),
        "b2": jnp.zeros((CNN_SPEC["c2"],)),
        "dense1": he(k[2], (5 * 5 * CNN_SPEC["c2"], CNN_SPEC["dense"]), 5 * 5 * CNN_SPEC["c2"]),
        "db1": jnp.zeros((CNN_SPEC["dense"],)),
        "dense2": he(k[3], (CNN_SPEC["dense"], CNN_SPEC["classes"]), CNN_SPEC["dense"]),
        "db2": jnp.zeros((CNN_SPEC["classes"],)),
    }


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_apply(params: dict, images: jnp.ndarray, *, dropout_key=None) -> jnp.ndarray:
    """CNN forward on ``[B, 784]`` images in [0,1]; dropout only when a key is given."""
    x = images.reshape(-1, 28, 28, 1)
    for conv, bias in (("conv1", "b1"), ("conv2", "b2")):
        x = jax.lax.conv_general_dilated(
            x, params[conv], (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        ) + params[bias]
        x = jax.nn.relu(x)
        x = _maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["dense1"] + params["db1"])
    if dropout_key is not None:
        keep = jax.random.bernoulli(dropout_key, 0.5, x.shape)
        x = jnp.where(keep, x / 0.5, 0.0)
    return x @ params["dense2"] + params["db2"]
