//! Async wire server e2e: bit-identity with the blocking server, ≥ 1024
//! concurrent connections on one event-loop thread, connection-cap
//! admission control, the `submitted == completed + rejected` ledger under
//! queue-full overload, slow-loris resilience, and typed idle timeouts.
//! Everything runs artifact-free on a `random_model`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;
use bnn_fpga::bnn::model::{random_model, BnnModel};
use bnn_fpga::bnn::Packed;
use bnn_fpga::coordinator::wire::{
    encode_request, read_response_v2, MAGIC_ERR, MAGIC_RESP,
};
use bnn_fpga::coordinator::{
    AsyncWireServer, BatcherConfig, Engine, InferBackend, InferOptions, InferScratch, Kernel,
    LogitsBuf, Metrics, WireClient, WireServer, WireServerConfig, WireStatus,
};
use bnn_fpga::util::prng::Xoshiro256;

fn rand_image(rng: &mut Xoshiro256, n_bits: usize) -> Packed {
    let bits: Vec<u8> = (0..n_bits).map(|_| rng.bool() as u8).collect();
    Packed::from_bits(&bits)
}

fn engine_784(seed: u64) -> (BnnModel, Arc<Engine>) {
    let model = random_model(&[784, 128, 64, 10], seed);
    let engine = Arc::new(
        Engine::builder()
            .native(&model)
            .kernel(Kernel::default())
            .workers(2)
            .batcher(BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .unwrap(),
    );
    (model, engine)
}

/// Raise the fd soft limit toward `want` (CI runners often default to
/// 1024, which the 1024-connection test would exhaust with client +
/// server sockets in one process).  Best-effort: never lowers, never
/// exceeds the hard limit.
#[cfg(target_os = "linux")]
fn raise_nofile_limit(want: u64) {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 || r.cur >= want {
            return;
        }
        let bumped = RLimit {
            cur: want.min(r.max),
            max: r.max,
        };
        let _ = setrlimit(RLIMIT_NOFILE, &bumped);
    }
}

#[cfg(not(target_os = "linux"))]
fn raise_nofile_limit(_want: u64) {}

/// Poll `cond` until it holds or `deadline` elapses; panics with `what` on
/// timeout.
fn wait_until(what: &str, deadline: Duration, mut cond: impl FnMut() -> bool) {
    let start = Instant::now();
    while !cond() {
        assert!(start.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

// ---------------------------------------------------------------------------
// bit-identity with the blocking server

#[test]
fn async_and_blocking_servers_answer_bit_identically() {
    let (model, blocking_engine) = engine_784(41);
    let (_, async_engine) = engine_784(41); // same seed ⇒ same weights
    let blocking = WireServer::start("127.0.0.1:0", blocking_engine).unwrap();
    let asynch = AsyncWireServer::start("127.0.0.1:0", async_engine).unwrap();

    let mut rng = Xoshiro256::new(7);
    let images: Vec<Packed> = (0..40).map(|_| rand_image(&mut rng, 784)).collect();

    let mut cb = WireClient::connect(blocking.addr).unwrap();
    let mut ca = WireClient::connect(asynch.addr).unwrap();

    // v1: digit + status must match (the latency field measures wall time,
    // so it is excluded from bit-identity by design)
    for img in images.iter().take(16) {
        let rb = cb.classify(img).unwrap();
        let ra = ca.classify(img).unwrap();
        assert_eq!(ra.digit, rb.digit, "v1 digit diverged");
        assert_eq!(ra.status, rb.status, "v1 status diverged");
        assert_eq!(ra.digit as usize, model.predict(&img.words));
    }

    // v2 batch with every optional section on: ids, digits, logits and
    // top-k must be byte-equal between the servers
    let opts = InferOptions::default().with_logits(true).with_top_k(3);
    let ib = cb.classify_batch(&images[..8], opts).unwrap();
    let ia = ca.classify_batch(&images[..8], opts).unwrap();
    assert_eq!(ib.len(), ia.len());
    for (b, a) in ib.iter().zip(ia.iter()) {
        assert_eq!(a.digit, b.digit, "v2 digit diverged");
        assert_eq!(a.logits, b.logits, "v2 logits diverged");
        assert_eq!(a.top_k, b.top_k, "v2 top-k diverged");
    }

    // pipelined v2 against the async server: in-order, correct digits
    let items = ca.classify_pipelined(&images, InferOptions::digits_only()).unwrap();
    assert_eq!(items.len(), images.len());
    for (item, img) in items.iter().zip(images.iter()) {
        assert_eq!(item.digit as usize, model.predict(&img.words));
    }

    // malformed magic: both servers answer the same 7-byte v1 error frame
    // and then close
    for addr in [blocking.addr, asynch.addr] {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&[0x5A]).unwrap();
        let mut frame = [0u8; 7];
        s.read_exact(&mut frame).unwrap();
        assert_eq!(frame[0], MAGIC_ERR);
        assert_eq!(WireStatus::from_u8(frame[1]), WireStatus::BadMagic);
        let mut probe = [0u8; 1];
        assert_eq!(s.read(&mut probe).unwrap(), 0, "connection must close after BadMagic");
    }

    asynch.shutdown();
    blocking.shutdown();
}

// ---------------------------------------------------------------------------
// fanout: ≥ 1024 concurrent connections, gauges balancing

#[test]
fn async_server_sustains_1024_concurrent_connections() {
    raise_nofile_limit(16_384);
    let (model, engine) = engine_784(43);
    let cfg = WireServerConfig {
        max_conns: 2048,
        idle_timeout: Duration::from_secs(60),
    };
    let server = AsyncWireServer::start_with("127.0.0.1:0", engine, cfg).unwrap();

    const CONNS: usize = 1024;
    let mut rng = Xoshiro256::new(9);
    let images: Vec<Packed> = (0..16).map(|_| rand_image(&mut rng, 784)).collect();
    let digits: Vec<u16> = images.iter().map(|i| model.predict(&i.words) as u16).collect();

    let mut clients: Vec<WireClient> = Vec::with_capacity(CONNS);
    for i in 0..CONNS {
        clients.push(WireClient::connect(server.addr).unwrap());
        // let the single accept loop drain the listen backlog
        if i % 100 == 99 {
            std::thread::sleep(Duration::from_millis(30));
        }
    }
    let m = server.metrics().clone();
    wait_until("all 1024 connections admitted", Duration::from_secs(30), || {
        m.conn_open.load(Ordering::SeqCst) == CONNS as u64
    });
    assert_eq!(m.conn_accepted.load(Ordering::SeqCst), CONNS as u64);

    // all 1024 connections held open, traffic on every one of them: v1 on
    // even connections, v2 on odd
    let outcomes: Vec<Result<()>> = std::thread::scope(|scope| {
        let images = &images;
        let digits = &digits;
        let mut handles = Vec::new();
        for (chunk_idx, chunk) in clients.chunks_mut(CONNS / 8).enumerate() {
            handles.push(scope.spawn(move || -> Result<()> {
                for (j, client) in chunk.iter_mut().enumerate() {
                    let conn_idx = chunk_idx * (CONNS / 8) + j;
                    let img_idx = conn_idx % images.len();
                    if conn_idx % 2 == 0 {
                        let r = client.classify(&images[img_idx])?;
                        anyhow::ensure!(
                            u16::from(r.digit) == digits[img_idx],
                            "v1 digit {} ≠ {} on conn {conn_idx}",
                            r.digit,
                            digits[img_idx]
                        );
                    } else {
                        let item =
                            client.classify_v2(&images[img_idx], InferOptions::digits_only())?;
                        anyhow::ensure!(
                            item.digit == digits[img_idx],
                            "v2 digit {} ≠ {} on conn {conn_idx}",
                            item.digit,
                            digits[img_idx]
                        );
                    }
                }
                Ok(())
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for o in outcomes {
        o.unwrap();
    }

    // still all open (nothing timed out or died under load)
    assert_eq!(m.conn_open.load(Ordering::SeqCst), CONNS as u64);
    assert!(m.conn_books_balance(), "gauge books must balance under load");
    assert!(server.served.load(Ordering::Relaxed) >= CONNS as u64);

    drop(clients);
    wait_until("all connections torn down", Duration::from_secs(30), || {
        m.conn_open.load(Ordering::SeqCst) == 0
    });
    assert_eq!(m.conn_accepted.load(Ordering::SeqCst), CONNS as u64);
    assert_eq!(m.conn_closed.load(Ordering::SeqCst), CONNS as u64);
    assert!(m.conn_books_balance());
    server.shutdown();
}

// ---------------------------------------------------------------------------
// connection cap

/// Open `n_conns` sockets against a server capped at `cap`; the excess must
/// get a typed Overloaded v1 error frame then EOF, the rest stay open
/// silently.  Returns after asserting the gauge books.
fn assert_conn_cap(addr: std::net::SocketAddr, metrics: &Arc<Metrics>, cap: u64, n_conns: u64) {
    let mut streams = Vec::new();
    for _ in 0..n_conns {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(400))).unwrap();
        streams.push(s);
        std::thread::sleep(Duration::from_millis(5));
    }
    wait_until("accept loop to process every connection", Duration::from_secs(10), || {
        metrics.conn_accepted.load(Ordering::SeqCst) == n_conns
    });
    let mut rejected = 0u64;
    let mut admitted = 0u64;
    for s in &mut streams {
        let mut frame = [0u8; 7];
        match s.read_exact(&mut frame) {
            Ok(()) => {
                assert_eq!(frame[0], MAGIC_ERR);
                assert_eq!(WireStatus::from_u8(frame[1]), WireStatus::Overloaded);
                rejected += 1;
            }
            Err(e) => {
                // admitted connections say nothing until spoken to
                assert!(
                    matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ),
                    "unexpected read error: {e}"
                );
                admitted += 1;
            }
        }
    }
    assert_eq!(admitted, cap, "exactly `cap` connections admitted");
    assert_eq!(rejected, n_conns - cap, "the excess got typed Overloaded");
    assert_eq!(metrics.conn_open.load(Ordering::SeqCst), cap);
    assert_eq!(metrics.conn_closed.load(Ordering::SeqCst), n_conns - cap);
    assert!(metrics.conn_books_balance());

    drop(streams);
    wait_until("admitted connections to close", Duration::from_secs(10), || {
        metrics.conn_open.load(Ordering::SeqCst) == 0
    });
    assert_eq!(metrics.conn_closed.load(Ordering::SeqCst), n_conns);
    assert!(metrics.conn_books_balance());
}

#[test]
fn connection_cap_rejects_excess_with_typed_status_async() {
    let (_, engine) = engine_784(44);
    let cfg = WireServerConfig {
        max_conns: 8,
        idle_timeout: Duration::from_secs(60),
    };
    let server = AsyncWireServer::start_with("127.0.0.1:0", engine, cfg).unwrap();
    assert_conn_cap(server.addr, server.metrics(), 8, 11);
    server.shutdown();
}

#[test]
fn connection_cap_rejects_excess_with_typed_status_blocking() {
    let (_, engine) = engine_784(45);
    let cfg = WireServerConfig {
        max_conns: 3,
        idle_timeout: Duration::from_secs(60),
    };
    let server = WireServer::start_with("127.0.0.1:0", engine, cfg).unwrap();
    assert_conn_cap(server.addr, server.metrics(), 3, 5);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// overload: queue-full rejections keep the ledger balanced

/// A backend that blocks every batch on a gate until the test opens it —
/// lets the test wedge the engine queue deterministically.
struct GateBackend {
    open: Mutex<bool>,
    cv: Condvar,
}

impl GateBackend {
    fn new() -> Arc<Self> {
        Arc::new(GateBackend {
            open: Mutex::new(false),
            cv: Condvar::new(),
        })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

impl InferBackend for GateBackend {
    fn name(&self) -> &'static str {
        "gate"
    }

    fn max_batch(&self) -> usize {
        16
    }

    fn expected_bits(&self) -> Option<usize> {
        Some(784)
    }

    fn infer_batch(
        &self,
        images: &[&Packed],
        _scratch: &mut InferScratch,
        out: &mut LogitsBuf,
    ) -> Result<()> {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
        drop(open);
        out.reset(images.len(), 10); // all-zero logits ⇒ digit 0
        Ok(())
    }
}

#[test]
fn queue_full_surfaces_as_overloaded_and_ledger_balances() {
    let gate = GateBackend::new();
    let engine = Arc::new(
        Engine::builder()
            .shared(gate.clone())
            .workers(1)
            .queue_cap(4)
            .build()
            .unwrap(),
    );
    let metrics = engine.metrics().clone();
    let server = AsyncWireServer::start("127.0.0.1:0", engine).unwrap();

    let mut rng = Xoshiro256::new(3);
    let img = rand_image(&mut rng, 784);
    let frame = encode_request(&img).unwrap();

    const N: u64 = 24;
    let mut s = TcpStream::connect(server.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    // fire all N v1 frames without reading: the gated worker wedges, the
    // queue fills to its cap of 4, and the rest must be shed as Overloaded
    for _ in 0..N {
        s.write_all(&frame).unwrap();
    }
    // every request reaches its ledger verdict (submitted counts rejected
    // submits too) before the gate opens, so shedding really happened
    wait_until("all submits to reach the engine", Duration::from_secs(15), || {
        metrics.submitted.load(Ordering::Relaxed) == N
    });
    assert!(
        metrics.rejected.load(Ordering::Relaxed) > 0,
        "the wedged queue must have shed load"
    );
    gate.release();

    let mut ok = 0u64;
    let mut overloaded = 0u64;
    for i in 0..N {
        let mut resp = [0u8; 7];
        s.read_exact(&mut resp).unwrap();
        match resp[0] {
            MAGIC_RESP => {
                assert_eq!(resp[1], 0, "gate backend always answers digit 0");
                ok += 1;
            }
            MAGIC_ERR => {
                assert_eq!(
                    WireStatus::from_u8(resp[1]),
                    WireStatus::Overloaded,
                    "shed requests must carry the typed overload status (frame {i})"
                );
                overloaded += 1;
            }
            m => panic!("bad response magic {m:#x}"),
        }
    }
    assert_eq!(ok + overloaded, N);
    assert!(ok > 0, "the in-flight batch and queued requests complete");
    assert!(overloaded > 0, "some requests must have been shed");

    // the ledger invariant under overload, end to end through the wire
    let submitted = metrics.submitted.load(Ordering::Relaxed);
    let completed = metrics.completed.load(Ordering::Relaxed);
    let rejected = metrics.rejected.load(Ordering::Relaxed);
    assert_eq!(submitted, N);
    assert_eq!(completed, ok);
    assert_eq!(rejected, overloaded);
    assert_eq!(
        submitted,
        completed + rejected,
        "submitted == completed + rejected must hold under queue-full shedding"
    );
    assert_eq!(
        metrics.cancelled.load(Ordering::Relaxed),
        0,
        "server-side slots never count as client cancels"
    );
    server.shutdown();
}

// ---------------------------------------------------------------------------
// slow-loris

#[test]
fn slow_loris_dribble_does_not_stall_well_behaved_clients() {
    let (model, engine) = engine_784(46);
    let server = AsyncWireServer::start("127.0.0.1:0", engine).unwrap();

    const DRIBBLERS: usize = 64;
    let mut rng = Xoshiro256::new(5);
    let dribble_images: Vec<Packed> = (0..DRIBBLERS).map(|_| rand_image(&mut rng, 784)).collect();
    let dribble_frames: Vec<Vec<u8>> =
        dribble_images.iter().map(|i| encode_request(i).unwrap()).collect();
    let frame_len = dribble_frames[0].len(); // 101 bytes

    let mut dribble_streams: Vec<TcpStream> = (0..DRIBBLERS)
        .map(|_| {
            let s = TcpStream::connect(server.addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
            s
        })
        .collect();

    let good_images: Vec<Packed> = (0..8).map(|_| rand_image(&mut rng, 784)).collect();
    let good_digits: Vec<u16> =
        good_images.iter().map(|i| model.predict(&i.words) as u16).collect();

    let still_dribbling = AtomicBool::new(true);
    std::thread::scope(|scope| {
        // one thread feeds every dribbler a single byte per ~5 ms round:
        // 64 stalled half-frames occupy 64 event-loop slots for ~500 ms
        let streams = &mut dribble_streams;
        let frames = &dribble_frames;
        let flag = &still_dribbling;
        let dribbler = scope.spawn(move || {
            for byte_idx in 0..frame_len {
                for (s, f) in streams.iter_mut().zip(frames.iter()) {
                    s.write_all(&f[byte_idx..byte_idx + 1]).unwrap();
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            flag.store(false, Ordering::SeqCst);
        });

        // meanwhile, well-behaved clients must make normal progress
        let addr = server.addr;
        let good_images = &good_images;
        let good_digits = &good_digits;
        let flag = &still_dribbling;
        let mut goods = Vec::new();
        for t in 0..2 {
            goods.push(scope.spawn(move || {
                let mut client = WireClient::connect(addr).unwrap();
                for round in 0..25 {
                    let idx = (t + round) % good_images.len();
                    if round % 2 == 0 {
                        let r = client.classify(&good_images[idx]).unwrap();
                        assert_eq!(u16::from(r.digit), good_digits[idx]);
                    } else {
                        let item = client
                            .classify_v2(&good_images[idx], InferOptions::digits_only())
                            .unwrap();
                        assert_eq!(item.digit, good_digits[idx]);
                    }
                }
                // 50 round trips across 2 clients finish far inside the
                // ~500 ms dribble window — progress was truly concurrent
                assert!(
                    flag.load(Ordering::SeqCst),
                    "well-behaved clients should finish while the dribble is still running"
                );
            }));
        }
        for g in goods {
            g.join().unwrap();
        }
        dribbler.join().unwrap();
    });

    // the dribbled frames, though slow, were valid — every one gets its
    // correct answer (bit-identical digits to the model / blocking server)
    for (s, img) in dribble_streams.iter_mut().zip(dribble_images.iter()) {
        let mut resp = [0u8; 7];
        s.read_exact(&mut resp).unwrap();
        assert_eq!(resp[0], MAGIC_RESP);
        assert_eq!(resp[1] as usize, model.predict(&img.words));
    }
    server.shutdown();
}

// ---------------------------------------------------------------------------
// idle timeouts

/// Half-send a v1 frame, go silent, and expect the typed 7-byte timeout
/// frame followed by EOF.
fn assert_v1_idle_timeout(addr: std::net::SocketAddr) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&[bnn_fpga::coordinator::wire::MAGIC_REQ, 0x62]).unwrap(); // magic + half the length
    let mut frame = [0u8; 7];
    s.read_exact(&mut frame).unwrap();
    assert_eq!(frame[0], MAGIC_ERR);
    assert_eq!(WireStatus::from_u8(frame[1]), WireStatus::Timeout);
    let mut probe = [0u8; 1];
    assert_eq!(s.read(&mut probe).unwrap(), 0, "connection must close after the timeout");
}

/// Half-send a v2 header, go silent, and expect a v2 error frame with the
/// typed timeout status followed by EOF.
fn assert_v2_idle_timeout(addr: std::net::SocketAddr) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&[bnn_fpga::coordinator::wire::MAGIC_REQ_V2, 0, 0, 1, 2]).unwrap();
    let resp = read_response_v2(&mut s).unwrap();
    assert_eq!(resp.status, WireStatus::Timeout);
    assert_eq!(resp.id, 0, "the half-read header never yielded an id");
    assert!(resp.items.is_empty());
    let mut probe = [0u8; 1];
    assert_eq!(s.read(&mut probe).unwrap(), 0, "connection must close after the timeout");
}

#[test]
fn idle_timeout_surfaces_as_typed_status_async() {
    let (model, engine) = engine_784(47);
    let cfg = WireServerConfig {
        max_conns: 64,
        idle_timeout: Duration::from_millis(150),
    };
    let server = AsyncWireServer::start_with("127.0.0.1:0", engine, cfg).unwrap();
    assert_v1_idle_timeout(server.addr);
    assert_v2_idle_timeout(server.addr);

    // idleness *between* frames is free on the async server: connect, wait
    // well past the timeout, then serve a request normally
    let mut rng = Xoshiro256::new(11);
    let img = rand_image(&mut rng, 784);
    let mut client = WireClient::connect(server.addr).unwrap();
    std::thread::sleep(Duration::from_millis(400));
    let r = client.classify(&img).unwrap();
    assert_eq!(r.digit as usize, model.predict(&img.words));
    server.shutdown();
}

// ---------------------------------------------------------------------------
// server-side observability

#[test]
fn async_server_records_latency_and_queue_wait_histograms() {
    // the async server's own Metrics must carry real percentiles after
    // traffic — the event loop records each resolved slot's latency and
    // queue wait (they were silently empty before, so a dashboard reading
    // this server saw p50 = p99 = 0)
    let (model, engine) = engine_784(49);
    let server = AsyncWireServer::start("127.0.0.1:0", engine).unwrap();
    let mut client = WireClient::connect(server.addr).unwrap();
    let mut rng = Xoshiro256::new(13);
    const N: u64 = 24;
    for i in 0..N {
        let img = rand_image(&mut rng, 784);
        if i % 2 == 0 {
            let r = client.classify(&img).unwrap();
            assert_eq!(r.digit as usize, model.predict(&img.words));
        } else {
            let item = client.classify_v2(&img, InferOptions::digits_only()).unwrap();
            assert_eq!(item.digit as usize, model.predict(&img.words));
        }
    }
    let m = server.metrics();
    let lat = m.latency_snapshot();
    assert_eq!(lat.count(), N, "one latency sample per served request");
    assert!(lat.percentile_ns(50.0) > 0, "p50 must be non-zero after traffic");
    assert!(lat.percentile_ns(99.0) > 0, "p99 must be non-zero after traffic");
    assert!(lat.percentile_ns(99.0) >= lat.percentile_ns(50.0));
    let wait = m.queue_wait_snapshot();
    assert_eq!(wait.count(), N, "one queue-wait sample per served request");
    server.shutdown();
}

#[test]
fn idle_timeout_surfaces_as_typed_status_blocking() {
    let (_, engine) = engine_784(48);
    let cfg = WireServerConfig {
        max_conns: 64,
        idle_timeout: Duration::from_millis(150),
    };
    let server = WireServer::start_with("127.0.0.1:0", engine, cfg).unwrap();
    assert_v1_idle_timeout(server.addr);
    assert_v2_idle_timeout(server.addr);
    // the blocking server times out idle-between-frames connections too —
    // an idle connection pins a whole handler thread there, which is
    // exactly the resource the timeout reclaims
    let mut s = TcpStream::connect(server.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut frame = [0u8; 7];
    s.read_exact(&mut frame).unwrap();
    assert_eq!(frame[0], MAGIC_ERR);
    assert_eq!(WireStatus::from_u8(frame[1]), WireStatus::Timeout);
    server.shutdown();
}
