//! Simulator calibration against the paper's published Table 1 (executed,
//! not just the closed form) plus the §4.2.2 speedup narrative.

use bnn_fpga::data::Dataset;
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::load_model_or_synth;

/// Paper Table 1: (P, style, latency ns, speedup).
const TABLE1: [(usize, MemStyle, f64, f64); 13] = [
    (1, MemStyle::Bram, 1_096_045.0, 1.00),
    (1, MemStyle::Lut, 1_096_035.0, 1.00),
    (4, MemStyle::Bram, 274_465.0, 4.00),
    (4, MemStyle::Lut, 274_455.0, 4.00),
    (8, MemStyle::Bram, 137_645.0, 7.96),
    (8, MemStyle::Lut, 137_635.0, 7.96),
    (16, MemStyle::Bram, 68_905.0, 15.90),
    (16, MemStyle::Lut, 68_895.0, 15.90),
    (32, MemStyle::Bram, 34_865.0, 31.43),
    (32, MemStyle::Lut, 34_855.0, 31.45),
    (64, MemStyle::Bram, 17_845.0, 61.42),
    (64, MemStyle::Lut, 17_835.0, 61.45),
    (128, MemStyle::Lut, 9_865.0, 111.10),
];

// The FSM's cycle count is input- and weight-independent (asserted below),
// so calibration against the paper's Table 1 is valid on the synthetic
// fallback model too — these tests never require `make artifacts`.
fn setup() -> (bnn_fpga::bnn::BnnModel, Dataset) {
    let (model, ds, _trained) = load_model_or_synth(10);
    (model, ds)
}

#[test]
fn executed_latency_within_1_2_percent_of_paper() {
    let (model, ds) = setup();
    for (p, style, paper_ns, _) in TABLE1 {
        let mut acc = Accelerator::new(&model, SimConfig::new(p, style)).unwrap();
        let r = acc.run_image(&ds.images[0]);
        let err = (r.latency_ns - paper_ns).abs() / paper_ns;
        let tol = if p == 128 { 0.012 } else { 0.001 };
        assert!(
            err <= tol,
            "P={p} {style:?}: sim {} vs paper {paper_ns} ({:.3}%)",
            r.latency_ns,
            err * 100.0
        );
    }
}

#[test]
fn speedup_column_reproduces() {
    let (model, ds) = setup();
    let base = {
        let mut acc = Accelerator::new(&model, SimConfig::new(1, MemStyle::Bram)).unwrap();
        acc.run_image(&ds.images[0]).latency_ns
    };
    for (p, style, _, paper_speedup) in TABLE1 {
        let mut acc = Accelerator::new(&model, SimConfig::new(p, style)).unwrap();
        let s = base / acc.run_image(&ds.images[0]).latency_ns;
        assert!(
            (s - paper_speedup).abs() / paper_speedup < 0.015,
            "P={p} {style:?}: speedup {s:.2} vs paper {paper_speedup}"
        );
    }
}

#[test]
fn speedup_nonlinearity_narrative() {
    // §4.2.2: sub-linear speedup that worsens with P — 15.9 @16, ~61.4 @64,
    // ~111 @128 (vs ideal 16/64/128).
    let (model, ds) = setup();
    let lat = |p: usize, style| {
        let mut acc = Accelerator::new(&model, SimConfig::new(p, style)).unwrap();
        acc.run_image(&ds.images[0]).latency_ns
    };
    let base = lat(1, MemStyle::Bram);
    let eff = |p: usize, style| base / lat(p, style) / p as f64;
    assert!(eff(16, MemStyle::Bram) < 1.0);
    assert!(eff(64, MemStyle::Bram) < eff(16, MemStyle::Bram));
    assert!(eff(128, MemStyle::Lut) < eff(64, MemStyle::Lut));
    // but never catastropically so (>80 % efficiency everywhere)
    assert!(eff(128, MemStyle::Lut) > 0.8);
}

#[test]
fn latency_is_input_independent() {
    // a hardware FSM takes the same cycles regardless of pixel values
    let (model, ds) = setup();
    let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    let cycles: Vec<u64> = ds.images.iter().take(10).map(|i| acc.run_image(i).cycles).collect();
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "{cycles:?}");
}

#[test]
fn strict_80mhz_mode_scales_latency_only() {
    let (model, ds) = setup();
    let mut a = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    let mut b =
        Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram).strict_80mhz()).unwrap();
    let ra = a.run_image(&ds.images[0]);
    let rb = b.run_image(&ds.images[0]);
    assert_eq!(ra.cycles, rb.cycles);
    assert_eq!(ra.digit, rb.digit);
    assert!((rb.latency_ns / ra.latency_ns - 1.25).abs() < 1e-9);
}
