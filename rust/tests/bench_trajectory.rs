//! Bench-trajectory gate (ISSUE 6, satellite 4): the committed
//! `BENCH_hotpath.json` at the repo root must carry a row for **every**
//! kernel tier in `Kernel::registry()`, and every row must be a sane
//! measurement.  ROADMAP flagged the missing committed benchmark file as
//! an open gap; this test (driven by `make bench-check`) keeps the file
//! from silently going stale when a new tier lands — the const
//! exhaustiveness guard adds the tier to the registry, and this gate
//! then fails until `make bench-json` regenerates the rows.
//!
//! The file is produced by `cargo bench --bench hotpath` (see the
//! `record_kernel` helper there); rows are keyed `scalar`,
//! `blocked_b16`, `tiled_b16_t4`, ..., `fused_t4`, `pipelined_r8` — a
//! registry tier matches a row whose key is the tier name or starts
//! with `"{name}_"` (shape-parameter suffix).

use std::path::Path;

use bnn_fpga::coordinator::Kernel;
use bnn_fpga::util::json::Json;

fn bench_file() -> std::path::PathBuf {
    repo_root().join("BENCH_hotpath.json")
}

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
}

#[test]
fn committed_hotpath_bench_covers_every_registry_tier() {
    let path = bench_file();
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "{} is missing ({e}); run `make bench-json` to regenerate it \
             and commit the result",
            path.display()
        )
    });
    let doc = Json::parse(&text).expect("BENCH_hotpath.json is not valid JSON");
    assert_eq!(
        doc.get("bench").unwrap().as_str().unwrap(),
        "hotpath",
        "unexpected bench id"
    );
    let kernels = match doc.get("kernels").unwrap() {
        Json::Obj(m) => m,
        other => panic!("'kernels' must be an object, got {other:?}"),
    };
    assert!(!kernels.is_empty(), "'kernels' carries no rows");

    // every registered tier has at least one committed row
    for k in Kernel::registry() {
        let name = k.name();
        let prefix = format!("{name}_");
        assert!(
            kernels
                .keys()
                .any(|key| key == name || key.starts_with(&prefix)),
            "no BENCH_hotpath.json row for registry tier '{name}' \
             (rows: {:?}); run `make bench-json` and commit the result",
            kernels.keys().collect::<Vec<_>>()
        );
    }

    // every row is a positive, self-consistent measurement
    for (key, row) in kernels {
        let ns = row
            .get("ns_per_image")
            .and_then(Json::as_f64)
            .unwrap_or_else(|e| panic!("row '{key}': {e}"));
        let ips = row
            .get("images_per_sec")
            .and_then(Json::as_f64)
            .unwrap_or_else(|e| panic!("row '{key}': {e}"));
        assert!(ns > 0.0, "row '{key}': ns_per_image must be positive");
        assert!(ips > 0.0, "row '{key}': images_per_sec must be positive");
        let implied = 1e9 / ns;
        assert!(
            (ips - implied).abs() / implied < 0.01,
            "row '{key}': images_per_sec {ips} inconsistent with \
             ns_per_image {ns} (implies {implied})"
        );
    }
}

// ---------------------------------------------------------------------------
// serving trajectory (ISSUE 7): BENCH_serving.json schema gate

#[test]
fn committed_serving_bench_has_a_sane_latency_trajectory() {
    let path = repo_root().join("BENCH_serving.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "{} is missing ({e}); run `make bench-serving` to regenerate it \
             and commit the result",
            path.display()
        )
    });
    let doc = Json::parse(&text).expect("BENCH_serving.json is not valid JSON");
    assert_eq!(
        doc.get("bench").unwrap().as_str().unwrap(),
        "serving",
        "unexpected bench id"
    );
    assert_eq!(doc.get("server").unwrap().as_str().unwrap(), "async");
    let backend = doc.get("poll_backend").unwrap().as_str().unwrap();
    assert!(
        backend == "epoll" || backend == "poll",
        "unknown poll backend '{backend}'"
    );

    let rates = match doc.get("rates").unwrap() {
        Json::Obj(m) => m,
        other => panic!("'rates' must be an object, got {other:?}"),
    };
    assert!(!rates.is_empty(), "'rates' carries no ladder rungs");

    for (rate, row) in rates {
        let field = |name: &str| -> f64 {
            row.get(name)
                .and_then(Json::as_f64)
                .unwrap_or_else(|e| panic!("rate '{rate}': {e}"))
        };
        let offered = field("offered_ips");
        let achieved = field("achieved_ips");
        let sent = field("sent");
        let completed = field("completed");
        let errors = field("errors");
        assert!(offered > 0.0, "rate '{rate}': offered_ips must be positive");
        assert!(achieved > 0.0, "rate '{rate}': achieved_ips must be positive");
        assert!(sent >= 1.0, "rate '{rate}': no requests were sent");
        assert!(
            (completed + errors - sent).abs() < 0.5,
            "rate '{rate}': completed {completed} + errors {errors} ≠ sent {sent}"
        );

        // percentiles present, positive, and ordered
        let p50 = field("p50_us");
        let p99 = field("p99_us");
        let p999 = field("p999_us");
        let max = field("max_us");
        assert!(p50 > 0.0, "rate '{rate}': p50_us must be positive");
        assert!(
            p50 <= p99 && p99 <= p999 && p999 <= max,
            "rate '{rate}': percentiles out of order \
             (p50 {p50}, p99 {p99}, p999 {p999}, max {max}); \
             run `make bench-serving` to regenerate"
        );

        // error-latency stream (ISSUE 10): split from the success-only
        // percentiles; zero when the rung saw no errors, ordered otherwise
        let ep50 = field("err_p50_us");
        let ep99 = field("err_p99_us");
        let emax = field("err_max_us");
        if errors == 0.0 {
            assert_eq!(
                (ep50, ep99, emax),
                (0.0, 0.0, 0.0),
                "rate '{rate}': error percentiles must be zero with no errors"
            );
        } else {
            assert!(
                ep50 <= ep99 && ep99 <= emax && emax > 0.0,
                "rate '{rate}': error percentiles out of order \
                 (err_p50 {ep50}, err_p99 {ep99}, err_max {emax})"
            );
        }
    }

    // engine fault ledger (ISSUE 10): the committed artifact must carry
    // the engine's own books, and they must balance — a bench run that
    // crashed workers or shed deadlines shows it here
    let ledger = doc.get("ledger").expect("'ledger' object");
    let lfield = |name: &str| -> f64 {
        ledger
            .get(name)
            .and_then(Json::as_f64)
            .unwrap_or_else(|e| panic!("ledger: {e}"))
    };
    let submitted = lfield("submitted");
    let completed = lfield("completed");
    let rejected = lfield("rejected");
    let cancelled = lfield("cancelled");
    assert!(submitted >= 1.0, "ledger: bench submitted no requests");
    assert!(
        (completed + rejected + cancelled - submitted).abs() < 0.5,
        "ledger does not balance: submitted {submitted} ≠ completed \
         {completed} + rejected {rejected} + cancelled {cancelled}"
    );
    assert!(
        lfield("worker_restarts") >= 0.0 && lfield("deadline_expired") >= 0.0,
        "ledger: fault counters must be present"
    );

    let sustained = doc
        .get("max_sustained_ips")
        .and_then(Json::as_f64)
        .expect("max_sustained_ips");
    assert!(sustained > 0.0, "max_sustained_ips must be positive");
}
