//! Bench-trajectory gate (ISSUE 6, satellite 4): the committed
//! `BENCH_hotpath.json` at the repo root must carry a row for **every**
//! kernel tier in `Kernel::registry()`, and every row must be a sane
//! measurement.  ROADMAP flagged the missing committed benchmark file as
//! an open gap; this test (driven by `make bench-check`) keeps the file
//! from silently going stale when a new tier lands — the const
//! exhaustiveness guard adds the tier to the registry, and this gate
//! then fails until `make bench-json` regenerates the rows.
//!
//! The file is produced by `cargo bench --bench hotpath` (see the
//! `record_kernel` helper there); rows are keyed `scalar`,
//! `blocked_b16`, `tiled_b16_t4`, ..., `fused_t4`, `pipelined_r8` — a
//! registry tier matches a row whose key is the tier name or starts
//! with `"{name}_"` (shape-parameter suffix).

use std::path::Path;

use bnn_fpga::coordinator::Kernel;
use bnn_fpga::util::json::Json;

fn bench_file() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_hotpath.json")
}

#[test]
fn committed_hotpath_bench_covers_every_registry_tier() {
    let path = bench_file();
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "{} is missing ({e}); run `make bench-json` to regenerate it \
             and commit the result",
            path.display()
        )
    });
    let doc = Json::parse(&text).expect("BENCH_hotpath.json is not valid JSON");
    assert_eq!(
        doc.get("bench").unwrap().as_str().unwrap(),
        "hotpath",
        "unexpected bench id"
    );
    let kernels = match doc.get("kernels").unwrap() {
        Json::Obj(m) => m,
        other => panic!("'kernels' must be an object, got {other:?}"),
    };
    assert!(!kernels.is_empty(), "'kernels' carries no rows");

    // every registered tier has at least one committed row
    for k in Kernel::registry() {
        let name = k.name();
        let prefix = format!("{name}_");
        assert!(
            kernels
                .keys()
                .any(|key| key == name || key.starts_with(&prefix)),
            "no BENCH_hotpath.json row for registry tier '{name}' \
             (rows: {:?}); run `make bench-json` and commit the result",
            kernels.keys().collect::<Vec<_>>()
        );
    }

    // every row is a positive, self-consistent measurement
    for (key, row) in kernels {
        let ns = row
            .get("ns_per_image")
            .and_then(Json::as_f64)
            .unwrap_or_else(|e| panic!("row '{key}': {e}"));
        let ips = row
            .get("images_per_sec")
            .and_then(Json::as_f64)
            .unwrap_or_else(|e| panic!("row '{key}': {e}"));
        assert!(ns > 0.0, "row '{key}': ns_per_image must be positive");
        assert!(ips > 0.0, "row '{key}': images_per_sec must be positive");
        let implied = 1e9 / ns;
        assert!(
            (ips - implied).abs() / implied < 0.01,
            "row '{key}': images_per_sec {ips} inconsistent with \
             ns_per_image {ns} (implies {implied})"
        );
    }
}
