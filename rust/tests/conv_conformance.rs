//! Conv-conformance suite (ISSUE 9): the binary-convolution subsystem
//! against committed golden vectors, an independent naive oracle, the
//! cycle-accurate simulator, the serving stack, and the estimate models.
//!
//! The contract mirrors `kernel_conformance.rs` for mixed conv→dense
//! models: **bit-identical logits** across every registered kernel tier,
//! the fpga-sim backend, and the wire-v2 serving path — all pinned to
//! `tests/golden/conv_golden_vectors.json`, whose committed values went
//! through the Python generator's *naive* nested-loop conv (the packed
//! im2col lowering under test never touched them).  The differential
//! fuzz here re-derives that independence in Rust: a from-scratch ±1
//! oracle with explicit bounds checks vs the im2col-to-packed-words
//! lowering, over kernel {1,3,5} × stride {1,2} × pad {0,1} and channel
//! counts off the 64-bit word grid.
//!
//! The CI kernel-conformance matrix runs `conv_layers_are_golden_conformant`
//! by name in both `BNN_FORCE_SCALAR` legs, so the vectorized and portable
//! conv paths are each provably exercised.

mod common;

use std::sync::Arc;
use std::time::Duration;

use bnn_fpga::bnn::packing::{pack_bits_u64, unpack_bits_u64};
use bnn_fpga::bnn::{BinaryConvLayer, BnnModel, Packed};
use bnn_fpga::coordinator::wire::WireServer;
use bnn_fpga::coordinator::{
    BatcherConfig, Engine, InferBackend, InferOptions, Kernel, NativeBackend, SimBackend,
    WireClient,
};
use bnn_fpga::estimate::{power, resources, timing};
use bnn_fpga::sim::{analytic_steps_model, conv_front_steps, MemStyle, SimConfig};
use bnn_fpga::util::prng::Xoshiro256;

/// Conv golden gate #1: the committed logits are exactly what the scalar
/// semantics reference (packed im2col lowering + dense scalar walk)
/// computes from the pinned seeds.  The fixture side came from the naive
/// Python conv, so agreement here is already a cross-implementation
/// check, not a tautology.
#[test]
fn conv_golden_fixture_matches_scalar_reference() {
    let golden = common::load_conv_golden_logits();
    for (spec, want) in common::CONV_CASES.iter().zip(&golden) {
        let got = spec.scalar_logits();
        assert_eq!(
            &got, want,
            "{}: scalar reference drifted from the committed conv golden vectors",
            spec.name
        );
    }
}

/// Conv golden gate #2 (CI-pinned by name): every registered kernel tier
/// reproduces the committed conv logits exactly, through the same backend
/// path serving uses — plus the fused tier at panel-straddling tile
/// widths and the pipelined tier from lockstep to buffered rings.
#[test]
fn conv_layers_are_golden_conformant() {
    let golden = common::load_conv_golden_logits();
    for (spec, want) in common::CONV_CASES.iter().zip(&golden) {
        let model = spec.model();
        let inputs = spec.inputs();
        // the full registry at a default-ish and a deliberately awkward
        // (block, tile) shape, then the two prepared tiers at extra
        // shapes of their own
        let mut kernels: Vec<Kernel> = Vec::new();
        for (block, tile) in [(16usize, 8usize), (3, 2)] {
            kernels.extend(Kernel::registry_with(block, tile));
        }
        kernels.extend([1usize, 3, 8].map(|tile_imgs| Kernel::Fused { tile_imgs }));
        kernels.extend([1usize, 4].map(|ring_cap| Kernel::Pipelined { ring_cap }));
        for kernel in kernels {
            let backend = NativeBackend::with_kernel(model.clone(), kernel);
            assert_eq!(
                &backend.infer_logits(&inputs).unwrap(),
                want,
                "{}: kernel {kernel:?} diverged from the conv golden vectors",
                spec.name
            );
        }
    }
}

/// Conv golden gate #3: the cycle-accurate FPGA simulator — which runs
/// its own u8-level window gather, never the packed im2col path —
/// reproduces the committed conv logits at both ends of the parallelism
/// sweep and both memory styles.
#[test]
fn conv_fpga_sim_reproduces_golden_vectors() {
    let golden = common::load_conv_golden_logits();
    for (spec, want) in common::CONV_CASES.iter().zip(&golden) {
        let model = spec.model();
        for cfg in [
            SimConfig::new(64, MemStyle::Bram),
            SimConfig::new(16, MemStyle::Lut),
        ] {
            let sim = SimBackend::new(&model, cfg).unwrap();
            let got = sim.infer_logits(&spec.inputs()).unwrap();
            assert_eq!(
                &got, want,
                "{}: fpga-sim (P={}, {:?}) diverged from the conv golden vectors",
                spec.name, cfg.parallelism, cfg.mem_style
            );
        }
    }
}

/// The committed conv fixture is byte-for-byte the canonical
/// serialization of the current reference — catches a stale fixture or a
/// Python/Rust writer divergence even when the logits still match.
#[test]
fn conv_fixture_file_is_canonical() {
    let logits: Vec<_> = common::CONV_CASES.iter().map(|s| s.scalar_logits()).collect();
    let want = common::conv_fixture_text(&logits);
    let got = std::fs::read_to_string(common::conv_golden_path()).expect("fixture readable");
    assert_eq!(
        got, want,
        "conv_golden_vectors.json is stale or non-canonical; regenerate with \
         `cargo test --release --test conv_conformance regenerate -- --ignored`"
    );
}

/// The regeneration path: rewrite the conv fixture from the scalar
/// reference.  Ignored so it only runs deliberately:
/// `cargo test --release --test conv_conformance regenerate -- --ignored`
#[test]
#[ignore = "rewrites tests/golden/conv_golden_vectors.json from the scalar reference"]
fn regenerate_conv_golden_vectors() {
    let logits: Vec<_> = common::CONV_CASES.iter().map(|s| s.scalar_logits()).collect();
    let text = common::conv_fixture_text(&logits);
    let path = common::conv_golden_path();
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(&path, &text).unwrap();
    assert_eq!(common::load_conv_golden_logits(), logits);
    eprintln!("regenerated {}", path.display());
}

/// From-scratch naive oracle: nested loops over ±1 values with explicit
/// bounds checks (out-of-image pixels contribute −1, the packed layout's
/// zero bit), sign activation at the layer threshold.  Shares *nothing*
/// with the im2col lowering beyond the layer's weight storage.
fn naive_conv_bits(layer: &BinaryConvLayer, x_bits: &[u8]) -> Vec<u8> {
    let (ci, h, w) = (layer.in_ch, layer.in_h, layer.in_w);
    let (k, s, p) = (layer.kernel, layer.stride as isize, layer.pad as isize);
    let thr = layer.core.thresholds.as_ref().expect("conv thresholds");
    let weight = |co: usize, bit: usize| -> i32 {
        let row = layer.core.row(co);
        if (row[bit / 64] >> (bit % 64)) & 1 == 1 {
            1
        } else {
            -1
        }
    };
    let mut out = Vec::with_capacity(layer.out_bits());
    for oy in 0..layer.out_h() {
        for ox in 0..layer.out_w() {
            for co in 0..layer.out_ch() {
                let mut z = 0i32;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = oy as isize * s - p + ky as isize;
                        let ix = ox as isize * s - p + kx as isize;
                        for c in 0..ci {
                            let xv = if iy >= 0
                                && iy < h as isize
                                && ix >= 0
                                && ix < w as isize
                                && x_bits[(iy as usize * w + ix as usize) * ci + c] == 1
                            {
                                1i32
                            } else {
                                -1
                            };
                            z += xv * weight(co, (ky * k + kx) * ci + c);
                        }
                    }
                }
                out.push(u8::from(z >= thr[co]));
            }
        }
    }
    out
}

/// Differential fuzz: the packed im2col lowering (whole-model `logits`
/// plus a batched kernel tier) vs the naive oracle chained into a
/// dense-only twin of the model, over kernel {1,3,5} × stride {1,2} ×
/// pad {0,1} (pad < kernel — the library rejects the rest) × channel
/// counts off the 64-bit word grid, with *random non-zero thresholds*
/// patched in so the sign activation is fuzzed too.
#[test]
fn conv_im2col_vs_naive_differential_fuzz() {
    let mut rng = Xoshiro256::new(0xD1FF);
    for k in [1usize, 3, 5] {
        for s in [1usize, 2] {
            for p in [0usize, 1] {
                if p >= k {
                    continue;
                }
                for (ci, co) in [(1usize, 5usize), (3, 7), (2, 66)] {
                    let h = k.max(5) + 1;
                    let mut model = bnn_fpga::bnn::random_conv_model(
                        (ci, h, h),
                        &[(co, k, s, p)],
                        &[17, 5],
                        rng.next_u64(),
                    );
                    // random thresholds in (−patch_bits, patch_bits)
                    let pb = model.conv[0].patch_bits() as i64;
                    let thr: Vec<i32> =
                        (0..co).map(|_| rng.range_i64(-pb, pb) as i32).collect();
                    model.conv[0].core.thresholds = Some(thr);
                    model.validate().unwrap();

                    let images: Vec<Packed> = common::random_images(&mut rng, model.n_in(), 3);
                    // naive pipeline: oracle conv bits → dense-only twin
                    let dense_twin = BnnModel::dense(model.layers.clone());
                    let want: Vec<Vec<i32>> = images
                        .iter()
                        .map(|img| {
                            let bits = unpack_bits_u64(&img.words, model.n_in());
                            let conv_out = naive_conv_bits(&model.conv[0], &bits);
                            dense_twin.logits(&pack_bits_u64(&conv_out))
                        })
                        .collect();
                    // packed im2col lowering: scalar walk per image…
                    let got: Vec<Vec<i32>> =
                        images.iter().map(|img| model.logits(&img.words)).collect();
                    assert_eq!(got, want, "scalar: k={k} s={s} p={p} ci={ci} co={co}");
                    // …and one batched prepared tier over the same images
                    let backend = NativeBackend::with_kernel(
                        model.clone(),
                        Kernel::Fused { tile_imgs: 2 },
                    );
                    assert_eq!(
                        backend.infer_logits(&images).unwrap(),
                        want,
                        "fused: k={k} s={s} p={p} ci={ci} co={co}"
                    );
                }
            }
        }
    }
}

/// End-to-end serve test: a conv model behind the batching engine and the
/// wire-v2 server returns the same digits and logits the model computes
/// locally — format v2 models are first-class citizens of the serving
/// stack, not just the library walks.
#[test]
fn conv_model_serves_end_to_end_over_wire_v2() {
    let spec = &common::CONV_CASES[0]; // 1×28×28 → the wire's native 784 bits
    let model = spec.model();
    let engine = Arc::new(
        Engine::builder()
            .native(&model)
            .kernel(Kernel::default())
            .workers(2)
            .batcher(BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .unwrap(),
    );
    let server = WireServer::start("127.0.0.1:0", engine).unwrap();
    let mut client = WireClient::connect(server.addr).unwrap();
    let opts = InferOptions::default().with_logits(true);
    for (i, img) in spec.inputs().iter().enumerate() {
        let item = client.classify_v2(img, opts).unwrap();
        assert_eq!(item.digit as usize, model.predict(&img.words), "image {i}");
        assert_eq!(item.logits, model.logits(&img.words), "image {i}");
    }
    server.shutdown();
}

/// The estimate stack covers conv topologies end to end: LUT/FF/BRAM
/// numbers from the resource model, slack from the timing model, watts
/// from the power model, and cycle counts from the analytic formula —
/// all finite, non-degenerate, and strictly above the dense-only
/// baseline where the conv front adds real work.
#[test]
fn estimate_stack_reports_conv_topology_numbers() {
    let model = common::CONV_CASES[0].model();
    for style in [MemStyle::Bram, MemStyle::Lut] {
        let r = resources::estimate_model(&model, 64, style);
        assert!(r.luts > 0 && r.flip_flops > 0, "{style:?}: {r:?}");
        if style == MemStyle::Bram {
            assert!(r.bram_blocks > 0, "{r:?}");
        }
        let t = timing::estimate_model(&model, 64, style);
        assert!(t.meets_80mhz, "{style:?}: WNS {}", t.wns_ns);
        let cfg = SimConfig::new(64, style);
        let w = power::estimate_model(&model, &cfg);
        assert!(w.total_w > 0.0 && w.total_w.is_finite(), "{style:?}: {w:?}");
        // cycles: the conv front adds steps on top of the dense walk
        let steps = analytic_steps_model(&model, 64, style);
        let front = conv_front_steps(&model, 64);
        assert!(front > 0 && steps > front, "front {front}, total {steps}");
    }
}
