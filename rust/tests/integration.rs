//! Cross-layer integration tests.
//!
//! The central faithfulness claim: the inference paths — scalar native,
//! blocked native, the cycle-accurate FPGA simulator, and the
//! PJRT-compiled Pallas/JAX artifacts — produce **identical logits**, and
//! the `.mem` hardware export is equivalent to the JSON export.
//!
//! Kernel/sim equivalence only depends on layer dimensions, so those tests
//! run on a deterministic random model with no artifacts.  Tests that need
//! the *trained* model (accuracy bands, export equivalence, PJRT) skip with
//! a note when `make artifacts` has not been run.

use std::path::PathBuf;
use std::sync::Arc;

use bnn_fpga::bnn::model::random_model;
use bnn_fpga::bnn::packing::pack_bits_u64;
use bnn_fpga::coordinator::{InferBackend, Kernel, NativeBackend, PjrtBackend, SimBackend};
use bnn_fpga::data::Dataset;
use bnn_fpga::runtime::Engine;
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::prng::Xoshiro256;
use bnn_fpga::{artifacts_dir, mem, BNN_DIMS};

/// `Some(dir)` when the trained artifacts exist, else `None` (test skips).
fn artifacts_or_skip(test: &str) -> Option<PathBuf> {
    let dir = artifacts_dir();
    if dir.join("weights.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping {test}: no artifacts (run `make artifacts` for full coverage)");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_or_skip(concat!(file!(), ":", line!())) {
            Some(dir) => dir,
            None => return,
        }
    };
}

/// PJRT needs a real `xla` runtime on top of the artifacts; with the
/// vendored stub `Engine::load` fails, and the test skips rather than
/// panics (see DESIGN.md §Substitutions).
macro_rules! require_engine {
    ($dir:expr) => {
        match Engine::load($dir) {
            Ok(e) => Arc::new(e),
            Err(e) => {
                eprintln!("skipping {}:{}: {e:#}", file!(), line!());
                return;
            }
        }
    };
}

/// Acceptance gate for the blocked kernel: on the paper's 784-128-64-10
/// network, blocked-kernel logits are bit-identical to the scalar path AND
/// to the cycle-accurate simulator, for every parallelism style and a
/// sweep of block sizes.  Needs no artifacts — equivalence is
/// dimension-dependent only.
#[test]
fn blocked_scalar_and_sim_logits_are_bit_identical() {
    let model = random_model(&BNN_DIMS, 2025);
    let mut rng = Xoshiro256::new(4242);
    let images: Vec<Vec<u64>> = (0..8)
        .map(|_| {
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            pack_bits_u64(&bits)
        })
        .collect();

    let mut sim = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    for (i, x) in images.iter().enumerate() {
        let scalar = model.logits(x);
        for block in [1, 4, 16, 64, 128] {
            assert_eq!(
                model.logits_blocked(x, block),
                scalar,
                "image {i}, block {block}: blocked != scalar"
            );
        }
        let packed = bnn_fpga::bnn::Packed {
            words: x.clone(),
            n_bits: 784,
        };
        let r = sim.run_image(&packed);
        assert_eq!(r.scores, scalar, "image {i}: sim != scalar");
    }
}

/// Acceptance gate for the weight-stationary batch-tiled kernel
/// (ISSUE 2): on the paper's 784-128-64-10 network the tiled batch pass is
/// bit-identical to the per-image scalar reference AND to the
/// cycle-accurate simulator, across batch sizes and tile shapes.  Needs no
/// artifacts — equivalence is dimension-dependent only.
#[test]
fn tiled_scalar_and_sim_logits_are_bit_identical() {
    let model = random_model(&BNN_DIMS, 2027);
    let mut rng = Xoshiro256::new(4243);
    let mut sim = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    for batch in [1usize, 2, 7, 16] {
        let mut inputs: Vec<u64> = Vec::new();
        let mut images = Vec::new();
        for _ in 0..batch {
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            let words = pack_bits_u64(&bits);
            inputs.extend_from_slice(&words);
            images.push(bnn_fpga::bnn::Packed {
                words,
                n_bits: 784,
            });
        }
        // per-image scalar reference + simulator, flattened batch-major
        let mut scalar = Vec::new();
        for img in &images {
            let logits = model.logits(&img.words);
            let r = sim.run_image(img);
            assert_eq!(r.scores, logits, "sim != scalar (batch {batch})");
            scalar.extend(logits);
        }
        for (block, tile) in [(1usize, 1usize), (4, 2), (16, 8), (64, 3), (128, 16)] {
            assert_eq!(
                model.logits_batch_tiled(&inputs, batch, block, tile),
                scalar,
                "batch {batch}, block {block}, tile {tile}: tiled != scalar"
            );
        }
    }
}

/// The backend wrappers agree too: tiled, blocked and scalar
/// NativeBackends and the SimBackend produce identical batch outputs.
#[test]
fn all_native_kernels_and_sim_backends_agree() {
    let model = random_model(&BNN_DIMS, 2026);
    let mut rng = Xoshiro256::new(777);
    let images: Vec<bnn_fpga::bnn::Packed> = (0..6)
        .map(|_| {
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            bnn_fpga::bnn::Packed {
                words: pack_bits_u64(&bits),
                n_bits: 784,
            }
        })
        .collect();
    let scalar = NativeBackend::new(model.clone());
    let blocked = NativeBackend::with_block_rows(model.clone(), 16);
    let tiled = NativeBackend::with_kernel(model.clone(), Kernel::default());
    let sim = SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    let a = scalar.infer_logits(&images).unwrap();
    let b = blocked.infer_logits(&images).unwrap();
    let t = tiled.infer_logits(&images).unwrap();
    let c = sim.infer_logits(&images).unwrap();
    assert_eq!(a, b, "scalar vs blocked backend");
    assert_eq!(a, t, "scalar vs tiled backend");
    assert_eq!(a, c, "scalar vs fpga-sim backend");
}

#[test]
fn mem_export_equals_json_export() {
    let dir = require_artifacts!();
    let from_json = mem::load_model(&dir.join("weights.json")).unwrap();
    let from_mem =
        mem::weights::load_model_from_mem(&dir.join("mem"), &bnn_fpga::BNN_DIMS).unwrap();
    assert_eq!(from_json.layers.len(), from_mem.layers.len());
    for (a, b) in from_json.layers.iter().zip(from_mem.layers.iter()) {
        assert_eq!(a.weights, b.weights, "packed weights differ");
        assert_eq!(a.thresholds, b.thresholds, "thresholds differ");
    }
}

#[test]
fn sim_equals_native_on_full_subset() {
    let dir = require_artifacts!();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    for &p in &[1usize, 16, 64] {
        let mut acc = Accelerator::new(&model, SimConfig::new(p, MemStyle::Bram)).unwrap();
        for (i, img) in ds.images.iter().enumerate() {
            let r = acc.run_image(img);
            assert_eq!(r.scores, model.logits(&img.words), "P={p} image {i}");
        }
    }
}

#[test]
fn pjrt_equals_native_on_subset() {
    let dir = require_artifacts!();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    let engine = require_engine!(&dir);
    // batch-1 artifact
    for (i, img) in ds.images.iter().take(25).enumerate() {
        let pjrt = engine
            .run_u32_to_i32("bnn_b1", &img.to_u32_words())
            .unwrap();
        assert_eq!(pjrt, model.logits(&img.words), "image {i}");
    }
    // batched artifact: 16 at once
    let mut input = Vec::new();
    for img in ds.images.iter().take(16) {
        input.extend(img.to_u32_words());
    }
    let out = engine.run_u32_to_i32("bnn_b16", &input).unwrap();
    for (i, img) in ds.images.iter().take(16).enumerate() {
        assert_eq!(&out[i * 10..(i + 1) * 10], model.logits(&img.words), "row {i}");
    }
}

#[test]
fn pjrt_backend_ladder_padding_is_invisible() {
    let dir = require_artifacts!();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    let backend = PjrtBackend::new(require_engine!(&dir)).unwrap();
    // 13 is not in the ladder → padded to 16; results must match native
    let images: Vec<_> = ds.images.iter().take(13).cloned().collect();
    let out = backend.infer_logits(&images).unwrap();
    assert_eq!(out.len(), 13);
    for (i, img) in images.iter().enumerate() {
        assert_eq!(out[i], model.logits(&img.words), "padded row {i}");
    }
}

#[test]
fn subset_accuracy_in_paper_band() {
    // §4.1: the paper reports 84/100; our synthetic-task model lands in the
    // high-80s/low-90s (EXPERIMENTS.md) — accept the band [0.75, 1.0].
    let dir = require_artifacts!();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    let correct = ds
        .images
        .iter()
        .zip(&ds.labels)
        .filter(|(img, &l)| model.predict(&img.words) == l as usize)
        .count();
    assert!(
        (75..=100).contains(&correct),
        "{correct}/100 outside the expected band"
    );
}

#[test]
fn full_test_set_accuracy_matches_train_log() {
    let dir = require_artifacts!();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let test = Dataset::load_idx_test(&dir.join("data")).unwrap();
    let correct = test
        .images
        .iter()
        .zip(&test.labels)
        .filter(|(img, &l)| model.predict(&img.words) == l as usize)
        .count();
    let acc = correct as f64 / test.len() as f64;
    // train_log.json's folded accuracy was measured through the Pallas
    // path in Python — the Rust path must agree within 1 %.
    let log = std::fs::read_to_string(dir.join("train_log.json")).unwrap();
    let parsed = bnn_fpga::util::json::Json::parse(&log).unwrap();
    let folded = parsed
        .get("bnn")
        .unwrap()
        .get("folded_accuracy")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(
        (acc - folded).abs() < 0.01,
        "rust {acc:.4} vs python folded {folded:.4}"
    );
}

#[test]
fn engine_rejects_malformed_inputs() {
    let dir = require_artifacts!();
    let engine = require_engine!(&dir);
    // wrong length
    assert!(engine.run_u32_to_i32("bnn_b1", &[0u32; 7]).is_err());
    // wrong dtype pairing
    assert!(engine.run_f32_to_f32("bnn_b1", &[0f32; 25]).is_err());
    // unknown artifact
    assert!(engine.run_u32_to_i32("bnn_b3", &[0u32; 75]).is_err());
}

#[test]
fn cnn_artifact_runs_and_is_confident() {
    let dir = require_artifacts!();
    let engine = require_engine!(&dir);
    let test = Dataset::load_idx_test(&dir.join("data")).unwrap();
    // CNN takes float pixels; reconstruct them from the idx file
    let (imgs, _, _) = mem::read_idx_images(&dir.join("data/t10k-images-idx3-ubyte")).unwrap();
    let mut correct = 0;
    let n = 50;
    for i in 0..n {
        let pixels: Vec<f32> = imgs[i].iter().map(|&p| p as f32 / 255.0).collect();
        let logits = engine.run_f32_to_f32("cnn_b1", &pixels).unwrap();
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        correct += (pred == test.labels[i] as usize) as usize;
    }
    assert!(correct >= 45, "CNN only {correct}/{n} — §4.6 expects ≈99 %");
}

#[test]
fn all_three_backends_agree_as_backends() {
    let dir = require_artifacts!();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    let images: Vec<_> = ds.images.iter().take(10).cloned().collect();

    let native = NativeBackend::with_kernel(model.clone(), Kernel::default());
    let sim = SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    let pjrt = PjrtBackend::new(require_engine!(&dir)).unwrap();

    let a = native.infer_logits(&images).unwrap();
    let b = sim.infer_logits(&images).unwrap();
    let c = pjrt.infer_logits(&images).unwrap();
    assert_eq!(a, b, "native vs fpga-sim");
    assert_eq!(a, c, "native vs pjrt");
}
