//! Cross-layer integration tests (require `make artifacts`).
//!
//! The central faithfulness claim: the three inference paths — native
//! bit-packed Rust, the cycle-accurate FPGA simulator, and the
//! PJRT-compiled Pallas/JAX artifacts — produce **identical logits** on
//! the trained model, and the `.mem` hardware export is equivalent to the
//! JSON export.

use std::path::PathBuf;
use std::sync::Arc;

use bnn_fpga::coordinator::{InferBackend, NativeBackend, PjrtBackend, SimBackend};
use bnn_fpga::data::Dataset;
use bnn_fpga::runtime::Engine;
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::{artifacts_dir, mem};

fn require_artifacts() -> PathBuf {
    let dir = artifacts_dir();
    assert!(
        dir.join("weights.json").exists(),
        "run `make artifacts` before `cargo test` (missing {})",
        dir.join("weights.json").display()
    );
    dir
}

#[test]
fn mem_export_equals_json_export() {
    let dir = require_artifacts();
    let from_json = mem::load_model(&dir.join("weights.json")).unwrap();
    let from_mem =
        mem::weights::load_model_from_mem(&dir.join("mem"), &bnn_fpga::BNN_DIMS).unwrap();
    assert_eq!(from_json.layers.len(), from_mem.layers.len());
    for (a, b) in from_json.layers.iter().zip(from_mem.layers.iter()) {
        assert_eq!(a.weights, b.weights, "packed weights differ");
        assert_eq!(a.thresholds, b.thresholds, "thresholds differ");
    }
}

#[test]
fn sim_equals_native_on_full_subset() {
    let dir = require_artifacts();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    for &p in &[1usize, 16, 64] {
        let mut acc = Accelerator::new(&model, SimConfig::new(p, MemStyle::Bram)).unwrap();
        for (i, img) in ds.images.iter().enumerate() {
            let r = acc.run_image(img);
            assert_eq!(r.scores, model.logits(&img.words), "P={p} image {i}");
        }
    }
}

#[test]
fn pjrt_equals_native_on_subset() {
    let dir = require_artifacts();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    let engine = Arc::new(Engine::load(&dir).unwrap());
    // batch-1 artifact
    for (i, img) in ds.images.iter().take(25).enumerate() {
        let pjrt = engine
            .run_u32_to_i32("bnn_b1", &img.to_u32_words())
            .unwrap();
        assert_eq!(pjrt, model.logits(&img.words), "image {i}");
    }
    // batched artifact: 16 at once
    let mut input = Vec::new();
    for img in ds.images.iter().take(16) {
        input.extend(img.to_u32_words());
    }
    let out = engine.run_u32_to_i32("bnn_b16", &input).unwrap();
    for (i, img) in ds.images.iter().take(16).enumerate() {
        assert_eq!(&out[i * 10..(i + 1) * 10], model.logits(&img.words), "row {i}");
    }
}

#[test]
fn pjrt_backend_ladder_padding_is_invisible() {
    let dir = require_artifacts();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    let backend = PjrtBackend::new(Arc::new(Engine::load(&dir).unwrap())).unwrap();
    // 13 is not in the ladder → padded to 16; results must match native
    let images: Vec<_> = ds.images.iter().take(13).cloned().collect();
    let out = backend.infer_batch(&images).unwrap();
    assert_eq!(out.len(), 13);
    for (i, img) in images.iter().enumerate() {
        assert_eq!(out[i], model.logits(&img.words), "padded row {i}");
    }
}

#[test]
fn subset_accuracy_in_paper_band() {
    // §4.1: the paper reports 84/100; our synthetic-task model lands in the
    // high-80s/low-90s (EXPERIMENTS.md) — accept the band [0.75, 1.0].
    let dir = require_artifacts();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    let correct = ds
        .images
        .iter()
        .zip(&ds.labels)
        .filter(|(img, &l)| model.predict(&img.words) == l as usize)
        .count();
    assert!(
        (75..=100).contains(&correct),
        "{correct}/100 outside the expected band"
    );
}

#[test]
fn full_test_set_accuracy_matches_train_log() {
    let dir = require_artifacts();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let test = Dataset::load_idx_test(&dir.join("data")).unwrap();
    let correct = test
        .images
        .iter()
        .zip(&test.labels)
        .filter(|(img, &l)| model.predict(&img.words) == l as usize)
        .count();
    let acc = correct as f64 / test.len() as f64;
    // train_log.json's folded accuracy was measured through the Pallas
    // path in Python — the Rust path must agree within 1 %.
    let log = std::fs::read_to_string(dir.join("train_log.json")).unwrap();
    let parsed = bnn_fpga::util::json::Json::parse(&log).unwrap();
    let folded = parsed
        .get("bnn")
        .unwrap()
        .get("folded_accuracy")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(
        (acc - folded).abs() < 0.01,
        "rust {acc:.4} vs python folded {folded:.4}"
    );
}

#[test]
fn engine_rejects_malformed_inputs() {
    let dir = require_artifacts();
    let engine = Engine::load(&dir).unwrap();
    // wrong length
    assert!(engine.run_u32_to_i32("bnn_b1", &[0u32; 7]).is_err());
    // wrong dtype pairing
    assert!(engine.run_f32_to_f32("bnn_b1", &[0f32; 25]).is_err());
    // unknown artifact
    assert!(engine.run_u32_to_i32("bnn_b3", &[0u32; 75]).is_err());
}

#[test]
fn cnn_artifact_runs_and_is_confident() {
    let dir = require_artifacts();
    let engine = Engine::load(&dir).unwrap();
    let test = Dataset::load_idx_test(&dir.join("data")).unwrap();
    // CNN takes float pixels; reconstruct them from the idx file
    let (imgs, _, _) = mem::read_idx_images(&dir.join("data/t10k-images-idx3-ubyte")).unwrap();
    let mut correct = 0;
    let n = 50;
    for i in 0..n {
        let pixels: Vec<f32> = imgs[i].iter().map(|&p| p as f32 / 255.0).collect();
        let logits = engine.run_f32_to_f32("cnn_b1", &pixels).unwrap();
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        correct += (pred == test.labels[i] as usize) as usize;
    }
    assert!(correct >= 45, "CNN only {correct}/{n} — §4.6 expects ≈99 %");
}

#[test]
fn all_three_backends_agree_as_backends() {
    let dir = require_artifacts();
    let model = mem::load_model(&dir.join("weights.json")).unwrap();
    let ds = Dataset::load_mem_subset(&dir.join("mem")).unwrap();
    let images: Vec<_> = ds.images.iter().take(10).cloned().collect();

    let native = NativeBackend::new(model.clone());
    let sim = SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    let pjrt = PjrtBackend::new(Arc::new(Engine::load(&dir).unwrap())).unwrap();

    let a = native.infer_batch(&images).unwrap();
    let b = sim.infer_batch(&images).unwrap();
    let c = pjrt.infer_batch(&images).unwrap();
    assert_eq!(a, b, "native vs fpga-sim");
    assert_eq!(a, c, "native vs pjrt");
}
