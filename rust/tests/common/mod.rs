//! Shared kernel-conformance harness for the integration test crates.
//!
//! One place defines (a) the golden-vector case specs — deterministic
//! synthetic models + input streams pinned by seed — and (b) the helpers
//! that rebuild them and load/serialize the committed fixture
//! (`tests/golden/golden_vectors.json`).  `kernel_conformance.rs` pulls
//! from here (any future test crate can `mod common;` the same way), so a
//! new golden case is wired into every golden gate at once; kernel
//! enumeration itself lives in `Kernel::registry` so library tests share
//! it too.
//!
//! Fixture provenance: authored by `python/tools/gen_golden_vectors.py`
//! (a line-for-line Python port of the PRNG + model builder + scalar
//! forward pass, usable without a Rust toolchain) and regenerable from
//! Rust via the ignored `regenerate_golden_vectors` test in
//! `kernel_conformance.rs`.  Both writers emit byte-identical JSON
//! (compact separators, sorted keys, trailing newline), which
//! `fixture_file_is_canonical` relies on.

#![allow(dead_code)] // consumers use different subsets of the helpers

use bnn_fpga::bnn::model::random_model;
use bnn_fpga::bnn::packing::pack_bits_u64;
use bnn_fpga::bnn::{random_conv_model, BnnModel, Packed};
use bnn_fpga::util::json::Json;
use bnn_fpga::util::prng::Xoshiro256;

/// One golden case: a fixed-seed synthetic model and input stream.
#[derive(Clone, Copy, Debug)]
pub struct CaseSpec {
    pub name: &'static str,
    pub dims: &'static [usize],
    pub model_seed: u64,
    pub input_seed: u64,
    pub n_inputs: usize,
}

/// The golden-vector case specs — keep in sync with `CASES` in
/// `python/tools/gen_golden_vectors.py`.  Widths deliberately cover the
/// paper network plus the word-boundary edges (65/63/37) and exact
/// multiples of 64; ~32 inputs total.
pub const CASES: [CaseSpec; 5] = [
    CaseSpec {
        name: "paper-784-128-64-10",
        dims: &[784, 128, 64, 10],
        model_seed: 2601,
        input_seed: 9001,
        n_inputs: 8,
    },
    CaseSpec {
        name: "edge-65-63-5-3",
        dims: &[65, 63, 5, 3],
        model_seed: 2602,
        input_seed: 9002,
        n_inputs: 8,
    },
    CaseSpec {
        name: "edge-37-19-11-3",
        dims: &[37, 19, 11, 3],
        model_seed: 2603,
        input_seed: 9003,
        n_inputs: 8,
    },
    CaseSpec {
        name: "aligned-128-64-10",
        dims: &[128, 64, 10],
        model_seed: 2604,
        input_seed: 9004,
        n_inputs: 4,
    },
    CaseSpec {
        name: "single-layer-64-10",
        dims: &[64, 10],
        model_seed: 2605,
        input_seed: 9005,
        n_inputs: 4,
    },
];

/// Absolute path of the committed fixture (CWD-independent).
pub fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/golden_vectors.json")
}

impl CaseSpec {
    /// Rebuild the case's deterministic model.
    pub fn model(&self) -> BnnModel {
        random_model(self.dims, self.model_seed)
    }

    /// Rebuild the case's input stream: `n_inputs` images drawn
    /// sequentially from one PRNG (the fixture's draw order).
    pub fn inputs(&self) -> Vec<Packed> {
        let mut rng = Xoshiro256::new(self.input_seed);
        let n_in = self.dims[0];
        (0..self.n_inputs)
            .map(|_| {
                let bits: Vec<u8> = (0..n_in).map(|_| rng.bool() as u8).collect();
                Packed {
                    words: pack_bits_u64(&bits),
                    n_bits: n_in,
                }
            })
            .collect()
    }

    /// Expected logits from the scalar semantics reference.
    pub fn scalar_logits(&self) -> Vec<Vec<i32>> {
        let model = self.model();
        self.inputs()
            .iter()
            .map(|img| model.logits(&img.words))
            .collect()
    }
}

/// Serialize all cases (with the given per-case logits, index-aligned with
/// [`CASES`]) into the canonical fixture document.
pub fn fixture_doc(logits_per_case: &[Vec<Vec<i32>>]) -> Json {
    assert_eq!(logits_per_case.len(), CASES.len());
    let cases: Vec<Json> = CASES
        .iter()
        .zip(logits_per_case)
        .map(|(spec, logits)| {
            let mut m = std::collections::BTreeMap::new();
            m.insert(
                "dims".to_string(),
                Json::Arr(spec.dims.iter().map(|&d| Json::from(d as u64)).collect()),
            );
            m.insert("input_seed".to_string(), Json::from(spec.input_seed));
            m.insert(
                "logits".to_string(),
                Json::Arr(
                    logits
                        .iter()
                        .map(|row| {
                            Json::Arr(row.iter().map(|&z| Json::from(z as f64)).collect())
                        })
                        .collect(),
                ),
            );
            m.insert("model_seed".to_string(), Json::from(spec.model_seed));
            m.insert("n_inputs".to_string(), Json::from(spec.n_inputs as u64));
            m.insert("name".to_string(), Json::from(spec.name));
            Json::Obj(m)
        })
        .collect();
    let mut doc = std::collections::BTreeMap::new();
    doc.insert("cases".to_string(), Json::Arr(cases));
    doc.insert(
        "generator".to_string(),
        Json::from("python/tools/gen_golden_vectors.py"),
    );
    doc.insert("version".to_string(), Json::from(1u64));
    Json::Obj(doc)
}

/// The canonical fixture file contents for the given logits.
pub fn fixture_text(logits_per_case: &[Vec<Vec<i32>>]) -> String {
    let mut s = fixture_doc(logits_per_case).to_string();
    s.push('\n');
    s
}

/// Load the committed fixture and return the expected logits per case,
/// index-aligned with [`CASES`] (validates names/dims/seeds against the
/// in-code specs so the two cannot drift apart silently).
pub fn load_golden_logits() -> Vec<Vec<Vec<i32>>> {
    let path = golden_path();
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "cannot read golden fixture {} ({e}); regenerate with \
             `cargo test --release --test kernel_conformance regenerate -- --ignored`",
            path.display()
        )
    });
    let doc = Json::parse(&text).expect("golden fixture parses");
    assert_eq!(doc.get("version").unwrap().as_u64().unwrap(), 1);
    let cases = doc.get("cases").unwrap().as_arr().unwrap();
    assert_eq!(cases.len(), CASES.len(), "fixture case count");
    cases
        .iter()
        .zip(&CASES)
        .map(|(case, spec)| {
            assert_eq!(case.get("name").unwrap().as_str().unwrap(), spec.name);
            let dims: Vec<usize> = case
                .get("dims")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect();
            assert_eq!(dims, spec.dims, "{}: dims drifted", spec.name);
            assert_eq!(
                case.get("model_seed").unwrap().as_u64().unwrap(),
                spec.model_seed,
                "{}: model_seed drifted",
                spec.name
            );
            assert_eq!(
                case.get("input_seed").unwrap().as_u64().unwrap(),
                spec.input_seed,
                "{}: input_seed drifted",
                spec.name
            );
            assert_eq!(
                case.get("n_inputs").unwrap().as_u64().unwrap() as usize,
                spec.n_inputs,
                "{}: n_inputs drifted",
                spec.name
            );
            case.get("logits")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|row| {
                    row.as_arr()
                        .unwrap()
                        .iter()
                        .map(|z| z.as_i64().unwrap() as i32)
                        .collect()
                })
                .collect()
        })
        .collect()
}

/// One conv golden case: a fixed-seed mixed conv→dense model and input
/// stream (fixture: `tests/golden/conv_golden_vectors.json`).
#[derive(Clone, Copy, Debug)]
pub struct ConvCaseSpec {
    pub name: &'static str,
    /// `(in_ch, in_h, in_w)`.
    pub in_shape: (usize, usize, usize),
    /// Per conv layer: `(out_ch, kernel, stride, pad)`.
    pub convs: &'static [(usize, usize, usize, usize)],
    pub dense: &'static [usize],
    pub model_seed: u64,
    pub input_seed: u64,
    pub n_inputs: usize,
}

/// The conv golden-vector case specs — keep in sync with `CONV_CASES` in
/// `python/tools/gen_golden_vectors.py`.  Geometries cover the MNIST
/// shape, stride 2, a two-conv chain with `C_in > 1`, and a 1×1 conv
/// whose 66 output channels straddle the 64-row panel boundary.
pub const CONV_CASES: [ConvCaseSpec; 4] = [
    ConvCaseSpec {
        name: "mnist-conv3x3-8ch",
        in_shape: (1, 28, 28),
        convs: &[(8, 3, 1, 1)],
        dense: &[64, 10],
        model_seed: 3601,
        input_seed: 9101,
        n_inputs: 4,
    },
    ConvCaseSpec {
        name: "conv5x5-stride2",
        in_shape: (1, 28, 28),
        convs: &[(6, 5, 2, 0)],
        dense: &[32, 10],
        model_seed: 3602,
        input_seed: 9102,
        n_inputs: 4,
    },
    ConvCaseSpec {
        name: "conv-stack-3ch",
        in_shape: (3, 9, 9),
        convs: &[(5, 3, 1, 1), (7, 3, 2, 0)],
        dense: &[33, 10],
        model_seed: 3603,
        input_seed: 9103,
        n_inputs: 4,
    },
    ConvCaseSpec {
        name: "conv1x1-panel-straddle",
        in_shape: (2, 6, 6),
        convs: &[(66, 1, 1, 0)],
        dense: &[17, 5],
        model_seed: 3604,
        input_seed: 9104,
        n_inputs: 4,
    },
];

/// Absolute path of the committed conv fixture (CWD-independent).
pub fn conv_golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/conv_golden_vectors.json")
}

impl ConvCaseSpec {
    /// Rebuild the case's deterministic mixed conv→dense model.
    pub fn model(&self) -> BnnModel {
        random_conv_model(self.in_shape, self.convs, self.dense, self.model_seed)
    }

    /// Image-level input width `C·H·W`.
    pub fn n_in(&self) -> usize {
        self.in_shape.0 * self.in_shape.1 * self.in_shape.2
    }

    /// Rebuild the case's input stream (the fixture's draw order).
    pub fn inputs(&self) -> Vec<Packed> {
        let mut rng = Xoshiro256::new(self.input_seed);
        random_images(&mut rng, self.n_in(), self.n_inputs)
    }

    /// Expected logits from the scalar semantics reference (the conv
    /// front lowers through the packed im2col path; the fixture's
    /// committed values went through the independent naive Python conv).
    pub fn scalar_logits(&self) -> Vec<Vec<i32>> {
        let model = self.model();
        self.inputs()
            .iter()
            .map(|img| model.logits(&img.words))
            .collect()
    }
}

/// Serialize all conv cases (with per-case logits, index-aligned with
/// [`CONV_CASES`]) into the canonical conv fixture document.
pub fn conv_fixture_doc(logits_per_case: &[Vec<Vec<i32>>]) -> Json {
    assert_eq!(logits_per_case.len(), CONV_CASES.len());
    let cases: Vec<Json> = CONV_CASES
        .iter()
        .zip(logits_per_case)
        .map(|(spec, logits)| {
            let mut m = std::collections::BTreeMap::new();
            m.insert(
                "convs".to_string(),
                Json::Arr(
                    spec.convs
                        .iter()
                        .map(|&(oc, k, s, p)| {
                            Json::Arr(
                                [oc, k, s, p].iter().map(|&v| Json::from(v as u64)).collect(),
                            )
                        })
                        .collect(),
                ),
            );
            m.insert(
                "dense".to_string(),
                Json::Arr(spec.dense.iter().map(|&d| Json::from(d as u64)).collect()),
            );
            let (c, h, w) = spec.in_shape;
            m.insert(
                "in_shape".to_string(),
                Json::Arr([c, h, w].iter().map(|&v| Json::from(v as u64)).collect()),
            );
            m.insert("input_seed".to_string(), Json::from(spec.input_seed));
            m.insert(
                "logits".to_string(),
                Json::Arr(
                    logits
                        .iter()
                        .map(|row| {
                            Json::Arr(row.iter().map(|&z| Json::from(z as f64)).collect())
                        })
                        .collect(),
                ),
            );
            m.insert("model_seed".to_string(), Json::from(spec.model_seed));
            m.insert("n_inputs".to_string(), Json::from(spec.n_inputs as u64));
            m.insert("name".to_string(), Json::from(spec.name));
            Json::Obj(m)
        })
        .collect();
    let mut doc = std::collections::BTreeMap::new();
    doc.insert("cases".to_string(), Json::Arr(cases));
    doc.insert(
        "generator".to_string(),
        Json::from("python/tools/gen_golden_vectors.py"),
    );
    doc.insert("version".to_string(), Json::from(1u64));
    Json::Obj(doc)
}

/// The canonical conv fixture file contents for the given logits.
pub fn conv_fixture_text(logits_per_case: &[Vec<Vec<i32>>]) -> String {
    let mut s = conv_fixture_doc(logits_per_case).to_string();
    s.push('\n');
    s
}

/// Load the committed conv fixture and return the expected logits per
/// case, index-aligned with [`CONV_CASES`] (validates names, geometries
/// and seeds against the in-code specs so the two cannot drift apart).
pub fn load_conv_golden_logits() -> Vec<Vec<Vec<i32>>> {
    let path = conv_golden_path();
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "cannot read conv golden fixture {} ({e}); regenerate with \
             `cargo test --release --test conv_conformance regenerate -- --ignored`",
            path.display()
        )
    });
    let doc = Json::parse(&text).expect("conv golden fixture parses");
    assert_eq!(doc.get("version").unwrap().as_u64().unwrap(), 1);
    let cases = doc.get("cases").unwrap().as_arr().unwrap();
    assert_eq!(cases.len(), CONV_CASES.len(), "conv fixture case count");
    cases
        .iter()
        .zip(&CONV_CASES)
        .map(|(case, spec)| {
            assert_eq!(case.get("name").unwrap().as_str().unwrap(), spec.name);
            let nums = |key: &str| -> Vec<usize> {
                case.get(key)
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect()
            };
            let (c, h, w) = spec.in_shape;
            assert_eq!(nums("in_shape"), vec![c, h, w], "{}: in_shape drifted", spec.name);
            assert_eq!(nums("dense"), spec.dense, "{}: dense dims drifted", spec.name);
            let convs: Vec<Vec<usize>> = case
                .get("convs")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|l| l.as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect())
                .collect();
            let want_convs: Vec<Vec<usize>> =
                spec.convs.iter().map(|&(oc, k, s, p)| vec![oc, k, s, p]).collect();
            assert_eq!(convs, want_convs, "{}: conv geometry drifted", spec.name);
            assert_eq!(
                case.get("model_seed").unwrap().as_u64().unwrap(),
                spec.model_seed,
                "{}: model_seed drifted",
                spec.name
            );
            assert_eq!(
                case.get("input_seed").unwrap().as_u64().unwrap(),
                spec.input_seed,
                "{}: input_seed drifted",
                spec.name
            );
            assert_eq!(
                case.get("n_inputs").unwrap().as_u64().unwrap() as usize,
                spec.n_inputs,
                "{}: n_inputs drifted",
                spec.name
            );
            case.get("logits")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|row| {
                    row.as_arr()
                        .unwrap()
                        .iter()
                        .map(|z| z.as_i64().unwrap() as i32)
                        .collect()
                })
                .collect()
        })
        .collect()
}

/// Random packed images of width `n_in`, drawn from one PRNG stream.
pub fn random_images(rng: &mut Xoshiro256, n_in: usize, count: usize) -> Vec<Packed> {
    (0..count)
        .map(|_| {
            let bits: Vec<u8> = (0..n_in).map(|_| rng.bool() as u8).collect();
            Packed {
                words: pack_bits_u64(&bits),
                n_bits: n_in,
            }
        })
        .collect()
}
