//! Wire-protocol conformance: v2 round-trip property tests over random
//! widths, v1↔v2 compatibility against one server, batch-vs-single
//! bit-identity, and malformed-frame fuzz asserting typed [`WireStatus`]
//! errors — never hangs.  Everything here runs artifact-free.

use std::io::{Cursor, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use bnn_fpga::bnn::model::random_model;
use bnn_fpga::bnn::Packed;
use bnn_fpga::coordinator::wire::{
    encode_error_v2, encode_request, encode_request_v2, encode_response_v2, payload_bytes,
    read_request_v2_body, read_response_v2, WireItem, WireServer, WireStatus, MAGIC_ERR,
    MAGIC_REQ_V2, MAX_WIRE_BATCH, MAX_WIRE_BITS, PAYLOAD_BYTES,
};
use bnn_fpga::coordinator::{BatcherConfig, Engine, InferOptions, Kernel, WireClient};
use bnn_fpga::util::prng::Xoshiro256;
use bnn_fpga::util::proptest_lite::{gens, Runner};

fn rand_image(rng: &mut Xoshiro256, n_bits: usize) -> Packed {
    let bits: Vec<u8> = (0..n_bits).map(|_| rng.bool() as u8).collect();
    Packed::from_bits(&bits)
}

/// The widths the acceptance gate names explicitly: the paper's 784 plus
/// the word-boundary edge cases.
const ACCEPTANCE_WIDTHS: [usize; 5] = [784, 65, 64, 63, 1];

// ---------------------------------------------------------------------------
// frame-level property tests (no sockets)

#[test]
fn v2_request_roundtrip_random_widths_and_batches() {
    Runner::new("wire-v2-request-roundtrip").cases(96).run(
        &gens::Pair(gens::U64(1..=1200), gens::Pair(gens::U64(1..=5), gens::U64(0..=u64::MAX / 2))),
        |&(n_bits, (n_images, seed))| {
            let mut rng = Xoshiro256::new(seed ^ 0xA5A5);
            let n_bits = n_bits as usize;
            let images: Vec<Packed> = (0..n_images).map(|_| rand_image(&mut rng, n_bits)).collect();
            let opts = InferOptions {
                include_logits: seed % 2 == 0,
                top_k: (seed % 3 == 0).then_some(1 + (seed % 10) as usize),
            };
            let id = seed.wrapping_mul(31);
            let frame = encode_request_v2(&images, id, opts).unwrap();
            if frame.len() != 17 + n_images as usize * payload_bytes(n_bits) {
                return false;
            }
            let mut cur = Cursor::new(&frame[1..]);
            let req = match read_request_v2_body(&mut cur) {
                Ok(r) => r,
                Err(_) => return false,
            };
            cur.position() as usize == frame.len() - 1
                && req.id == id
                && req.opts == opts
                && req.images.len() == images.len()
                && req
                    .images
                    .iter()
                    .zip(&images)
                    .all(|(a, b)| a.n_bits == b.n_bits && a.words == b.words)
        },
    );
}

#[test]
fn v2_request_roundtrip_acceptance_widths() {
    let mut rng = Xoshiro256::new(2026);
    for w in ACCEPTANCE_WIDTHS {
        let images: Vec<Packed> = (0..3).map(|_| rand_image(&mut rng, w)).collect();
        let frame = encode_request_v2(&images, 7, InferOptions::default()).unwrap();
        let req = read_request_v2_body(&mut Cursor::new(&frame[1..])).unwrap();
        for (a, b) in req.images.iter().zip(&images) {
            assert_eq!(a.n_bits, w);
            assert_eq!(a.words, b.words, "width {w}");
            assert_eq!(a.to_bits(), b.to_bits(), "width {w}");
        }
    }
}

#[test]
fn v2_response_roundtrip_random_payloads() {
    Runner::new("wire-v2-response-roundtrip").cases(96).run(
        &gens::Pair(gens::U64(0..=3), gens::U64(0..=u64::MAX / 2)),
        |&(n_items, seed)| {
            let mut rng = Xoshiro256::new(seed ^ 0x17);
            let with_logits = seed % 2 == 0;
            let with_topk = seed % 3 == 0;
            let mut features = 0u8;
            if with_logits {
                features |= bnn_fpga::coordinator::wire::FEAT_LOGITS;
            }
            if with_topk {
                features |= bnn_fpga::coordinator::wire::FEAT_TOPK;
            }
            let items: Vec<WireItem> = (0..n_items)
                .map(|i| WireItem {
                    id: seed.wrapping_add(i),
                    // the full u16 carrier, not just 0..10: digits > 255
                    // must survive the round trip since the u8 widening
                    digit: rng.below(5000) as u16,
                    latency_us: rng.below(1 << 30) as u32,
                    logits: if with_logits {
                        (0..10).map(|_| rng.below(1 << 16) as i32 - (1 << 15)).collect()
                    } else {
                        Vec::new()
                    },
                    top_k: if with_topk {
                        (0..3)
                            .map(|_| (rng.below(5000) as u16, rng.below(100) as i32))
                            .collect()
                    } else {
                        Vec::new()
                    },
                })
                .collect();
            let frame = match encode_response_v2(seed, WireStatus::Ok, features, 3, &items) {
                Ok(f) => f,
                Err(_) => return false,
            };
            let mut cur = Cursor::new(frame.as_slice());
            match read_response_v2(&mut cur) {
                Ok(resp) => {
                    cur.position() as usize == frame.len()
                        && resp.id == seed
                        && resp.status == WireStatus::Ok
                        && resp.items == items
                }
                Err(_) => false,
            }
        },
    );
}

#[test]
fn v2_error_frames_roundtrip_every_status() {
    for status in [
        WireStatus::BadMagic,
        WireStatus::BadLength,
        WireStatus::Backend,
        WireStatus::TooLarge,
        WireStatus::BadFeature,
    ] {
        let frame = encode_error_v2(123, status);
        let resp = read_response_v2(&mut Cursor::new(frame.as_slice())).unwrap();
        assert_eq!(resp.status, status);
        assert_eq!(resp.id, 123);
        assert!(resp.items.is_empty());
    }
    assert_eq!(WireStatus::from_u8(200), WireStatus::Unknown);
}

#[test]
fn v2_truncation_fuzz_every_cut_is_a_typed_error() {
    // every strict prefix of a valid request body must parse to a clean
    // BadLength — no panic, no garbage acceptance
    let mut rng = Xoshiro256::new(9);
    let images = vec![rand_image(&mut rng, 63), rand_image(&mut rng, 63)];
    let frame = encode_request_v2(&images, 11, InferOptions::default().with_top_k(2)).unwrap();
    let body = &frame[1..];
    for cut in 0..body.len() {
        let e = read_request_v2_body(&mut Cursor::new(&body[..cut])).unwrap_err();
        assert_eq!(e.status, WireStatus::BadLength, "cut {cut}: {e}");
    }
    // full body parses
    assert!(read_request_v2_body(&mut Cursor::new(body)).is_ok());
}

// ---------------------------------------------------------------------------
// live-server tests

fn engine_784() -> (bnn_fpga::bnn::BnnModel, Arc<Engine>) {
    let model = random_model(&[784, 128, 64, 10], 41);
    let engine = Arc::new(
        Engine::builder()
            .native(&model)
            .kernel(Kernel::default())
            .workers(2)
            .batcher(BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .unwrap(),
    );
    (model, engine)
}

#[test]
fn v1_client_still_classifies_against_the_v2_server() {
    let (model, engine) = engine_784();
    let server = WireServer::start("127.0.0.1:0", engine).unwrap();
    let mut client = WireClient::connect(server.addr).unwrap();
    let mut rng = Xoshiro256::new(50);
    for i in 0..6 {
        let img = rand_image(&mut rng, 784);
        let r = client.classify(&img).unwrap();
        assert_eq!(r.digit as usize, model.predict(&img.words), "image {i}");
        assert_eq!(r.status, 0);
    }
    assert_eq!(server.served.load(Ordering::Relaxed), 6);
    server.shutdown();
}

#[test]
fn batched_frame_matches_per_image_submission_bit_for_bit() {
    let (model, engine) = engine_784();
    let server = WireServer::start("127.0.0.1:0", engine).unwrap();
    let mut rng = Xoshiro256::new(51);
    let images: Vec<Packed> = (0..9).map(|_| rand_image(&mut rng, 784)).collect();
    let opts = InferOptions::default().with_top_k(3);

    // one batched frame on one connection…
    let mut batch_client = WireClient::connect(server.addr).unwrap();
    let batched = batch_client.classify_batch(&images, opts).unwrap();
    // …vs one frame per image, pipelined, on another
    let mut single_client = WireClient::connect(server.addr).unwrap();
    let singles = single_client.classify_pipelined(&images, opts).unwrap();

    assert_eq!(batched.len(), images.len());
    assert_eq!(singles.len(), images.len());
    let base = batched[0].id;
    for (i, ((b, s), img)) in batched.iter().zip(&singles).zip(&images).enumerate() {
        assert_eq!(b.id, base + i as u64, "batch ids are frame id + index");
        assert_eq!(b.digit, s.digit, "image {i}");
        assert_eq!(b.digit as usize, model.predict(&img.words), "image {i}");
        assert_eq!(b.logits, s.logits, "image {i}");
        assert_eq!(b.logits, model.logits(&img.words), "image {i}");
        assert_eq!(b.top_k, s.top_k, "image {i}");
        assert_eq!(b.top_k.len(), 3);
        assert_eq!(b.top_k[0].0, b.digit);
    }
    assert_eq!(server.served.load(Ordering::Relaxed), 18);
    server.shutdown();
}

#[test]
fn pipelined_path_survives_lists_longer_than_its_window() {
    // more images than WireClient::PIPELINE_WINDOW forces the bounded
    // window to interleave reads with writes — the path that prevents the
    // both-sides-blocked-on-full-TCP-buffers failure mode
    let (model, engine) = engine_784();
    let server = WireServer::start("127.0.0.1:0", engine).unwrap();
    let mut rng = Xoshiro256::new(57);
    let n = WireClient::PIPELINE_WINDOW * 2 + 5;
    let images: Vec<Packed> = (0..n).map(|_| rand_image(&mut rng, 784)).collect();
    let mut client = WireClient::connect(server.addr).unwrap();
    let items = client.classify_pipelined(&images, InferOptions::digits_only()).unwrap();
    assert_eq!(items.len(), n);
    for (item, img) in items.iter().zip(&images) {
        assert_eq!(item.digit as usize, model.predict(&img.words));
    }
    assert_eq!(server.served.load(Ordering::Relaxed), n as u64);
    server.shutdown();
}

#[test]
fn v2_serves_every_acceptance_width_end_to_end() {
    // the wire path must be width-agnostic end to end: serve a model of
    // each acceptance width and classify over v2
    let mut rng = Xoshiro256::new(52);
    for w in ACCEPTANCE_WIDTHS {
        let model = random_model(&[w, 16, 10], 60 + w as u64);
        let engine = Arc::new(Engine::builder().native(&model).workers(1).build().unwrap());
        let server = WireServer::start("127.0.0.1:0", engine).unwrap();
        let mut client = WireClient::connect(server.addr).unwrap();
        for _ in 0..3 {
            let img = rand_image(&mut rng, w);
            let item = client.classify_v2(&img, InferOptions::default()).unwrap();
            assert_eq!(item.digit as usize, model.predict(&img.words), "width {w}");
            assert_eq!(item.logits, model.logits(&img.words), "width {w}");
        }
        server.shutdown();
    }
}

/// Raw-socket helper with a read timeout so a hung server fails the test
/// instead of deadlocking it.
fn raw_conn(addr: std::net::SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s
}

#[test]
fn malformed_frames_get_typed_errors_not_hangs() {
    let (model, engine) = engine_784();
    let server = WireServer::start("127.0.0.1:0", engine).unwrap();

    // bad magic → 7-byte v1-style error frame with BadMagic, then close
    {
        let mut s = raw_conn(server.addr);
        s.write_all(&[0x55, 0, 0]).unwrap();
        let mut frame = [0u8; 7];
        s.read_exact(&mut frame).unwrap();
        assert_eq!(frame[0], MAGIC_ERR);
        assert_eq!(WireStatus::from_u8(frame[1]), WireStatus::BadMagic);
        // connection is closed after a magic failure
        assert_eq!(s.read(&mut frame).unwrap(), 0, "server should close");
    }

    // v1 frame with a wrong length → BadLength
    {
        let mut s = raw_conn(server.addr);
        let mut f = vec![0xB1u8];
        f.extend_from_slice(&5u16.to_le_bytes());
        f.extend_from_slice(&[0u8; 5]);
        s.write_all(&f).unwrap();
        let mut frame = [0u8; 7];
        s.read_exact(&mut frame).unwrap();
        assert_eq!(frame[0], MAGIC_ERR);
        assert_eq!(WireStatus::from_u8(frame[1]), WireStatus::BadLength);
    }

    // absurd v2 header fields → v2 error frames with typed statuses
    let v2_head = |features: u8, top_k: u8, n_images: u16, n_bits: u32| -> Vec<u8> {
        let mut h = vec![MAGIC_REQ_V2, features, top_k];
        h.extend_from_slice(&7u64.to_le_bytes());
        h.extend_from_slice(&n_images.to_le_bytes());
        h.extend_from_slice(&n_bits.to_le_bytes());
        h
    };
    let cases: [(Vec<u8>, WireStatus); 6] = [
        (v2_head(0, 0, u16::MAX, 784), WireStatus::TooLarge),
        (v2_head(0, 0, 1, u32::MAX), WireStatus::TooLarge),
        // exact boundaries: one past each limit is TooLarge (never a
        // wrapped length), the limits themselves pass header validation
        // (exercised separately below)
        (v2_head(0, 0, (MAX_WIRE_BATCH + 1) as u16, 1), WireStatus::TooLarge),
        (v2_head(0, 0, 1, (MAX_WIRE_BITS + 1) as u32), WireStatus::TooLarge),
        (v2_head(0, 0, 0, 784), WireStatus::BadLength),
        (v2_head(0xF0, 0, 1, 784), WireStatus::BadFeature),
    ];
    for (bytes, want) in cases {
        let mut s = raw_conn(server.addr);
        s.write_all(&bytes).unwrap();
        let resp = read_response_v2(&mut s).unwrap();
        assert_eq!(resp.status, want);
        assert_eq!(resp.id, 7, "v2 errors echo the frame id");
        assert!(resp.items.is_empty());
    }
    // sanity: the limits the fuzz leans on are what the module exports
    assert!(u16::MAX as usize > MAX_WIRE_BATCH);
    assert!(u32::MAX as usize > MAX_WIRE_BITS);

    // short read: half a v2 header, then hang up — the server must just
    // drop the connection and keep serving others
    {
        let mut s = raw_conn(server.addr);
        s.write_all(&[MAGIC_REQ_V2, 0, 0, 1, 2, 3]).unwrap();
        drop(s);
    }
    // a backend-refused request (wrong width for this model) errors the
    // frame but keeps the connection
    {
        let mut client = WireClient::connect(server.addr).unwrap();
        let narrow = rand_image(&mut Xoshiro256::new(53), 16);
        let e = client.classify_v2(&narrow, InferOptions::default()).unwrap_err();
        assert!(format!("{e}").contains(WireStatus::Backend.name()), "{e}");
        // still serving on the same connection
        let img = rand_image(&mut Xoshiro256::new(54), 784);
        let item = client.classify_v2(&img, InferOptions::default()).unwrap();
        assert_eq!(item.digit as usize, model.predict(&img.words));
    }
    // and the server overall is still alive for fresh connections
    {
        let mut client = WireClient::connect(server.addr).unwrap();
        let img = rand_image(&mut Xoshiro256::new(55), 784);
        assert!(client.classify(&img).is_ok());
    }
    server.shutdown();
}

#[test]
fn oversize_batches_refuse_to_encode_client_side() {
    let mut rng = Xoshiro256::new(56);
    let too_many: Vec<Packed> = (0..MAX_WIRE_BATCH + 1).map(|_| rand_image(&mut rng, 8)).collect();
    assert!(encode_request_v2(&too_many, 1, InferOptions::default()).is_err());
    // and the v1 payload constant matches the v2 arithmetic at 784 bits
    assert_eq!(payload_bytes(784), PAYLOAD_BYTES);
    assert!(encode_request(&rand_image(&mut rng, 12)).is_err());
}

#[test]
fn boundary_counts_encode_or_refuse_typed_never_wrap() {
    use bnn_fpga::coordinator::wire::{
        encode_features, FEAT_LOGITS, FEAT_TOPK, MAX_WIRE_CLASSES,
    };
    // the one-byte top-k carrier's exact bounds
    assert!(encode_features(&InferOptions::default().with_top_k(255)).is_ok());
    assert!(encode_features(&InferOptions::default().with_top_k(256)).is_err());
    assert!(encode_features(&InferOptions::default().with_top_k(0)).is_err());

    // response sections at their exact limits round-trip; one past refuses
    // with a typed error instead of wrapping the length byte/word
    let item = |top_k_len: usize, logits_len: usize| WireItem {
        id: 1,
        digit: 300,
        latency_us: 5,
        logits: vec![0; logits_len],
        top_k: (0..top_k_len).map(|i| (i as u16, 0)).collect(),
    };
    let f = encode_response_v2(9, WireStatus::Ok, FEAT_TOPK, 255, &[item(255, 0)]).unwrap();
    let resp = read_response_v2(&mut Cursor::new(f.as_slice())).unwrap();
    assert_eq!(resp.items[0].top_k.len(), 255);
    assert_eq!(resp.items[0].digit, 300, "a >255 digit rides the wire unwrapped");
    assert!(encode_response_v2(9, WireStatus::Ok, FEAT_TOPK, 255, &[item(256, 0)]).is_err());
    let f =
        encode_response_v2(9, WireStatus::Ok, FEAT_LOGITS, 0, &[item(0, MAX_WIRE_CLASSES)])
            .unwrap();
    let resp = read_response_v2(&mut Cursor::new(f.as_slice())).unwrap();
    assert_eq!(resp.items[0].logits.len(), MAX_WIRE_CLASSES);
    assert!(encode_response_v2(9, WireStatus::Ok, FEAT_LOGITS, 0, &[item(0, MAX_WIRE_CLASSES + 1)])
        .is_err());
}

#[test]
fn batch_limit_boundary_passes_framing_end_to_end() {
    // exactly MAX_WIRE_BATCH images passes header validation and reaches
    // the backend (which refuses the width with a typed Backend error on
    // this 784-bit server) — proving the count boundary is inclusive and
    // never a framing failure or a hang
    let (_model, engine) = engine_784();
    let server = WireServer::start("127.0.0.1:0", engine).unwrap();
    let mut rng = Xoshiro256::new(58);
    let images: Vec<Packed> = (0..MAX_WIRE_BATCH).map(|_| rand_image(&mut rng, 1)).collect();
    let frame = encode_request_v2(&images, 21, InferOptions::digits_only()).unwrap();
    let mut s = raw_conn(server.addr);
    s.write_all(&frame).unwrap();
    let resp = read_response_v2(&mut s).unwrap();
    assert_eq!(resp.status, WireStatus::Backend);
    assert_eq!(resp.id, 21);
    server.shutdown();
}

#[test]
fn single_model_server_ignores_v2_model_names() {
    // a server started on one engine (no registry) serves named requests
    // as if the name were absent — model routing is a registry concern
    let (model, engine) = engine_784();
    let server = WireServer::start("127.0.0.1:0", engine).unwrap();
    let mut client = WireClient::connect(server.addr).unwrap();
    let img = rand_image(&mut Xoshiro256::new(59), 784);
    let item = client.classify_model("anything", &img, InferOptions::digits_only()).unwrap();
    assert_eq!(item.digit as usize, model.predict(&img.words));
    server.shutdown();
}
