//! Multi-model serving acceptance (DESIGN.md §Model registry): the
//! 400-class digit gate on every serving path (pool, blocking v2, async
//! v2, with v1 refusing typed instead of truncating), registry routing by
//! wire name with a typed unknown-model status on both servers, and the
//! zero-downtime hot-swap guarantee — open-loop load across repeated
//! swaps loses nothing, both ledgers balance, and the outgoing engines'
//! pipeline stage threads all exit.  Everything here runs artifact-free.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bnn_fpga::bnn::model::random_model;
use bnn_fpga::bnn::{BnnModel, Packed, DEFAULT_RING_CAP};
use bnn_fpga::coordinator::{
    run_open_loop, AsyncWireServer, BatcherConfig, Engine, InferOptions, Kernel, LoadConfig,
    ModelRegistry, WireClient, WireServer, WireStatus,
};
use bnn_fpga::util::prng::Xoshiro256;

fn rand_image(rng: &mut Xoshiro256, n_bits: usize) -> Packed {
    let bits: Vec<u8> = (0..n_bits).map(|_| rng.bool() as u8).collect();
    Packed::from_bits(&bits)
}

/// A random 784-bit image whose argmax under `model` satisfies `want`.
fn find_image(model: &BnnModel, seed: u64, want: impl Fn(usize) -> bool) -> (Packed, usize) {
    let mut rng = Xoshiro256::new(seed);
    for _ in 0..2000 {
        let img = rand_image(&mut rng, 784);
        let d = model.predict(&img.words);
        if want(d) {
            return (img, d);
        }
    }
    panic!("no random image satisfied the predicate within 2000 draws");
}

fn engine_for(model: &BnnModel, kernel: Kernel) -> Engine {
    Engine::builder()
        .native(model)
        .kernel(kernel)
        .workers(2)
        .batcher(BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(100),
        })
        .build()
        .unwrap()
}

// ---------------------------------------------------------------------------
// satellite 1: the u8 digit-truncation family

/// A 400-class model must serve its real argmax on the pool path and both
/// v2 wire paths; v1 (one digit byte) must refuse >255 digits with a typed
/// `too-large` error — and the connection must survive the refusal.
#[test]
fn four_hundred_class_models_serve_unwrapped_digits_everywhere() {
    let model = random_model(&[784, 128, 400], 77);
    // Classes are near-uniform under a random ±1 model, so both kinds of
    // image show up within a few draws.
    let (img_hi, digit_hi) = find_image(&model, 4001, |d| d > 255);
    let (img_lo, digit_lo) = find_image(&model, 4002, |d| d <= 255);

    // Pool path: InferResponse carries the u16 digit unwrapped.
    let engine = Arc::new(engine_for(&model, Kernel::default()));
    let resp = engine.infer(img_hi.clone()).unwrap();
    assert_eq!(usize::from(resp.digit), digit_hi);
    assert!(resp.digit > 255, "the gate image must exercise the widened type");

    // Blocking wire server: v2 carries the u16; v1 refuses typed.
    let server = WireServer::start("127.0.0.1:0", engine.clone()).unwrap();
    let mut client = WireClient::connect(server.addr).unwrap();
    let item = client.classify_v2(&img_hi, InferOptions::default()).unwrap();
    assert_eq!(usize::from(item.digit), digit_hi);
    let err = client.classify(&img_hi).unwrap_err();
    assert!(
        format!("{err:#}").contains(WireStatus::TooLarge.name()),
        "v1 must refuse a >255 digit with a typed error, got: {err:#}"
    );
    // The refusal is per-request: the same connection keeps serving.
    let ok = client.classify(&img_lo).unwrap();
    assert_eq!(usize::from(ok.digit), digit_lo);
    drop(client);
    server.shutdown();

    // Async wire server: same contract on both protocol versions.
    let server = AsyncWireServer::start("127.0.0.1:0", engine.clone()).unwrap();
    let mut client = WireClient::connect(server.addr).unwrap();
    let item = client.classify_v2(&img_hi, InferOptions::default()).unwrap();
    assert_eq!(usize::from(item.digit), digit_hi);
    let err = client.classify(&img_hi).unwrap_err();
    assert!(
        format!("{err:#}").contains(WireStatus::TooLarge.name()),
        "async v1 must refuse a >255 digit with a typed error, got: {err:#}"
    );
    let ok = client.classify(&img_lo).unwrap();
    assert_eq!(usize::from(ok.digit), digit_lo);
    drop(client);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// tentpole: wire-v2 model routing

/// Nameless v2 frames and all v1 frames hit the default model; a
/// `FEAT_MODEL` name routes to that engine; an unregistered name is a
/// typed `unknown-model` status — on both server implementations.
#[test]
fn registry_routes_by_name_with_default_and_typed_unknown() {
    let model_a = random_model(&[784, 32, 10], 1);
    let model_b = random_model(&[784, 32, 10], 2);
    // A probe the two models classify differently, so routing is
    // observable from the digit alone.
    let mut rng = Xoshiro256::new(1203);
    let (probe, digit_a, digit_b) = loop {
        let img = rand_image(&mut rng, 784);
        let (da, db) = (model_a.predict(&img.words), model_b.predict(&img.words));
        if da != db {
            break (img, da, db);
        }
    };

    let registry = Arc::new(ModelRegistry::new());
    assert!(registry.register("a", engine_for(&model_a, Kernel::default())).is_none());
    assert!(registry.register("b", engine_for(&model_b, Kernel::default())).is_none());
    assert_eq!(registry.default_model().as_deref(), Some("a"));

    let check = |addr: std::net::SocketAddr| {
        let mut client = WireClient::connect(addr).unwrap();
        // nameless v2 → the default model
        let item = client.classify_v2(&probe, InferOptions::default()).unwrap();
        assert_eq!(usize::from(item.digit), digit_a, "nameless v2 hits the default");
        // named v2 → that model's engine
        let item = client.classify_model("b", &probe, InferOptions::default()).unwrap();
        assert_eq!(usize::from(item.digit), digit_b, "named v2 routes by name");
        // unknown name → typed status, connection survives
        let err = client.classify_model("missing", &probe, InferOptions::default()).unwrap_err();
        assert!(
            format!("{err:#}").contains(WireStatus::UnknownModel.name()),
            "unregistered names must be typed, got: {err:#}"
        );
        // v1 cannot name a model and always hits the default
        let resp = client.classify(&probe).unwrap();
        assert_eq!(usize::from(resp.digit), digit_a, "v1 hits the default");
    };

    let server = WireServer::start_registry("127.0.0.1:0", registry.clone()).unwrap();
    check(server.addr);
    server.shutdown();

    let server = AsyncWireServer::start_registry("127.0.0.1:0", registry.clone()).unwrap();
    check(server.addr);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// tentpole + satellite 5: zero-downtime hot swap under open-loop load

/// Swap the live engine three times while an open-loop generator offers
/// named v2 traffic: no request may fail, every displaced engine's ledger
/// must balance after its drain, the post-swap engine must answer with the
/// replacement model's weights, and — engines here run the streaming
/// pipelined kernel — every stage thread must exit once the engines drop.
#[test]
fn hot_swap_under_open_loop_load_drops_nothing() {
    let model_a = random_model(&[784, 64, 10], 31);
    let model_b = random_model(&[784, 64, 10], 32);
    let mut rng = Xoshiro256::new(55);
    let (probe, digit_a, digit_b) = loop {
        let img = rand_image(&mut rng, 784);
        let (da, db) = (model_a.predict(&img.words), model_b.predict(&img.words));
        if da != db {
            break (img, da, db);
        }
    };

    // The pipelined tier spawns per-worker stage threads — exactly what the
    // leak gauge at the end watches.  Deep queue so load shedding can never
    // masquerade as a swap casualty.
    let build = |m: &BnnModel| {
        Engine::builder()
            .native(m)
            .kernel(Kernel::Pipelined {
                ring_cap: DEFAULT_RING_CAP,
            })
            .workers(2)
            .batcher(BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_micros(100),
            })
            .queue_cap(20_000)
            .build()
            .unwrap()
    };

    let registry = Arc::new(ModelRegistry::new());
    registry.register("live", build(&model_a));
    let server = AsyncWireServer::start_registry("127.0.0.1:0", registry.clone()).unwrap();

    let mut client = WireClient::connect(server.addr).unwrap();
    let before = client.classify_model("live", &probe, InferOptions::default()).unwrap();
    assert_eq!(usize::from(before.digit), digit_a);

    // Open-loop named traffic for 1.5 s; the swaps land in the middle.
    let images: Vec<Packed> = (0..8).map(|_| rand_image(&mut rng, 784)).collect();
    let cfg = LoadConfig {
        addr: server.addr,
        connections: 4,
        rate: 800.0,
        duration: Duration::from_millis(1500),
        v1_fraction: 0.0,
        seed: 99,
        model: Some("live".to_string()),
    };
    let load = std::thread::spawn(move || run_open_loop(&images, &cfg));

    std::thread::sleep(Duration::from_millis(200));
    for (i, m) in [&model_b, &model_a, &model_b].into_iter().enumerate() {
        // New submits land on the replacement the instant swap() returns;
        // the displaced engine finishes its in-flight tickets and must
        // settle to a balanced ledger.
        let old = registry.swap("live", build(m)).unwrap();
        ModelRegistry::drain(&old, Duration::from_secs(10)).unwrap();
        let mm = old.metrics();
        let (submitted, completed, rejected, cancelled) = (
            mm.submitted.load(Ordering::SeqCst),
            mm.completed.load(Ordering::SeqCst),
            mm.rejected.load(Ordering::SeqCst),
            mm.cancelled.load(Ordering::SeqCst),
        );
        assert_eq!(submitted, completed + rejected, "swap {i}: displaced ledger must balance");
        assert_eq!(cancelled, 0, "swap {i}: the wire path waits every ticket");
        drop(old);
        std::thread::sleep(Duration::from_millis(150));
    }

    let report = load.join().expect("loadgen thread").expect("open-loop run");
    assert!(report.sent > 0);
    assert_eq!(report.errors, 0, "a hot swap must shed nothing: {report:?}");
    assert_eq!(report.completed, report.sent, "every offered request must complete: {report:?}");

    // The name now routes to the last replacement's weights.
    let after = client.classify_model("live", &probe, InferOptions::default()).unwrap();
    assert_eq!(usize::from(after.digit), digit_b);
    assert_ne!(before.digit, after.digit, "the swap must be observable");

    // The surviving engine's ledger balances once traffic stops.
    let live = registry.engine("live").unwrap();
    ModelRegistry::drain(&live, Duration::from_secs(10)).unwrap();
    drop(live);

    drop(client);
    server.shutdown();
    drop(registry);
    // Four pipelined engines came and went; their stage threads must all
    // have exited (this binary's other tests never use the pipelined tier,
    // so the process-wide gauge is exclusively ours).
    let t0 = Instant::now();
    while bnn_fpga::bnn::pipeline::live_stage_threads() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "pipeline stage threads leaked across the swaps"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}
