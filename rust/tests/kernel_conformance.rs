//! Kernel-conformance suite: golden vectors + differential fuzzing.
//!
//! The contract every kernel tier must hold (ISSUE 3): **bit-identical
//! logits** to the scalar semantics reference and to the cycle-accurate
//! FPGA simulator, on every input.  Two instruments pin it:
//!
//! * **Golden vectors** — committed expected logits
//!   (`tests/golden/golden_vectors.json`) for fixed-seed synthetic models
//!   and inputs (see `common::CASES`), so cross-platform or cross-PR drift
//!   — a PRNG change, a packing change, an optimization-dependent kernel
//!   divergence — fails loudly against values that cannot silently move.
//!   Regenerate deliberately with the ignored test below or
//!   `python/tools/gen_golden_vectors.py` (both emit byte-identical JSON).
//! * **Differential fuzzing** — randomized layer shapes, edge widths,
//!   batch ladders and tile shapes, with every kernel enumerated from
//!   [`Kernel::registry_with`] (never hand-listed) and every [`SimdLevel`]
//!   forced explicitly, so the vectorized and fallback paths are both
//!   exercised on whatever host runs the suite.
//!
//! The CI matrix re-runs all of this with `BNN_FORCE_SCALAR=1` (pinning
//! the SIMD tier to its portable fallback on SIMD hosts) and runs the
//! golden test under `--release` to catch optimization-dependent drift.

mod common;

use bnn_fpga::bnn::model::random_model;
use bnn_fpga::bnn::packing::{
    pack_bits_u64, words_u64, xnor_popcount_z, xnor_popcount_z_simd_at, SimdLevel,
};
use bnn_fpga::coordinator::{InferBackend, Kernel, NativeBackend, SimBackend};
use bnn_fpga::sim::{MemStyle, SimConfig};
use bnn_fpga::util::prng::Xoshiro256;
use bnn_fpga::util::proptest_lite::{gens, Runner};

/// Golden gate #1: the committed logits are exactly what the scalar
/// semantics reference computes from the pinned seeds.  A failure here
/// means the *reference itself* moved (PRNG, packing, model builder) —
/// which must be a deliberate, fixture-regenerating change, never an
/// accident.
#[test]
fn golden_fixture_matches_scalar_reference() {
    let golden = common::load_golden_logits();
    for (spec, want) in common::CASES.iter().zip(&golden) {
        let got = spec.scalar_logits();
        assert_eq!(
            &got, want,
            "{}: scalar reference drifted from the committed golden vectors",
            spec.name
        );
    }
}

/// Golden gate #2: every registered kernel tier reproduces the committed
/// logits exactly, through the same backend path serving uses.
#[test]
fn every_kernel_reproduces_golden_vectors() {
    let golden = common::load_golden_logits();
    for (spec, want) in common::CASES.iter().zip(&golden) {
        let model = spec.model();
        let inputs = spec.inputs();
        // default shapes plus deliberately awkward ones (unaligned with
        // the 4-row quad / 2-image pair / layer widths)
        for (block, tile) in [(16usize, 8usize), (3, 2), (5, 3)] {
            for kernel in Kernel::registry_with(block, tile) {
                let backend = NativeBackend::with_kernel(model.clone(), kernel);
                let got = backend.infer_logits(&inputs).unwrap();
                assert_eq!(
                    &got, want,
                    "{}: kernel {kernel:?} diverged from the golden vectors",
                    spec.name
                );
            }
        }
    }
}

/// Golden gate #3: the cycle-accurate FPGA simulator reproduces the
/// committed logits too — the golden vectors pin hardware semantics, not
/// just the software kernels.
#[test]
fn fpga_sim_reproduces_golden_vectors() {
    let golden = common::load_golden_logits();
    for (spec, want) in common::CASES.iter().zip(&golden) {
        let model = spec.model();
        let sim = SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
        let got = sim.infer_logits(&spec.inputs()).unwrap();
        assert_eq!(
            &got, want,
            "{}: fpga-sim diverged from the golden vectors",
            spec.name
        );
    }
}

/// The committed file is byte-for-byte the canonical serialization of the
/// current reference — catches a stale fixture (or a writer divergence
/// between the Python generator and the Rust regeneration path) even when
/// the logits happen to still match.
#[test]
fn fixture_file_is_canonical() {
    let logits: Vec<_> = common::CASES.iter().map(|s| s.scalar_logits()).collect();
    let want = common::fixture_text(&logits);
    let got = std::fs::read_to_string(common::golden_path()).expect("fixture readable");
    assert_eq!(
        got, want,
        "golden_vectors.json is stale or non-canonical; regenerate with \
         `cargo test --release --test kernel_conformance regenerate -- --ignored`"
    );
}

/// The regeneration path (satellite): rewrite the fixture from the scalar
/// reference.  Ignored so it only runs deliberately:
/// `cargo test --release --test kernel_conformance regenerate -- --ignored`
#[test]
#[ignore = "rewrites tests/golden/golden_vectors.json from the scalar reference"]
fn regenerate_golden_vectors() {
    let logits: Vec<_> = common::CASES.iter().map(|s| s.scalar_logits()).collect();
    let text = common::fixture_text(&logits);
    let path = common::golden_path();
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(&path, &text).unwrap();
    // round-trip sanity: what we wrote is what the loader sees
    assert_eq!(common::load_golden_logits(), logits);
    eprintln!("regenerated {}", path.display());
}

/// Differential fuzz (satellite): random layer shapes, batch sizes and
/// tile shapes — every registered kernel against the per-image scalar
/// reference, and the scalar reference against the cycle-accurate
/// simulator.  Kernels come from the registry, so a future tier is pulled
/// in automatically.
#[test]
fn kernel_registry_differential_fuzz() {
    Runner::new("kernel-registry-differential").cases(10).run(
        &gens::Pair(gens::U64(0..=1u64 << 40), gens::Pair(gens::U64(1..=40), gens::U64(1..=12))),
        |(seed, (block, tile))| {
            let (block, tile) = (*block as usize, *tile as usize);
            let mut rng = Xoshiro256::new(*seed);
            // random 2–3-layer nets over word-straddling widths
            let n_layers = 2 + rng.below(2) as usize;
            let mut dims = Vec::with_capacity(n_layers + 1);
            for _ in 0..=n_layers {
                dims.push(1 + rng.below(130) as usize);
            }
            let model = random_model(&dims, rng.next_u64());
            let mut sim = None; // built lazily: the sim pays full cycle cost
            [1usize, 2, 7, 16].iter().all(|&batch| {
                let images = common::random_images(&mut rng, dims[0], batch);
                let scalar: Vec<Vec<i32>> =
                    images.iter().map(|img| model.logits(&img.words)).collect();
                // every registered kernel tier through the backend path
                let kernels_ok = Kernel::registry_with(block, tile).into_iter().all(|kernel| {
                    let backend = NativeBackend::with_kernel(model.clone(), kernel);
                    backend.infer_logits(&images).unwrap() == scalar
                });
                // the simulator on the first batch only (enough to pin the
                // model; keeps the fuzz loop fast)
                let sim_ok = if batch == 1 {
                    let s = sim.get_or_insert_with(|| {
                        SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap()
                    });
                    s.infer_logits(&images).unwrap() == scalar
                } else {
                    true
                };
                kernels_ok && sim_ok
            })
        },
    );
}

/// Every [`SimdLevel`] — the vectorized paths *and* the forced portable
/// fallback — conforms to the scalar XNOR-popcount identity over random
/// shapes.  This pins the `BNN_FORCE_SCALAR=1` path without needing the
/// env var, and the AVX2/NEON paths on hosts that have them.
#[test]
fn simd_levels_differential_fuzz() {
    Runner::new("simd-levels-differential").cases(24).run(
        &gens::Pair(gens::BitVec(1..=300), gens::Pair(gens::U64(1..=6), gens::U64(1..=9))),
        |(bits, (n_imgs, n_rows))| {
            let n = bits.len();
            let wpr = words_u64(n);
            let (n_imgs, n_rows) = (*n_imgs as usize, *n_rows as usize);
            let mut rng = Xoshiro256::new(n as u64 * 977 + n_imgs as u64 * 31 + n_rows as u64);
            let mut imgs = pack_bits_u64(bits);
            for _ in 1..n_imgs {
                let b: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
                imgs.extend(pack_bits_u64(&b));
            }
            let mut rows = Vec::new();
            for _ in 0..n_rows {
                let b: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
                rows.extend(pack_bits_u64(&b));
            }
            SimdLevel::ALL.iter().all(|&level| {
                let mut got = vec![0i32; n_imgs * n_rows];
                xnor_popcount_z_simd_at(level, &imgs, n_imgs, &rows, wpr, n, &mut got, n_rows);
                (0..n_imgs).all(|i| {
                    (0..n_rows).all(|r| {
                        let want = xnor_popcount_z(
                            &imgs[i * wpr..(i + 1) * wpr],
                            &rows[r * wpr..(r + 1) * wpr],
                            n,
                        );
                        got[i * n_rows + r] == want
                    })
                })
            })
        },
    );
}

/// CI-pinned (ISSUE 5): the fused threshold-pack tier must be in the
/// registry — so every golden/differential gate above enumerates it — and
/// must reproduce the committed logits through the serving backend path on
/// its own, at tile widths that straddle its 64-row panel and 4-row quad.
/// The CI kernel-conformance matrix runs this by name in both
/// `BNN_FORCE_SCALAR` legs, so the vectorized and portable fused kernels
/// are each provably exercised.
#[test]
fn fused_tier_is_registered_and_golden_conformant() {
    let reg = Kernel::registry();
    assert!(
        reg.iter().any(|k| k.name() == "fused"),
        "fused tier missing from the registry: {reg:?}"
    );
    let golden = common::load_golden_logits();
    for (spec, want) in common::CASES.iter().zip(&golden) {
        let model = spec.model();
        let inputs = spec.inputs();
        for tile in [1usize, 3, 8] {
            let kernel = Kernel::Fused { tile_imgs: tile };
            let backend = NativeBackend::with_kernel(model.clone(), kernel);
            assert!(backend.prepared().is_some(), "{}: panels not prepared", spec.name);
            assert_eq!(
                &backend.infer_logits(&inputs).unwrap(),
                want,
                "{}: fused tier (tile {tile}) diverged from the golden vectors",
                spec.name
            );
        }
    }
}

/// CI-pinned (ISSUE 6): the streaming layer-pipelined dataflow tier must
/// be in the registry — so every golden/differential gate above
/// enumerates it — and must reproduce the committed logits through the
/// serving backend path on its own, at ring capacities from lockstep (1)
/// to generous buffering (64).  The CI kernel-conformance matrix runs
/// this by name in both `BNN_FORCE_SCALAR` legs, so the vectorized and
/// portable stage kernels are each provably exercised; the dedicated
/// drain/fuzz matrix lives in `tests/pipeline_conformance.rs`.
#[test]
fn pipelined_tier_is_registered_and_golden_conformant() {
    let reg = Kernel::registry();
    assert!(
        reg.iter().any(|k| k.name() == "pipelined"),
        "pipelined tier missing from the registry: {reg:?}"
    );
    let golden = common::load_golden_logits();
    for (spec, want) in common::CASES.iter().zip(&golden) {
        let model = spec.model();
        let inputs = spec.inputs();
        for cap in [1usize, 3, 64] {
            let kernel = Kernel::Pipelined { ring_cap: cap };
            let backend = NativeBackend::with_kernel(model.clone(), kernel);
            assert!(
                backend.prepared().is_some(),
                "{}: stages not prepared",
                spec.name
            );
            assert_eq!(
                &backend.infer_logits(&inputs).unwrap(),
                want,
                "{}: pipelined tier (ring cap {cap}) diverged from the golden vectors",
                spec.name
            );
        }
    }
}

/// The fixture deliberately covers the widths that break naive kernels:
/// sub-word, word-straddling, exact-multiple and the paper's own shapes.
#[test]
fn golden_cases_cover_edge_widths() {
    let all_dims: Vec<usize> = common::CASES
        .iter()
        .flat_map(|c| c.dims.iter().copied())
        .collect();
    for needed in [63usize, 64, 65, 37, 784] {
        assert!(
            all_dims.contains(&needed),
            "golden cases no longer cover width {needed}"
        );
    }
    let total: usize = common::CASES.iter().map(|c| c.n_inputs).sum();
    assert!(total >= 32, "golden fixture shrank below 32 inputs ({total})");
}
