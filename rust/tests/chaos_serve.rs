//! Fault-tolerant serving acceptance (DESIGN.md §Fault tolerance): a
//! seeded chaos soak — panic, latency-spike and error faults injected at
//! >5% of backend calls — through the registry-backed async server under
//! ≥10k open-loop requests.  Every request must resolve typed (no hangs),
//! the engine ledger must balance across every crash and restart,
//! `worker_restarts` must show supervision did real work, non-faulted
//! responses must still carry the model's argmax, and the pipelined
//! kernel's stage threads must all exit at teardown.  A second test pins
//! wire deadline propagation end to end on both servers.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bnn_fpga::bnn::model::random_model;
use bnn_fpga::bnn::{Packed, DEFAULT_RING_CAP};
use bnn_fpga::coordinator::{
    run_open_loop, AsyncWireServer, BatcherConfig, ChaosConfig, Engine, FaultKind, InferOptions,
    Kernel, LoadConfig, ModelRegistry, RetryPolicy, WireClient, WireServer, WireStatus,
};
use bnn_fpga::util::prng::Xoshiro256;

fn rand_image(rng: &mut Xoshiro256, n_bits: usize) -> Packed {
    let bits: Vec<u8> = (0..n_bits).map(|_| rng.bool() as u8).collect();
    Packed::from_bits(&bits)
}

#[test]
fn chaos_soak_resolves_every_request_typed_and_balances() {
    let model = random_model(&[784, 64, 10], 41);
    // Panic + latency + error faults on ~6% of backend calls.  The
    // pipelined kernel runs underneath so the stage-thread leak gauge at
    // the end is meaningful even across worker crashes.
    let chaos = ChaosConfig::new(0xC4A0_5EED, 0.06)
        .with_kinds(&[FaultKind::Error, FaultKind::Panic, FaultKind::Latency])
        .with_spike(Duration::from_millis(1));
    let engine = Engine::builder()
        .native(&model)
        .kernel(Kernel::Pipelined {
            ring_cap: DEFAULT_RING_CAP,
        })
        .workers(2)
        .batcher(BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(100),
        })
        .queue_cap(50_000)
        .chaos(chaos)
        .build()
        .unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.register("live", engine);
    let server = AsyncWireServer::start_registry("127.0.0.1:0", registry.clone()).unwrap();

    let mut rng = Xoshiro256::new(77);
    let images: Vec<Packed> = (0..16).map(|_| rand_image(&mut rng, 784)).collect();
    let cfg = LoadConfig {
        addr: server.addr,
        connections: 8,
        rate: 6_000.0,
        duration: Duration::from_secs(2),
        v1_fraction: 0.5,
        seed: 4242,
        model: None,
    };
    let report = run_open_loop(&images, &cfg).expect("open-loop soak");

    // ≥10k offered requests, and *all* of them answered — a hang anywhere
    // (dead shard, unresolved ticket, wedged connection) would strand the
    // readers and fail the run instead.
    assert!(report.sent >= 10_000, "soak too small: {report:?}");
    assert_eq!(
        report.completed + report.errors,
        report.sent,
        "every request must resolve, OK or typed: {report:?}"
    );
    assert!(report.errors > 0, "a 6% fault plan must surface typed errors");
    assert!(report.completed > 0, "most traffic must still serve");
    // the refusals have their own latency stream, split from the
    // success-only percentiles
    assert!(report.err_max_us > 0.0, "error latency must be captured");

    // Non-faulted responses still carry the model's argmax — chaos must
    // corrupt nothing it didn't explicitly fault.  The retrying client
    // also exercises reconnect-and-resend against a faulting server.
    let mut client = WireClient::connect(server.addr)
        .unwrap()
        .with_retry(RetryPolicy::default());
    let mut served = 0usize;
    for img in &images {
        match client.classify_v2(img, InferOptions::default()) {
            Ok(item) => {
                assert_eq!(
                    usize::from(item.digit),
                    model.predict(&img.words),
                    "a non-faulted response must carry the true argmax"
                );
                served += 1;
            }
            // a chaos fault landed on this probe: typed, never hung
            Err(_) => {}
        }
    }
    assert!(served > 0, "probes can't all fault at a 6% rate");
    drop(client);

    // Ledger: displaced (crashed-and-restarted) and surviving workers
    // together must balance the books, and supervision must have actually
    // restarted someone under a 2% panic share of 10k+ calls.
    let live = registry.engine("live").unwrap();
    ModelRegistry::drain(&live, Duration::from_secs(10)).unwrap();
    let m = live.metrics();
    let (submitted, completed, rejected, cancelled) = (
        m.submitted.load(Ordering::SeqCst),
        m.completed.load(Ordering::SeqCst),
        m.rejected.load(Ordering::SeqCst),
        m.cancelled.load(Ordering::SeqCst),
    );
    assert_eq!(
        submitted,
        completed + rejected,
        "ledger must balance across crashes: {}",
        m.summary_line()
    );
    assert_eq!(cancelled, 0, "the wire path waits every ticket");
    assert!(
        m.worker_restarts.load(Ordering::SeqCst) > 0,
        "panic faults must have forced supervised restarts: {}",
        m.summary_line()
    );
    drop(live);

    drop(registry);
    server.shutdown();
    // Crashed workers shared pipelined replicas; teardown must still
    // reap every stage thread.
    let t0 = Instant::now();
    while bnn_fpga::bnn::pipeline::live_stage_threads() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "pipeline stage threads leaked across worker crashes"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn deadlines_propagate_over_the_wire_and_shed_typed() {
    let model = random_model(&[784, 32, 10], 43);
    let mut rng = Xoshiro256::new(91);
    let img = rand_image(&mut rng, 784);
    let digit = model.predict(&img.words);

    let engine = Arc::new(
        Engine::builder()
            .native(&model)
            .workers(1)
            .build()
            .unwrap(),
    );

    let blocking = WireServer::start("127.0.0.1:0", engine.clone()).unwrap();
    let asynch = AsyncWireServer::start("127.0.0.1:0", engine.clone()).unwrap();
    for addr in [blocking.addr, asynch.addr] {
        let mut client = WireClient::connect(addr).unwrap();
        // a roomy budget rides the FEAT_DEADLINE section and still serves
        let item = client
            .classify_v2(&img, InferOptions::default().with_budget(Duration::from_secs(30)))
            .unwrap();
        assert_eq!(usize::from(item.digit), digit);
        // an already-expired deadline is shed server-side, typed — the
        // request never executes against the backend
        let err = client
            .classify_v2(&img, InferOptions::default().with_deadline(Instant::now()))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains(WireStatus::DeadlineExceeded.name()),
            "expired budgets must shed typed, got: {err:#}"
        );
        // the shed is per-request: the same connection keeps serving
        let again = client.classify_v2(&img, InferOptions::default()).unwrap();
        assert_eq!(usize::from(again.digit), digit);
    }
    let m = engine.metrics();
    assert_eq!(
        m.deadline_expired.load(Ordering::SeqCst),
        2,
        "each server shed exactly one expired request: {}",
        m.summary_line()
    );
    assert_eq!(
        m.submitted.load(Ordering::SeqCst),
        m.completed.load(Ordering::SeqCst) + m.rejected.load(Ordering::SeqCst),
        "sheds count rejected so the books still balance"
    );
    blocking.shutdown();
    asynch.shutdown();
}
