//! Pipeline-conformance suite (ISSUE 6): the streaming layer-pipelined
//! dataflow tier (`Kernel::Pipelined`) against the scalar semantics
//! reference.
//!
//! Two instruments, mirroring `kernel_conformance.rs`:
//!
//! * **Golden vectors** — the pipelined walk must reproduce the committed
//!   logits (`tests/golden/golden_vectors.json`) for every fixed-seed
//!   case, at every swept ring capacity, through both the direct
//!   `PreparedModel::logits_batch_pipelined` path and the
//!   `NativeBackend` serving path.
//! * **Differential fuzz** — edge widths {1, 37, 63, 64, 65, 784} ×
//!   batch sizes {1, 2, `FUSED_PAR_MIN_CHUNK`±1, 2×`FUSED_PAR_MIN_CHUNK`
//!   + 37} × ring capacities {1, 2, 7, 64} × depths 0–2 hidden layers,
//!   asserting bit-identity against the per-image scalar reference.
//!
//! Every case additionally asserts **clean shutdown**: the pipeline's
//! `std::thread::scope` must have joined all stage workers by the time
//! the call returns, observed via
//! [`bnn_fpga::bnn::pipeline::live_stage_threads`].  That counter is
//! process-global, so every test in this binary serializes on one mutex —
//! the assertion is exact, never racing a concurrent pipeline.

mod common;

use std::sync::{Mutex, MutexGuard};

use bnn_fpga::bnn::model::random_model;
use bnn_fpga::bnn::pipeline::live_stage_threads;
use bnn_fpga::bnn::{PreparedModel, FUSED_PAR_MIN_CHUNK};
use bnn_fpga::coordinator::{InferBackend, Kernel, NativeBackend};
use bnn_fpga::util::prng::Xoshiro256;

/// Ring capacities under test: lockstep (1), tiny, odd, generous.
const RING_CAPS: [usize; 4] = [1, 2, 7, 64];

/// Batch sizes under test: single image, pair, the parallel-split
/// threshold straddled from both sides, and a ragged multi-chunk batch.
const BATCHES: [usize; 5] = [
    1,
    2,
    FUSED_PAR_MIN_CHUNK - 1,
    FUSED_PAR_MIN_CHUNK + 1,
    2 * FUSED_PAR_MIN_CHUNK + 37,
];

/// Input widths that break naive kernels: sub-word, word-straddling,
/// exact multiples, and the paper's 784.
const WIDTHS: [usize; 6] = [1, 37, 63, 64, 65, 784];

/// All tests in this binary serialize here so the process-global
/// [`live_stage_threads`] gauge reads exactly 0 between cases.
static SERIAL: Mutex<()> = Mutex::new(());

fn serialized() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Assert the scope joined every stage worker before returning.
fn assert_drained(context: &str) {
    assert_eq!(
        live_stage_threads(),
        0,
        "{context}: stage threads leaked past the pipeline call"
    );
}

/// Golden gate: the pipelined tier reproduces the committed logits for
/// every fixed-seed case at every ring capacity — through the direct
/// prepared-model walk AND the serving backend path — and joins all
/// stage threads after every call.
#[test]
fn pipelined_walk_reproduces_golden_vectors_at_every_ring_cap() {
    let _guard = serialized();
    let golden = common::load_golden_logits();
    for (spec, want) in common::CASES.iter().zip(&golden) {
        let model = spec.model();
        let inputs = spec.inputs();
        let prepared = PreparedModel::new(&model).unwrap();
        let batch = inputs.len();
        let mut flat = Vec::new();
        for img in &inputs {
            flat.extend_from_slice(&img.words);
        }
        let want_flat: Vec<i32> = want.iter().flatten().copied().collect();
        for cap in RING_CAPS {
            // direct walk
            let mut got = vec![0i32; batch * model.n_classes()];
            prepared.logits_batch_pipelined(&flat, batch, &mut got, cap);
            assert_eq!(
                got, want_flat,
                "{}: pipelined walk (ring cap {cap}) diverged from the golden vectors",
                spec.name
            );
            assert_drained(spec.name);
            // serving backend path
            let backend =
                NativeBackend::with_kernel(model.clone(), Kernel::Pipelined { ring_cap: cap });
            assert!(
                backend.prepared().is_some(),
                "{}: pipelined backend did not prepare stages",
                spec.name
            );
            assert_eq!(
                &backend.infer_logits(&inputs).unwrap(),
                want,
                "{}: pipelined backend (ring cap {cap}) diverged from the golden vectors",
                spec.name
            );
            assert_drained(spec.name);
        }
    }
}

/// Differential fuzz: edge widths × batch ladder × ring capacities ×
/// model depths (including no-hidden-layer), bit-identical to the
/// per-image scalar reference with clean shutdown on every single case.
#[test]
fn pipelined_walk_differential_fuzz_widths_batches_ring_caps() {
    let _guard = serialized();
    let mut rng = Xoshiro256::new(0xDA7A_F10E);
    for (wi, &w) in WIDTHS.iter().enumerate() {
        // depth 0 (output stage inline), depth 1, and depth 2 (a real
        // multi-stage chain) — hidden widths straddle word boundaries
        let depths: [Vec<usize>; 3] = [
            vec![w, 10],
            vec![w, 65, 10],
            vec![w, 63, 37, 10],
        ];
        for (di, dims) in depths.iter().enumerate() {
            let model = random_model(dims, 4_000 + (wi * 10 + di) as u64);
            let prepared = PreparedModel::new(&model).unwrap();
            for &batch in &BATCHES {
                let images = common::random_images(&mut rng, w, batch);
                let mut flat = Vec::new();
                for img in &images {
                    flat.extend_from_slice(&img.words);
                }
                // scalar reference, computed once per (width, depth, batch)
                let want = model.logits_batch(&flat, batch);
                for cap in RING_CAPS {
                    let mut got = vec![0i32; batch * model.n_classes()];
                    prepared.logits_batch_pipelined(&flat, batch, &mut got, cap);
                    assert_eq!(
                        got, want,
                        "dims {dims:?}, batch {batch}, ring cap {cap}: \
                         pipelined diverged from scalar"
                    );
                    assert_drained("differential fuzz");
                }
            }
        }
    }
}

/// The degenerate drains named in the tentpole contract, each pinned
/// explicitly (they are also inside the fuzz matrix, but a named failure
/// beats a matrix coordinate): single-image batch, ragged tail relative
/// to the parallel-split chunking, no-hidden-layer model, empty batch.
#[test]
fn pipelined_walk_drains_degenerate_batches_cleanly() {
    let _guard = serialized();
    let mut rng = Xoshiro256::new(0x0D0E_60E5);

    // single image through a deep chain at lockstep capacity
    let deep = random_model(&[65, 63, 37, 19, 10], 31);
    let prepared = PreparedModel::new(&deep).unwrap();
    let images = common::random_images(&mut rng, 65, 1);
    let want = deep.logits(&images[0].words);
    let mut got = vec![0i32; 10];
    prepared.logits_batch_pipelined(&images[0].words, 1, &mut got, 1);
    assert_eq!(got, want, "single-image batch through 3 hidden stages");
    assert_drained("single-image batch");

    // ragged tail: a batch that does not divide the split threshold
    let batch = FUSED_PAR_MIN_CHUNK + FUSED_PAR_MIN_CHUNK / 2 + 1;
    let images = common::random_images(&mut rng, 65, batch);
    let mut flat = Vec::new();
    for img in &images {
        flat.extend_from_slice(&img.words);
    }
    let want = deep.logits_batch(&flat, batch);
    let mut got = vec![0i32; batch * 10];
    prepared.logits_batch_pipelined(&flat, batch, &mut got, 2);
    assert_eq!(got, want, "ragged-tail batch of {batch}");
    assert_drained("ragged-tail batch");

    // no hidden layers: the output stage runs inline, zero threads
    let shallow = random_model(&[37, 10], 32);
    let prepared = PreparedModel::new(&shallow).unwrap();
    let images = common::random_images(&mut rng, 37, 5);
    let mut flat = Vec::new();
    for img in &images {
        flat.extend_from_slice(&img.words);
    }
    let want = shallow.logits_batch(&flat, 5);
    let mut got = vec![0i32; 5 * 10];
    prepared.logits_batch_pipelined(&flat, 5, &mut got, 64);
    assert_eq!(got, want, "no-hidden-layer model");
    assert_drained("no-hidden-layer model");

    // empty batch: a no-op that must not spawn or deadlock
    prepared.logits_batch_pipelined(&[], 0, &mut [], 1);
    assert_drained("empty batch");
}

/// The registry pins the pipelined tier into every kernel-enumerating
/// suite; this guards the wiring this suite itself depends on.
#[test]
fn registry_carries_the_pipelined_tier() {
    let _guard = serialized();
    let reg = Kernel::registry();
    let pipelined: Vec<_> = reg.iter().filter(|k| k.name() == "pipelined").collect();
    assert_eq!(
        pipelined.len(),
        1,
        "registry must carry exactly one pipelined tier: {reg:?}"
    );
    pipelined[0].validate().unwrap();
}
