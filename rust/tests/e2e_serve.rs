//! End-to-end serving tests: coordinator + worker pool + router under
//! concurrent load.  Serving mechanics don't depend on trained weights, so
//! these run on the synthetic fallback when `make artifacts` has not run;
//! only the PJRT test needs real artifacts (and skips without them).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use bnn_fpga::coordinator::{
    BatcherConfig, Coordinator, InferBackend, Kernel, NativeBackend, PjrtBackend, Router,
    SimBackend, WorkerPool,
};
use bnn_fpga::data::Dataset;
use bnn_fpga::runtime::Engine;
use bnn_fpga::sim::{MemStyle, SimConfig};
use bnn_fpga::{artifacts_dir, load_model_or_synth};

fn setup() -> (bnn_fpga::bnn::BnnModel, Dataset) {
    let (model, ds, _trained) = load_model_or_synth(100);
    (model, ds)
}

#[test]
fn coordinator_over_pjrt_serves_correctly() {
    let (model, ds) = setup();
    let engine = match Engine::load(&artifacts_dir()) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!("skipping PJRT e2e test: {e:#}");
            return;
        }
    };
    let coord = Coordinator::start(
        Arc::new(PjrtBackend::new(engine).unwrap()),
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(500),
        },
        1,
    )
    .unwrap();
    let images: Vec<_> = ds.images.iter().take(40).cloned().collect();
    let responses = coord.infer_many(images.clone()).unwrap();
    for (img, r) in images.iter().zip(&responses) {
        assert_eq!(r.digit as usize, model.predict(&img.words));
        assert_eq!(r.backend, "pjrt");
    }
    assert_eq!(coord.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 40);
    coord.shutdown();
}

#[test]
fn concurrent_submitters_no_loss_no_mixup() {
    let (model, ds) = setup();
    let coord = Arc::new(
        Coordinator::start(
            Arc::new(NativeBackend::new(model.clone())),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            },
            3,
        )
        .unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..8u64 {
        let coord = coord.clone();
        let ds = ds.clone();
        let model = model.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..25usize {
                let idx = ((t as usize) * 25 + i) % ds.len();
                let img = ds.images[idx].clone();
                let r = coord.infer(img.clone()).unwrap();
                // response must correspond to *this* image (no cross-wiring)
                assert_eq!(r.logits, model.logits(&img.words), "thread {t} req {i}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(
        coord.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        200
    );
    assert_eq!(coord.metrics.rejected.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn router_composes_heterogeneous_backends() {
    let (model, ds) = setup();
    let mut router = Router::new();
    router.register(
        "native",
        Coordinator::start(
            Arc::new(NativeBackend::new(model.clone())),
            BatcherConfig::default(),
            1,
        )
        .unwrap(),
    );
    router.register(
        "fpga-sim",
        Coordinator::start(
            Arc::new(SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap()),
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(10),
            },
            1,
        )
        .unwrap(),
    );
    for (i, img) in ds.images.iter().take(12).enumerate() {
        let name = if i % 2 == 0 { "native" } else { "fpga-sim" };
        let r = router.route(name, img.clone()).unwrap();
        assert_eq!(r.digit as usize, model.predict(&img.words));
    }
    // least-queue routing also works and serves correctly
    for img in ds.images.iter().take(6) {
        let r = router.route_least_queue(img.clone()).unwrap();
        assert_eq!(r.digit as usize, model.predict(&img.words));
    }
}

#[test]
fn worker_pool_scales_without_changing_results() {
    // The sharded pool must return the same classifications at every worker
    // count (1, 2, 4) and kernel schedule; only throughput may differ.
    let (model, ds) = setup();
    let images: Vec<_> = (0..60).map(|i| ds.images[i % ds.len()].clone()).collect();
    let expected: Vec<Vec<i32>> = images.iter().map(|img| model.logits(&img.words)).collect();
    for workers in [1usize, 2, 4] {
        for kernel in Kernel::registry_with(16, 4) {
            let pool = WorkerPool::native(
                &model,
                workers,
                kernel,
                BatcherConfig {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                },
            )
            .unwrap();
            let responses = pool.infer_many(images.clone()).unwrap();
            for (r, want) in responses.iter().zip(&expected) {
                assert_eq!(
                    &r.logits, want,
                    "workers={workers} kernel={kernel:?} req {}",
                    r.id
                );
            }
            assert_eq!(
                pool.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
                60
            );
            pool.shutdown();
        }
    }
}

#[test]
fn worker_pool_concurrent_submitters_no_loss_no_mixup() {
    let (model, ds) = setup();
    let pool = Arc::new(
        WorkerPool::native(
            &model,
            4,
            Kernel::default(),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            },
        )
        .unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..8u64 {
        let pool = pool.clone();
        let ds = ds.clone();
        let model = model.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..25usize {
                let idx = ((t as usize) * 25 + i) % ds.len();
                let img = ds.images[idx].clone();
                let r = pool.infer(img.clone()).unwrap();
                // response must correspond to *this* image (no cross-wiring)
                assert_eq!(r.logits, model.logits(&img.words), "thread {t} req {i}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(
        pool.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        200
    );
    assert_eq!(pool.metrics.rejected.load(std::sync::atomic::Ordering::Relaxed), 0);
    // the per-worker view accounts for every completion exactly once
    let per: u64 = pool
        .worker_metrics
        .iter()
        .map(|m| m.completed.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    assert_eq!(per, 200);
}

#[test]
fn mixed_kernel_pool_burst_no_loss_and_metrics_balance() {
    // Concurrency stress (ISSUE 3): one worker per registered kernel tier
    // — scalar, blocked, tiled and the runtime-dispatched SIMD path all
    // serving the same pool — under a multi-thread burst.  Whatever shard
    // a request lands on, the response must carry *that* request's logits
    // (no loss, no misrouting), every request id must be answered exactly
    // once, and the pool's books must balance:
    // `submitted == completed + rejected`.
    let (model, ds) = setup();
    let replicas: Vec<Arc<dyn InferBackend>> = Kernel::registry()
        .into_iter()
        .map(|k| -> Arc<dyn InferBackend> {
            Arc::new(NativeBackend::with_kernel(model.clone(), k))
        })
        .collect();
    let n_workers = replicas.len();
    let pool = Arc::new(
        WorkerPool::start(
            replicas,
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            },
        )
        .unwrap(),
    );
    assert_eq!(pool.workers(), n_workers);

    let threads = 8u64;
    let per_thread = 40usize;
    let mut joins = Vec::new();
    for t in 0..threads {
        let pool = pool.clone();
        let ds = ds.clone();
        let model = model.clone();
        joins.push(std::thread::spawn(move || {
            // burst-submit everything first, then collect — maximizes
            // in-flight overlap across the mixed-kernel shards
            let mut pending = Vec::with_capacity(per_thread);
            for i in 0..per_thread {
                let idx = ((t as usize) * per_thread + i) % ds.len();
                let img = ds.images[idx].clone();
                let (id, rx) = pool.submit(img.clone()).unwrap();
                pending.push((id, rx, img));
            }
            let mut ids = Vec::with_capacity(per_thread);
            for (id, rx, img) in pending {
                let r = rx.recv().expect("response lost");
                assert_eq!(r.id, id, "response misrouted across requests");
                assert_eq!(
                    r.logits,
                    model.logits(&img.words),
                    "thread {t}: logits belong to a different image"
                );
                assert_eq!(r.backend, "native");
                ids.push(id);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = Vec::new();
    for j in joins {
        all_ids.extend(j.join().unwrap());
    }
    let total = threads as usize * per_thread;
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(all_ids.len(), total, "duplicate or missing request ids");

    // inject size-mismatched images (backend reject path) once the burst
    // has drained, one at a time so each failed batch is its own
    let bad_count = 3u64;
    for _ in 0..bad_count {
        let bad = bnn_fpga::bnn::Packed::from_bits(&vec![1u8; 5]);
        assert!(pool.infer(bad).is_err(), "mismatched image must error");
    }

    let m = &pool.metrics;
    let submitted = m.submitted.load(Ordering::Relaxed);
    let completed = m.completed.load(Ordering::Relaxed);
    let rejected = m.rejected.load(Ordering::Relaxed);
    assert_eq!(submitted, total as u64 + bad_count);
    assert_eq!(completed, total as u64);
    assert_eq!(rejected, bad_count);
    assert_eq!(
        submitted,
        completed + rejected,
        "pool books must balance: submitted == completed + rejected"
    );
    // the per-worker ledgers agree with the aggregate
    let per_completed: u64 = pool
        .worker_metrics
        .iter()
        .map(|w| w.completed.load(Ordering::Relaxed))
        .sum();
    let per_rejected: u64 = pool
        .worker_metrics
        .iter()
        .map(|w| w.rejected.load(Ordering::Relaxed))
        .sum();
    assert_eq!(per_completed, completed);
    assert_eq!(per_rejected, rejected);
    // Arc-held pool: workers join on Drop
}

#[test]
fn coordinator_burst_metrics_balance() {
    // Same accounting contract on the single-queue coordinator: a
    // concurrent burst plus backend-rejected stragglers must leave
    // `submitted == completed + rejected`.
    let (model, ds) = setup();
    let coord = Arc::new(
        Coordinator::start(
            Arc::new(NativeBackend::with_kernel(model.clone(), Kernel::default())),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
            },
            2,
        )
        .unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..6u64 {
        let coord = coord.clone();
        let ds = ds.clone();
        let model = model.clone();
        joins.push(std::thread::spawn(move || {
            let mut pending = Vec::new();
            for i in 0..30usize {
                let img = ds.images[((t as usize) * 30 + i) % ds.len()].clone();
                let (id, rx) = coord.submit(img.clone()).unwrap();
                pending.push((id, rx, img));
            }
            for (id, rx, img) in pending {
                let r = rx.recv().expect("response lost");
                assert_eq!(r.id, id);
                assert_eq!(r.logits, model.logits(&img.words), "thread {t}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let bad = bnn_fpga::bnn::Packed::from_bits(&vec![0u8; 9]);
    assert!(coord.infer(bad).is_err());
    let submitted = coord.metrics.submitted.load(Ordering::Relaxed);
    let completed = coord.metrics.completed.load(Ordering::Relaxed);
    let rejected = coord.metrics.rejected.load(Ordering::Relaxed);
    assert_eq!(completed, 180);
    assert_eq!(
        submitted,
        completed + rejected,
        "coordinator books must balance"
    );
    // Arc-held coordinator: workers join on Drop
}

#[test]
fn throughput_sanity_native() {
    // the native path should comfortably exceed 10k req/s in release even
    // in CI; `cargo test` runs unoptimized, so use a debug-aware floor
    let floor = if cfg!(debug_assertions) { 500.0 } else { 10_000.0 };
    let (model, ds) = setup();
    let coord = Coordinator::start(
        Arc::new(NativeBackend::new(model)),
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(50),
        },
        2,
    )
    .unwrap();
    let n = 2000;
    let images: Vec<_> = (0..n).map(|i| ds.images[i % ds.len()].clone()).collect();
    let t0 = std::time::Instant::now();
    let responses = coord.infer_many(images).unwrap();
    let rps = n as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(responses.len(), n);
    assert!(rps > floor, "native throughput only {rps:.0} req/s (floor {floor})");
    coord.shutdown();
}
