//! End-to-end serving tests: the `Engine` builder API (the one public
//! construction path), tickets, the router and the metrics books under
//! concurrent load.  Serving mechanics don't depend on trained weights, so
//! these run on the synthetic fallback when `make artifacts` has not run;
//! only the PJRT test needs real artifacts (and skips without them).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use bnn_fpga::coordinator::{
    BatcherConfig, Engine, InferBackend, InferOptions, Kernel, NativeBackend, PjrtBackend, Router,
    Ticket,
};
use bnn_fpga::data::Dataset;
use bnn_fpga::runtime::Engine as PjrtRuntime;
use bnn_fpga::sim::{MemStyle, SimConfig};
use bnn_fpga::{artifacts_dir, load_model_or_synth};

fn setup() -> (bnn_fpga::bnn::BnnModel, Dataset) {
    let (model, ds, _trained) = load_model_or_synth(100);
    (model, ds)
}

#[test]
fn engine_over_pjrt_serves_correctly() {
    let (model, ds) = setup();
    let runtime = match PjrtRuntime::load(&artifacts_dir()) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!("skipping PJRT e2e test: {e:#}");
            return;
        }
    };
    let engine = Engine::builder()
        .shared(Arc::new(PjrtBackend::new(runtime).unwrap()))
        .workers(1)
        .batcher(BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(500),
        })
        .build()
        .unwrap();
    let images: Vec<_> = ds.images.iter().take(40).cloned().collect();
    let responses = engine.infer_many(images.clone()).unwrap();
    for (img, r) in images.iter().zip(&responses) {
        assert_eq!(r.digit as usize, model.predict(&img.words));
        assert_eq!(r.backend, "pjrt");
    }
    assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 40);
    engine.shutdown();
}

#[test]
fn concurrent_submitters_no_loss_no_mixup_single_queue() {
    let (model, ds) = setup();
    let engine = Arc::new(
        Engine::builder()
            .shared(Arc::new(NativeBackend::new(model.clone())))
            .workers(3)
            .batcher(BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..8u64 {
        let engine = engine.clone();
        let ds = ds.clone();
        let model = model.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..25usize {
                let idx = ((t as usize) * 25 + i) % ds.len();
                let img = ds.images[idx].clone();
                let r = engine.infer(img.clone()).unwrap();
                // response must correspond to *this* image (no cross-wiring)
                assert_eq!(r.logits, model.logits(&img.words), "thread {t} req {i}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 200);
    assert_eq!(engine.metrics().rejected.load(Ordering::Relaxed), 0);
    assert_eq!(engine.metrics().cancelled.load(Ordering::Relaxed), 0);
}

#[test]
fn router_composes_heterogeneous_engines() {
    let (model, ds) = setup();
    let mut router = Router::new();
    router.register(
        "native",
        Engine::builder()
            .native(&model)
            .kernel(Kernel::Scalar)
            .workers(1)
            .build()
            .unwrap(),
    );
    router.register(
        "fpga-sim",
        Engine::builder()
            .fpga_sim(&model, SimConfig::new(64, MemStyle::Bram))
            .workers(1)
            .batcher(BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(10),
            })
            .build()
            .unwrap(),
    );
    for (i, img) in ds.images.iter().take(12).enumerate() {
        let name = if i % 2 == 0 { "native" } else { "fpga-sim" };
        let r = router.route(name, img.clone()).unwrap();
        assert_eq!(r.digit as usize, model.predict(&img.words));
    }
    // least-queue routing also works and serves correctly
    for img in ds.images.iter().take(6) {
        let r = router.route_least_queue(img.clone()).unwrap();
        assert_eq!(r.digit as usize, model.predict(&img.words));
    }
    let report = router.metrics_report();
    assert!(report.contains("native:") && report.contains("fpga-sim:"), "{report}");
}

#[test]
fn engine_scales_workers_without_changing_results() {
    // The sharded engine must return the same classifications at every
    // worker count (1, 2, 4) and kernel schedule; only throughput differs.
    let (model, ds) = setup();
    let images: Vec<_> = (0..60).map(|i| ds.images[i % ds.len()].clone()).collect();
    let expected: Vec<Vec<i32>> = images.iter().map(|img| model.logits(&img.words)).collect();
    for workers in [1usize, 2, 4] {
        for kernel in Kernel::registry_with(16, 4) {
            let engine = Engine::builder()
                .native(&model)
                .kernel(kernel)
                .workers(workers)
                .batcher(BatcherConfig {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                })
                .build()
                .unwrap();
            let responses = engine.infer_many(images.clone()).unwrap();
            for (r, want) in responses.iter().zip(&expected) {
                assert_eq!(
                    &r.logits, want,
                    "workers={workers} kernel={kernel:?} req {}",
                    r.id
                );
            }
            assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 60);
            engine.shutdown();
        }
    }
}

#[test]
fn engine_concurrent_submitters_no_loss_no_mixup() {
    let (model, ds) = setup();
    let engine = Arc::new(
        Engine::builder()
            .native(&model)
            .kernel(Kernel::default())
            .workers(4)
            .batcher(BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..8u64 {
        let engine = engine.clone();
        let ds = ds.clone();
        let model = model.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..25usize {
                let idx = ((t as usize) * 25 + i) % ds.len();
                let img = ds.images[idx].clone();
                let r = engine.infer(img.clone()).unwrap();
                // response must correspond to *this* image (no cross-wiring)
                assert_eq!(r.logits, model.logits(&img.words), "thread {t} req {i}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 200);
    assert_eq!(engine.metrics().rejected.load(Ordering::Relaxed), 0);
    // the per-worker view accounts for every completion exactly once
    let per: u64 = engine
        .worker_metrics()
        .iter()
        .map(|m| m.completed.load(Ordering::Relaxed))
        .sum();
    assert_eq!(per, 200);
}

#[test]
fn mixed_kernel_engine_burst_no_loss_and_metrics_balance() {
    // Concurrency stress: one worker per registered kernel tier — scalar,
    // blocked, tiled, the runtime-dispatched SIMD path, the fused
    // threshold-pack path and the streaming layer pipeline all serving
    // the same engine — under a
    // multi-thread burst of ticketed submissions.
    // Whatever shard a request lands on, the response must carry *that*
    // request's logits (no loss, no misrouting), every ticket id must be
    // answered exactly once, and the books must balance:
    // `submitted == completed + rejected`.
    let (model, ds) = setup();
    let replicas: Vec<Arc<dyn InferBackend>> = Kernel::registry()
        .into_iter()
        .map(|k| -> Arc<dyn InferBackend> {
            Arc::new(NativeBackend::with_kernel(model.clone(), k))
        })
        .collect();
    let n_workers = replicas.len();
    let engine = Arc::new(
        Engine::builder()
            .replicas(replicas)
            .batcher(BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .unwrap(),
    );
    assert_eq!(engine.workers(), n_workers);

    let threads = 8u64;
    let per_thread = 40usize;
    let mut joins = Vec::new();
    for t in 0..threads {
        let engine = engine.clone();
        let ds = ds.clone();
        let model = model.clone();
        joins.push(std::thread::spawn(move || {
            // burst-submit everything first, then collect — maximizes
            // in-flight overlap across the mixed-kernel shards
            let mut pending: Vec<(Ticket, _)> = Vec::with_capacity(per_thread);
            for i in 0..per_thread {
                let idx = ((t as usize) * per_thread + i) % ds.len();
                let img = ds.images[idx].clone();
                let ticket = engine.submit(img.clone()).unwrap();
                pending.push((ticket, img));
            }
            let mut ids = Vec::with_capacity(per_thread);
            for (ticket, img) in pending {
                let id = ticket.id();
                let r = ticket.wait().expect("response lost");
                assert_eq!(r.id, id, "response misrouted across requests");
                assert_eq!(
                    r.logits,
                    model.logits(&img.words),
                    "thread {t}: logits belong to a different image"
                );
                assert_eq!(r.backend, "native");
                ids.push(id);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = Vec::new();
    for j in joins {
        all_ids.extend(j.join().unwrap());
    }
    let total = threads as usize * per_thread;
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(all_ids.len(), total, "duplicate or missing request ids");

    // inject size-mismatched images once the burst has drained: the
    // expected_bits gate rejects them at submit time (counted submitted +
    // rejected), so they can never poison a co-scheduled batch
    let bad_count = 3u64;
    for _ in 0..bad_count {
        let bad = bnn_fpga::bnn::Packed::from_bits(&vec![1u8; 5]);
        assert!(engine.infer(bad).is_err(), "mismatched image must error");
    }

    let m = engine.metrics();
    let submitted = m.submitted.load(Ordering::Relaxed);
    let completed = m.completed.load(Ordering::Relaxed);
    let rejected = m.rejected.load(Ordering::Relaxed);
    assert_eq!(submitted, total as u64 + bad_count);
    assert_eq!(completed, total as u64);
    assert_eq!(rejected, bad_count);
    assert_eq!(
        submitted,
        completed + rejected,
        "engine books must balance: submitted == completed + rejected"
    );
    // every ticket was waited, so nothing counts as cancelled
    assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
    // the per-worker ledgers agree with the aggregate
    let per_completed: u64 = engine
        .worker_metrics()
        .iter()
        .map(|w| w.completed.load(Ordering::Relaxed))
        .sum();
    let per_rejected: u64 = engine
        .worker_metrics()
        .iter()
        .map(|w| w.rejected.load(Ordering::Relaxed))
        .sum();
    assert_eq!(per_completed, completed);
    assert_eq!(per_rejected, rejected);
    // Arc-held engine: workers join on Drop
}

#[test]
fn single_queue_burst_metrics_balance_and_options() {
    // Same accounting contract on the single-queue core: a concurrent
    // burst plus backend-rejected stragglers must leave
    // `submitted == completed + rejected`; per-request options ride along.
    let (model, ds) = setup();
    let engine = Arc::new(
        Engine::builder()
            .shared(Arc::new(NativeBackend::with_kernel(
                model.clone(),
                Kernel::default(),
            )))
            .workers(2)
            .batcher(BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
            })
            .build()
            .unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..6u64 {
        let engine = engine.clone();
        let ds = ds.clone();
        let model = model.clone();
        joins.push(std::thread::spawn(move || {
            let mut pending = Vec::new();
            for i in 0..30usize {
                let img = ds.images[((t as usize) * 30 + i) % ds.len()].clone();
                let ticket = engine
                    .submit_with(img.clone(), InferOptions::default().with_top_k(2))
                    .unwrap();
                pending.push((ticket, img));
            }
            for (ticket, img) in pending {
                let id = ticket.id();
                let r = ticket.wait().expect("response lost");
                assert_eq!(r.id, id);
                let want = model.logits(&img.words);
                assert_eq!(r.logits, want, "thread {t}");
                assert_eq!(r.top_k, bnn_fpga::coordinator::request::top_k_i32(&want, 2));
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let bad = bnn_fpga::bnn::Packed::from_bits(&vec![0u8; 9]);
    assert!(engine.infer(bad).is_err());
    let submitted = engine.metrics().submitted.load(Ordering::Relaxed);
    let completed = engine.metrics().completed.load(Ordering::Relaxed);
    let rejected = engine.metrics().rejected.load(Ordering::Relaxed);
    assert_eq!(completed, 180);
    assert_eq!(
        submitted,
        completed + rejected,
        "engine books must balance"
    );
    // Arc-held engine: workers join on Drop
}

#[test]
fn ticket_polling_under_real_serving() {
    let (model, ds) = setup();
    let engine = Engine::builder().native(&model).workers(1).build().unwrap();
    let img = ds.images[0].clone();
    let mut ticket = engine.submit(img.clone()).unwrap();
    // poll until resolved (bounded; the backend is fast)
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let r = loop {
        if let Some(r) = ticket.wait_timeout(Duration::from_millis(5)).unwrap() {
            break r;
        }
        assert!(std::time::Instant::now() < deadline, "response never arrived");
    };
    assert_eq!(r.digit as usize, model.predict(&img.words));
    engine.shutdown();
}

#[test]
fn throughput_sanity_native() {
    // the native path should comfortably exceed 10k req/s in release even
    // in CI; `cargo test` runs unoptimized, so use a debug-aware floor
    let floor = if cfg!(debug_assertions) { 500.0 } else { 10_000.0 };
    let (model, ds) = setup();
    let engine = Engine::builder()
        .native(&model)
        .kernel(Kernel::default())
        .workers(2)
        .batcher(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(50),
        })
        .build()
        .unwrap();
    let n = 2000;
    let images: Vec<_> = (0..n).map(|i| ds.images[i % ds.len()].clone()).collect();
    let t0 = std::time::Instant::now();
    let responses = engine.infer_many(images).unwrap();
    let rps = n as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(responses.len(), n);
    assert!(rps > floor, "native throughput only {rps:.0} req/s (floor {floor})");
    engine.shutdown();
}
