//! Serving-path load benchmark: the async wire server under open-loop
//! mixed v1/v2 traffic across an arrival-rate ladder.
//!
//! Where `hotpath.rs` measures the kernels, this measures the *system*:
//! TCP framing, the readiness event loop, the dynamic batcher, and the
//! engine queue, all under a fixed offered rate so queueing delay lands in
//! the histogram instead of throttling the client (open-loop — see
//! `coordinator/loadgen.rs` on coordinated omission).
//!
//! Results go to `BENCH_serving.json` **at the repo root** next to
//! `BENCH_hotpath.json` (rate → p50/p99/p999 latency + achieved
//! images/sec, plus the max sustained rate) — the committed serving-latency
//! trajectory `make bench-serving` and CI regenerate every run, schema-gated
//! by `tests/bench_trajectory.rs`.  `BNN_BENCH_SERVING_JSON` overrides the
//! destination; `--quick` runs a short ladder for CI smoke.

#[path = "common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use bnn_fpga::bnn::DEFAULT_TILE_IMGS;
use bnn_fpga::coordinator::{
    run_open_loop, AsyncWireServer, BatcherConfig, Engine, Kernel, LoadConfig,
};
use bnn_fpga::util::json::{obj, Json};
use bnn_fpga::util::table::{Align, Table};

/// A run "sustains" its offered rate when it achieves at least this
/// fraction of it (scheduling jitter and ramp-down eat a little).
const SUSTAIN_FRACTION: f64 = 0.95;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (model, ds, dir) = common::load();
    println!("=== serving load benchmark (model from {}) ===\n", dir.display());

    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4);
    let engine = Arc::new(
        Engine::builder()
            .native(&model)
            .kernel(Kernel::Fused { tile_imgs: DEFAULT_TILE_IMGS })
            .workers(workers)
            .batcher(BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .expect("engine build"),
    );
    let server = AsyncWireServer::start("127.0.0.1:0", engine.clone()).expect("server start");
    println!(
        "async server on {} ({} backend), {workers} engine workers, fused kernel\n",
        server.addr, server.poll_backend
    );

    let images: Vec<_> = ds.images.iter().take(256).cloned().collect();

    // Warmup: one short closed-rate burst so first-connect and first-batch
    // costs don't pollute the first ladder rung.
    let warm = LoadConfig {
        addr: server.addr,
        connections: 4,
        rate: 2_000.0,
        duration: Duration::from_millis(300),
        v1_fraction: 0.5,
        seed: 1,
        model: None,
    };
    run_open_loop(&images, &warm).expect("warmup run");

    // The ladder: offered arrival rates (images/sec).  The top rungs are
    // meant to exceed what the engine sustains so the trajectory records
    // where saturation sets in and what overload does to the tails.
    let (rates, connections, duration): (&[f64], usize, Duration) = if quick {
        (&[10_000.0, 30_000.0], 8, Duration::from_millis(800))
    } else {
        (
            &[25_000.0, 50_000.0, 100_000.0, 200_000.0, 400_000.0],
            32,
            Duration::from_secs(3),
        )
    };

    let mut t = Table::new(&[
        "Offered (img/s)",
        "Achieved",
        "Sent",
        "OK",
        "Err",
        "p50 (µs)",
        "p99 (µs)",
        "p999 (µs)",
    ])
    .align(0, Align::Left);
    let mut rate_json = BTreeMap::new();
    let mut max_sustained: f64 = 0.0;
    let mut best_achieved: f64 = 0.0;
    for (i, &rate) in rates.iter().enumerate() {
        let cfg = LoadConfig {
            addr: server.addr,
            connections,
            rate,
            duration,
            v1_fraction: 0.5,
            seed: 0xB14D + i as u64,
            model: None,
        };
        let r = run_open_loop(&images, &cfg).expect("load run");
        t.row(vec![
            format!("{rate:.0}"),
            format!("{:.0}", r.achieved_ips),
            r.sent.to_string(),
            r.completed.to_string(),
            r.errors.to_string(),
            format!("{:.0}", r.p50_us),
            format!("{:.0}", r.p99_us),
            format!("{:.0}", r.p999_us),
        ]);
        best_achieved = best_achieved.max(r.achieved_ips);
        if r.achieved_ips >= SUSTAIN_FRACTION * rate {
            max_sustained = max_sustained.max(r.achieved_ips);
        }
        rate_json.insert(
            format!("{rate:.0}"),
            obj(vec![
                ("offered_ips", Json::from(r.offered_ips)),
                ("achieved_ips", Json::from(r.achieved_ips)),
                ("sent", Json::from(r.sent)),
                ("completed", Json::from(r.completed)),
                ("errors", Json::from(r.errors)),
                ("p50_us", Json::from(r.p50_us)),
                ("p99_us", Json::from(r.p99_us)),
                ("p999_us", Json::from(r.p999_us)),
                ("max_us", Json::from(r.max_us)),
                ("err_p50_us", Json::from(r.err_p50_us)),
                ("err_p99_us", Json::from(r.err_p99_us)),
                ("err_max_us", Json::from(r.err_max_us)),
            ]),
        );
    }
    t.print();
    // if no rung was fully sustained (tiny CI hosts), fall back to the best
    // achieved throughput so the field stays positive and meaningful
    if max_sustained == 0.0 {
        max_sustained = best_achieved;
    }
    println!(
        "\nmax sustained: {max_sustained:.0} images/sec (achieved ≥ {:.0}% of offered)",
        SUSTAIN_FRACTION * 100.0
    );
    println!("server served {} images OK", server.served.load(Ordering::Relaxed));

    // The engine's own books: the trajectory carries the fault ledger so a
    // regression that crashes workers or sheds deadlines mid-bench is
    // visible in the committed artifact, not just the latency tails.
    let m = engine.metrics();
    let ledger = obj(vec![
        ("submitted", Json::from(m.submitted.load(Ordering::Relaxed))),
        ("completed", Json::from(m.completed.load(Ordering::Relaxed))),
        ("rejected", Json::from(m.rejected.load(Ordering::Relaxed))),
        ("cancelled", Json::from(m.cancelled.load(Ordering::Relaxed))),
        (
            "worker_restarts",
            Json::from(m.worker_restarts.load(Ordering::Relaxed)),
        ),
        (
            "deadline_expired",
            Json::from(m.deadline_expired.load(Ordering::Relaxed)),
        ),
    ]);

    let doc = obj(vec![
        ("bench", Json::from("serving")),
        ("server", Json::from("async")),
        ("poll_backend", Json::from(server.poll_backend)),
        ("kernel", Json::from("fused")),
        ("workers", Json::from(workers as u64)),
        ("connections", Json::from(connections as u64)),
        ("v1_fraction", Json::from(0.5)),
        ("rates", Json::Obj(rate_json)),
        ("max_sustained_ips", Json::from(max_sustained)),
        ("ledger", ledger),
    ]);
    let out_path = std::env::var_os("BNN_BENCH_SERVING_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .parent()
                .map(|p| p.join("BENCH_serving.json"))
                .unwrap_or_else(|| std::path::PathBuf::from("BENCH_serving.json"))
        });
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("\nwrote serving trajectory to {}", out_path.display()),
        Err(e) => println!("\ncould not write {}: {e}", out_path.display()),
    }
    server.shutdown();
}
