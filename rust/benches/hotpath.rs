//! Hot-path micro-benchmarks (the §Perf instrument): native inference
//! (scalar vs blocked vs weight-stationary tiled vs the runtime-dispatched
//! SIMD tier vs the fused threshold-pack tier, with block-size and
//! tile-width sweeps), batch throughput, the 1-vs-N worker-pool
//! scaling sweep, simulator tick rate, PJRT dispatch overhead, and
//! coordinator round-trip cost.  Run before/after each optimization and
//! record deltas in EXPERIMENTS.md §Perf.
//!
//! Besides the human-readable tables, the kernel-variant results are
//! written to `BENCH_hotpath.json` **at the repo root** (kernel →
//! ns/image, images/sec, simd_level) — the committed perf trajectory
//! `make bench-json` and CI regenerate every run, so kernel regressions
//! have a baseline to diff against instead of only printed tables.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use std::collections::BTreeMap;

use bnn_fpga::bnn::{DEFAULT_BLOCK_ROWS, DEFAULT_RING_CAP, DEFAULT_TILE_IMGS};
use bnn_fpga::coordinator::{BatcherConfig, Engine, Kernel};
use bnn_fpga::runtime::Engine as PjrtRuntime;
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::bench::{from_args, BenchResult};
use bnn_fpga::util::json::{obj, Json};
use bnn_fpga::util::table::{Align, Table};

/// Record one kernel variant's batch result as `{ns_per_image, images_per_sec}`.
fn record_kernel(map: &mut BTreeMap<String, Json>, key: &str, batch: usize, r: &BenchResult) {
    map.insert(
        key.to_string(),
        obj(vec![
            ("ns_per_image", Json::from(r.summary.mean / batch as f64)),
            (
                "images_per_sec",
                Json::from(batch as f64 * 1e9 / r.summary.mean),
            ),
        ]),
    );
}

fn main() {
    let (model, ds, dir) = common::load();
    let bench = from_args();
    let img = &ds.images[0];
    println!("=== hot-path microbenchmarks ===\n");
    let mut t = Table::new(&["Benchmark", "mean", "p50", "p99", "iters"]).align(0, Align::Left);
    let fmt = |ns: f64| -> String {
        if ns < 1_000.0 {
            format!("{ns:.0} ns")
        } else if ns < 1_000_000.0 {
            format!("{:.2} µs", ns / 1e3)
        } else {
            format!("{:.2} ms", ns / 1e6)
        }
    };
    let mut add = |name: &str, r: bnn_fpga::util::bench::BenchResult| {
        t.row(vec![
            name.into(),
            fmt(r.summary.mean),
            fmt(r.summary.p50),
            fmt(r.summary.p99),
            r.iters.to_string(),
        ]);
    };

    // 1. native single-image inference — scalar baseline (allocation-free)
    {
        let mut scratch = bnn_fpga::bnn::model::Scratch::default();
        let mut out = vec![0i32; 10];
        let r = bench.run("native-single", || {
            model.logits_into(&img.words, &mut scratch, &mut out);
            out[0]
        });
        add("native single, scalar kernel", r);
    }

    // 2. native single-image inference — blocked kernel, block-size sweep
    //    (the software mirror of the paper's parallelism sweep)
    for block in [4usize, 8, 16, 32, 64] {
        let mut scratch = bnn_fpga::bnn::model::Scratch::default();
        let mut out = vec![0i32; 10];
        let r = bench.run(&format!("native-single-b{block}"), || {
            model.logits_into_blocked(&img.words, &mut scratch, &mut out, block);
            out[0]
        });
        add(&format!("native single, blocked B={block}"), r);
    }

    // 3. native batch-100 throughput: scalar vs blocked vs the
    //    weight-stationary tiled kernel, with a tile-width sweep — the
    //    variants recorded to BENCH_hotpath.json
    let mut kernel_json = BTreeMap::new();
    let batch_n = ds.len().min(100);
    {
        let inputs = ds.batch_words(0, batch_n);
        let n = batch_n;
        let r = bench.run("native-b100", || model.logits_batch(&inputs, n));
        record_kernel(&mut kernel_json, "scalar", n, &r);
        add("native batch-100, scalar (total)", r);
        let r = bench.run("native-b100-blocked", || {
            model.logits_batch_blocked(&inputs, n, DEFAULT_BLOCK_ROWS)
        });
        record_kernel(&mut kernel_json, &format!("blocked_b{DEFAULT_BLOCK_ROWS}"), n, &r);
        add("native batch-100, blocked (total)", r);
        for tile in [2usize, 4, 8, 16] {
            let r = bench.run(&format!("native-b100-tiled-t{tile}"), || {
                model.logits_batch_tiled(&inputs, n, DEFAULT_BLOCK_ROWS, tile)
            });
            record_kernel(
                &mut kernel_json,
                &format!("tiled_b{DEFAULT_BLOCK_ROWS}_t{tile}"),
                n,
                &r,
            );
            add(&format!("native batch-100, tiled T={tile} (total)"), r);
        }
        // the runtime-dispatched SIMD tier (AVX2/NEON, tiled fallback) at
        // the same tile-width ladder — plus the resolved vector level so
        // BENCH_hotpath.json rows are comparable across hosts
        let level = bnn_fpga::bnn::simd_level();
        for tile in [2usize, 4, 8, 16] {
            let r = bench.run(&format!("native-b100-simd-t{tile}"), || {
                model.logits_batch_simd(&inputs, n, DEFAULT_BLOCK_ROWS, tile)
            });
            record_kernel(
                &mut kernel_json,
                &format!("simd_b{DEFAULT_BLOCK_ROWS}_t{tile}"),
                n,
                &r,
            );
            add(
                &format!("native batch-100, simd[{}] T={tile} (total)", level.name()),
                r,
            );
        }
        // the fused threshold-pack tier: panel weights prepared once
        // outside the timed loop (exactly what Engine::build() does),
        // then the register-fused walk over the same tile-width ladder
        let prepared = bnn_fpga::bnn::PreparedModel::new(&model).unwrap();
        for tile in [2usize, 4, 8, 16] {
            let r = bench.run(&format!("native-b100-fused-t{tile}"), || {
                prepared.logits_batch(&inputs, n, tile)
            });
            record_kernel(&mut kernel_json, &format!("fused_t{tile}"), n, &r);
            add(
                &format!("native batch-100, fused[{}] T={tile} (total)", level.name()),
                r,
            );
        }
        // the streaming layer-pipelined dataflow tier over the same
        // prepared panels: one stage thread per hidden layer chained by
        // SPSC rings, swept across ring capacities (1 = lockstep
        // hand-over-hand; larger caps absorb inter-layer jitter)
        let mut piped_out = vec![0i32; n * model.n_classes()];
        for cap in [1usize, 4, DEFAULT_RING_CAP, 64] {
            let r = bench.run(&format!("native-b100-pipelined-r{cap}"), || {
                prepared.logits_batch_pipelined(&inputs, n, &mut piped_out, cap);
                piped_out[0]
            });
            record_kernel(&mut kernel_json, &format!("pipelined_r{cap}"), n, &r);
            add(
                &format!("native batch-100, pipelined[{}] R={cap} (total)", level.name()),
                r,
            );
        }
    }

    // 4. one binary dense layer (784→128) in isolation, scalar vs blocked
    {
        let layer = &model.layers[0];
        let r = bench.run("layer0-scalar", || {
            let mut acc = 0i32;
            for j in 0..layer.n_out {
                acc = acc.wrapping_add(layer.z(&img.words, j));
            }
            acc
        });
        add("layer 784→128, scalar (128 rows)", r);
        let mut z = vec![0i32; layer.n_out];
        let r = bench.run("layer0-blocked", || {
            layer.z_block(&img.words, 0, &mut z);
            z[0]
        });
        add("layer 784→128, blocked (128 rows)", r);
    }

    // 5. FPGA simulator, one inference at P=64 (cycle-accurate cost)
    {
        let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
        let r = bench.run("sim-p64", || acc.run_image(img).digit);
        add("fpga-sim inference (P=64)", r);
    }

    // 6. PJRT dispatch (batch-1 artifact) — skipped when the runtime or the
    //    artifacts are unavailable
    match PjrtRuntime::load(&dir) {
        Ok(engine) => {
            let engine = Arc::new(engine);
            engine.prepare("bnn_b1").unwrap();
            let input = img.to_u32_words();
            let r = bench.run("pjrt-b1", || engine.run_u32_to_i32("bnn_b1", &input).unwrap());
            add("pjrt batch-1 round trip", r);
        }
        Err(e) => println!("pjrt bench skipped: {e:#}\n"),
    }

    // 7. engine round trip (queue + batch + native execute)
    {
        let engine = Engine::builder()
            .native(&model)
            .kernel(Kernel::Scalar)
            .workers(1)
            .batcher(BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(1),
            })
            .build()
            .unwrap();
        let r = bench.run("coord-rt", || engine.infer(img.clone()).unwrap().digit);
        add("engine round trip (b=1)", r);
        engine.shutdown();
    }

    t.print();

    // machine-readable perf trajectory: kernel variant -> ns/image +
    // images/sec at the batch-100 point, tracked across PRs.  Written to
    // the **repo root** (cargo runs benches from the package dir) so
    // `make bench-json` / CI always land the file in one committed place;
    // BNN_BENCH_JSON overrides the destination.
    let doc = obj(vec![
        ("bench", Json::from("hotpath")),
        ("batch", Json::from(batch_n as u64)),
        ("block_rows", Json::from(DEFAULT_BLOCK_ROWS as u64)),
        ("simd_level", Json::from(bnn_fpga::bnn::simd_level().name())),
        ("kernels", Json::Obj(kernel_json)),
    ]);
    let out_path = std::env::var_os("BNN_BENCH_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .parent()
                .map(|p| p.join("BENCH_hotpath.json"))
                .unwrap_or_else(|| std::path::PathBuf::from("BENCH_hotpath.json"))
        });
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("\nwrote kernel-variant results to {}", out_path.display()),
        Err(e) => println!("\ncould not write {}: {e}", out_path.display()),
    }

    // 8. worker-pool scaling sweep: same workload, 1..N workers, scalar vs
    //    blocked vs tiled — the speedup is measured, not asserted.
    println!("\n=== worker-pool scaling (kernel schedules, offered load fixed) ===\n");
    let mut pt = Table::new(&[
        "Workers", "Kernel", "Requests", "Wall (ms)", "Throughput (req/s)", "Speedup",
    ])
    .align(1, Align::Left);
    let quick = std::env::args().any(|a| a == "--quick");
    let n_req = if quick { 2_000 } else { 10_000 };
    let images: Vec<_> = (0..n_req).map(|i| ds.images[i % ds.len()].clone()).collect();
    let mut baseline_rps = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        for (label, kernel) in [
            ("scalar", Kernel::Scalar),
            (
                "blocked",
                Kernel::Blocked {
                    block_rows: DEFAULT_BLOCK_ROWS,
                },
            ),
            (
                "tiled",
                Kernel::Tiled {
                    block_rows: DEFAULT_BLOCK_ROWS,
                    tile_imgs: DEFAULT_TILE_IMGS,
                },
            ),
            (
                "simd",
                Kernel::Simd {
                    block_rows: DEFAULT_BLOCK_ROWS,
                    tile_imgs: DEFAULT_TILE_IMGS,
                },
            ),
            (
                "fused",
                Kernel::Fused {
                    tile_imgs: DEFAULT_TILE_IMGS,
                },
            ),
            (
                "pipelined",
                Kernel::Pipelined {
                    ring_cap: DEFAULT_RING_CAP,
                },
            ),
        ] {
            let pool = Engine::builder()
                .native(&model)
                .kernel(kernel)
                .workers(workers)
                .batcher(BatcherConfig {
                    max_batch: 64,
                    max_wait: Duration::from_micros(100),
                })
                .build()
                .unwrap();
            let input = images.clone(); // clone outside the timed window
            let t0 = Instant::now();
            pool.infer_many(input).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            pool.shutdown();
            let rps = n_req as f64 / wall;
            if workers == 1 && kernel == Kernel::Scalar {
                baseline_rps = rps;
            }
            pt.row(vec![
                workers.to_string(),
                label.into(),
                n_req.to_string(),
                format!("{:.1}", wall * 1e3),
                format!("{rps:.0}"),
                format!("{:.2}x", rps / baseline_rps),
            ]);
        }
    }
    pt.print();

    println!("\ntargets (EXPERIMENTS.md §Perf): native single ≤ 17.8 µs (the simulated");
    println!("hardware point — software must not be the bottleneck); coordinator");
    println!("overhead ≪ backend latency; pool throughput ≈ linear until memory-bound.");
}
