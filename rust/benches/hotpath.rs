//! Hot-path micro-benchmarks (the §Perf instrument): native inference,
//! batch throughput, simulator tick rate, PJRT dispatch overhead, and
//! coordinator round-trip cost.  Run before/after each optimization and
//! record deltas in EXPERIMENTS.md §Perf.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::Duration;

use bnn_fpga::coordinator::{BatcherConfig, Coordinator, NativeBackend};
use bnn_fpga::runtime::Engine;
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::bench::from_args;
use bnn_fpga::util::table::{Align, Table};

fn main() {
    let (model, ds, dir) = common::load();
    let bench = from_args();
    let img = &ds.images[0];
    println!("=== hot-path microbenchmarks ===\n");
    let mut t = Table::new(&["Benchmark", "mean", "p50", "p99", "iters"]).align(0, Align::Left);
    let fmt = |ns: f64| -> String {
        if ns < 1_000.0 {
            format!("{ns:.0} ns")
        } else if ns < 1_000_000.0 {
            format!("{:.2} µs", ns / 1e3)
        } else {
            format!("{:.2} ms", ns / 1e6)
        }
    };
    let mut add = |name: &str, r: bnn_fpga::util::bench::BenchResult| {
        t.row(vec![
            name.into(),
            fmt(r.summary.mean),
            fmt(r.summary.p50),
            fmt(r.summary.p99),
            r.iters.to_string(),
        ]);
    };

    // 1. native single-image inference (allocation-free path)
    {
        let mut scratch = bnn_fpga::bnn::model::Scratch::default();
        let mut out = vec![0i32; 10];
        let r = bench.run("native-single", || {
            model.logits_into(&img.words, &mut scratch, &mut out);
            out[0]
        });
        add("native single inference", r);
    }

    // 2. native batch-100 throughput
    {
        let inputs = ds.batch_words(0, 100);
        let r = bench.run("native-b100", || model.logits_batch(&inputs, 100));
        add("native batch-100 (total)", r);
    }

    // 3. one binary dense layer (784→128) in isolation
    {
        let layer = &model.layers[0];
        let r = bench.run("layer0", || {
            let mut acc = 0i32;
            for j in 0..layer.n_out {
                acc = acc.wrapping_add(layer.z(&img.words, j));
            }
            acc
        });
        add("layer 784→128 (128 neurons)", r);
    }

    // 4. FPGA simulator, one inference at P=64 (cycle-accurate cost)
    {
        let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
        let r = bench.run("sim-p64", || acc.run_image(img).digit);
        add("fpga-sim inference (P=64)", r);
    }

    // 5. PJRT dispatch (batch-1 artifact)
    {
        let engine = Arc::new(Engine::load(&dir).unwrap());
        engine.prepare("bnn_b1").unwrap();
        let input = img.to_u32_words();
        let r = bench.run("pjrt-b1", || engine.run_u32_to_i32("bnn_b1", &input).unwrap());
        add("pjrt batch-1 round trip", r);
    }

    // 6. coordinator round trip (queue + batch + native execute)
    {
        let coord = Coordinator::start(
            Arc::new(NativeBackend::new(model.clone())),
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(1),
            },
            1,
        )
        .unwrap();
        let r = bench.run("coord-rt", || coord.infer(img.clone()).unwrap().digit);
        add("coordinator round trip (b=1)", r);
        coord.shutdown();
    }

    t.print();
    println!("\ntargets (EXPERIMENTS.md §Perf): native single ≤ 17.8 µs (the simulated");
    println!("hardware point — software must not be the bottleneck); coordinator");
    println!("overhead ≪ backend latency.");
}
