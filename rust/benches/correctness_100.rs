//! §4.1 correctness verification: the exported 100-image binarized subset
//! (10 per digit) through the cycle-accurate simulator at the paper's 64×
//! BRAM design point.  Paper: 84/100 (software model: 87.97 %).

#[path = "common/mod.rs"]
mod common;

use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::table::{Align, Table};

fn main() {
    let (model, ds, dir) = common::load();
    let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();

    let mut correct = 0usize;
    let mut per_digit = [[0u32; 2]; 10];
    let mut sim_ns_total = 0.0;
    for (img, &label) in ds.images.iter().zip(&ds.labels) {
        let r = acc.run_image(img);
        let ok = r.digit == label;
        correct += ok as usize;
        per_digit[label as usize][usize::from(ok)] += 1;
        sim_ns_total += r.latency_ns;
    }

    println!("=== §4.1 correctness verification (100 binarized images, 10/digit) ===\n");
    let mut t = Table::new(&["Digit", "Correct", "Paper row"]).align(2, Align::Left);
    for (d, [wrong, right]) in per_digit.iter().enumerate() {
        t.row(vec![
            d.to_string(),
            format!("{right}/{}", wrong + right),
            "-".into(),
        ]);
    }
    t.row(vec![
        "all".into(),
        format!("{correct}/100"),
        "84/100 (software 87.97%)".into(),
    ]);
    t.print();

    // software full-test-set accuracy for the §4.1 software/hardware gap
    // (needs the exported idx files; skipped on the synthetic fallback)
    match bnn_fpga::data::Dataset::load_idx_test(&dir.join("data")) {
        Ok(test) => {
            let sw = test
                .images
                .iter()
                .zip(&test.labels)
                .filter(|(img, &l)| model.predict(&img.words) == l as usize)
                .count();
            println!(
                "\nfull test set (software path): {}/{} = {:.2}%  (paper: 87.97%)",
                sw,
                test.len(),
                sw as f64 / test.len() as f64 * 100.0
            );
        }
        Err(e) => println!("\nfull-test-set accuracy skipped: {e:#}"),
    }
    println!(
        "simulated hardware time for the 100 images: {:.3} ms ({:.1} µs/image, paper: 17.8 µs)",
        sim_ns_total / 1e6,
        sim_ns_total / 100.0 / 1e3
    );
}
