//! Shared bench setup (each bench is its own crate; this module is
//! `#[path]`-included).

use std::path::PathBuf;

use bnn_fpga::bnn::BnnModel;
use bnn_fpga::data::Dataset;
use bnn_fpga::artifacts_dir;

/// Trained model + §4.1 subset when `make artifacts` has run, otherwise the
/// deterministic synthetic fallback.  Latency/throughput numbers are valid
/// either way (the kernels are data-oblivious); accuracy columns are only
/// meaningful on the trained model.
#[allow(dead_code)] // table2/table3 include this module for the note only
pub fn load() -> (BnnModel, Dataset, PathBuf) {
    let dir = artifacts_dir();
    let (model, ds, trained) = bnn_fpga::load_model_or_synth(100);
    if !trained {
        println!(
            "(no artifacts — deterministic synthetic model/dataset; timing stands, \
             accuracy ≈ chance. run `make artifacts` for the trained model)\n"
        );
    }
    (model, ds, dir)
}

/// Where benches drop CSV/series output.
#[allow(dead_code)]
pub fn out_dir() -> PathBuf {
    let d = PathBuf::from("bench_out");
    std::fs::create_dir_all(&d).ok();
    d
}

#[allow(dead_code)]
pub fn paper_row_note() {
    println!("(paper values quoted from Ertörer & Ünsalan 2025; see EXPERIMENTS.md for deltas)\n");
}
