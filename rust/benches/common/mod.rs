//! Shared bench setup (each bench is its own crate; this module is
//! `#[path]`-included).

use std::path::PathBuf;

use bnn_fpga::bnn::BnnModel;
use bnn_fpga::data::Dataset;
use bnn_fpga::{artifacts_dir, mem};

pub fn load() -> (BnnModel, Dataset, PathBuf) {
    let dir = artifacts_dir();
    let model = mem::load_model(&dir.join("weights.json"))
        .expect("run `make artifacts` before `cargo bench`");
    let ds = Dataset::load_mem_subset(&dir.join("mem")).expect("mem subset");
    (model, ds, dir)
}

/// Where benches drop CSV/series output.
#[allow(dead_code)]
pub fn out_dir() -> PathBuf {
    let d = PathBuf::from("bench_out");
    std::fs::create_dir_all(&d).ok();
    d
}

#[allow(dead_code)]
pub fn paper_row_note() {
    println!("(paper values quoted from Ertörer & Ünsalan 2025; see EXPERIMENTS.md for deltas)\n");
}
