//! Table 1: latency, speedup, and resource usage for every parallelism ×
//! memory-style configuration.  Latency/speedup are **executed** on the
//! cycle-accurate simulator; LUT/FF/BRAM and power come from the estimator
//! stack (Vivado anchors + activity model — DESIGN.md §Substitutions).

#[path = "common/mod.rs"]
mod common;

use bnn_fpga::estimate::{power, resources};
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::table::{fmt_thousands, Align, Table};
use bnn_fpga::BNN_DIMS;

/// Paper Table 1 for side-by-side printing.
const PAPER: [(usize, &str, u64, f64); 13] = [
    (1, "BRAM", 1_096_045, 1.00),
    (1, "LUT", 1_096_035, 1.00),
    (4, "BRAM", 274_465, 4.00),
    (4, "LUT", 274_455, 4.00),
    (8, "BRAM", 137_645, 7.96),
    (8, "LUT", 137_635, 7.96),
    (16, "BRAM", 68_905, 15.90),
    (16, "LUT", 68_895, 15.90),
    (32, "BRAM", 34_865, 31.43),
    (32, "LUT", 34_855, 31.45),
    (64, "BRAM", 17_845, 61.42),
    (64, "LUT", 17_835, 61.45),
    (128, "LUT", 9_865, 111.10),
];

fn main() {
    let (model, ds, _) = common::load();
    let img = &ds.images[0];
    println!("=== Table 1: latency, speedup, resources vs parallelism × memory style ===\n");
    common::paper_row_note();

    let base = {
        let mut acc = Accelerator::new(&model, SimConfig::new(1, MemStyle::Bram)).unwrap();
        acc.run_image(img).latency_ns
    };

    let mut t = Table::new(&[
        "Parallelism", "Latency (ns)", "paper", "Speedup", "paper", "LUTs (%)", "FFs (%)",
        "BRAMs (%)", "Power (W)", "Dyn/Static", "Memory",
    ])
    .align(10, Align::Left);

    for (i, cfg) in SimConfig::table1_rows().into_iter().enumerate() {
        let mut acc = Accelerator::new(&model, cfg).unwrap();
        let r = acc.run_image(img);
        let res = resources::best(&BNN_DIMS, cfg.parallelism, cfg.mem_style);
        let pow = power::estimate(&BNN_DIMS, &cfg);
        let (pp, pstyle, pns, pspeed) = PAPER[i];
        assert_eq!((pp, pstyle), (cfg.parallelism, cfg.mem_style.name()));
        t.row(vec![
            cfg.parallelism.to_string(),
            fmt_thousands(r.latency_ns as u64),
            fmt_thousands(pns),
            format!("{:.2}", base / r.latency_ns),
            format!("{pspeed:.2}"),
            format!("{:.2}", res.lut_pct()),
            format!("{:.2}", res.ff_pct()),
            format!("{:.2}", res.bram_pct()),
            format!("{:.3}", pow.total_w),
            format!("{:.0}/{:.0}", pow.dynamic_pct(), pow.static_pct()),
            cfg.mem_style.name().into(),
        ]);
    }
    t.print();

    println!(
        "\n§4.2.1: BRAM-based design unsynthesizable beyond P=64 (demand {} blocks > 132 usable \
         with no LUT fallback); 128 is LUT-only; >128 fails — reproduced by resources::estimate.",
        resources::bram_demand(&BNN_DIMS, 128)
    );
}
