//! Table 3: power and junction-temperature estimates across configurations,
//! from the activity-based model (coefficients fitted to the paper's rows;
//! per-row deltas in EXPERIMENTS.md).

#[path = "common/mod.rs"]
mod common;

use bnn_fpga::estimate::power;
use bnn_fpga::sim::SimConfig;
use bnn_fpga::util::table::{Align, Table};
use bnn_fpga::BNN_DIMS;

/// Paper Table 3: (total W, junction °C, dyn %).
const PAPER: [(f64, f64, u32); 13] = [
    (0.103, 25.5, 5),
    (0.106, 25.5, 9),
    (0.111, 25.5, 10),
    (0.119, 25.5, 19),
    (0.127, 25.6, 20),
    (0.115, 25.5, 16),
    (0.183, 25.8, 43),
    (0.142, 25.6, 32),
    (0.633, 27.9, 83),
    (0.147, 25.7, 34),
    (0.617, 27.8, 83),
    (0.156, 25.7, 37),
    (0.179, 25.8, 46),
];

fn main() {
    println!("=== Table 3: post-implementation power and temperature estimates ===\n");
    common::paper_row_note();
    let mut t = Table::new(&[
        "Parallelization", "Total Power (W)", "paper", "Junction (°C)", "paper",
        "Dyn/Static (%)", "paper", "Memory",
    ])
    .align(7, Align::Left);
    let mut max_err: f64 = 0.0;
    for (i, cfg) in SimConfig::table1_rows().into_iter().enumerate() {
        let r = power::estimate(&BNN_DIMS, &cfg);
        let (pw, pj, pdyn) = PAPER[i];
        max_err = max_err.max((r.total_w - pw).abs() / pw);
        t.row(vec![
            cfg.parallelism.to_string(),
            format!("{:.3}", r.total_w),
            format!("{pw:.3}"),
            format!("{:.1}", r.junction_c),
            format!("{pj:.1}"),
            format!("{:.0}/{:.0}", r.dynamic_pct(), r.static_pct()),
            format!("{pdyn}/{}", 100 - pdyn),
            cfg.mem_style.name().into(),
        ]);
    }
    t.print();
    println!("\nmax total-power error vs paper: {:.1}%", max_err * 100.0);
    println!("§4.4 shape checks: BRAM power jumps into the >0.6 W regime at 32–64×;");
    println!("LUT designs stay ≤0.18 W and ≤25.8 °C; junction T = 25 °C + 4.6 °C/W × P.");
}
