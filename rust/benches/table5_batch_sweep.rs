//! Table 5 (§4.7.2): inference latency vs batch size on CPU and GPU — plus
//! the native-engine extension: scalar vs blocked vs weight-stationary
//! tiled vs simd vs fused threshold-pack vs streaming layer-pipelined
//! kernels and 1-vs-N worker pools over the same batch ladder.  Every batch-capable tier is asserted
//! bit-identical to the scalar reference and the cycle-accurate simulator
//! before any timing is reported.
//!
//! The CPU column is **measured** by executing the batched AOT artifacts on
//! the PJRT CPU client (the paper used TF on a Colab Xeon) when the runtime
//! and artifacts are available, and skipped otherwise; the GPU column is
//! the calibrated T4 batch-scaling model (no GPU in this environment —
//! DESIGN.md §Substitutions).  The FPGA design point is appended for the
//! §4.7.2 narrative.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use bnn_fpga::bnn::{DEFAULT_BLOCK_ROWS, DEFAULT_RING_CAP, DEFAULT_TILE_IMGS};
use bnn_fpga::coordinator::{BatcherConfig, Engine, Kernel};
use bnn_fpga::estimate::gpu_model::GpuModel;
use bnn_fpga::runtime::Engine as PjrtRuntime;
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::bench::Bench;
use bnn_fpga::util::stats::Summary;
use bnn_fpga::util::table::{Align, Table};

const BATCHES: [usize; 5] = [1, 10, 100, 1000, 10000];

/// Paper Table 5 means (ms): (cpu, gpu) per batch.
const PAPER: [(f64, f64); 5] = [(1.60, 0.82), (1.01, 0.87), (1.75, 1.22), (6.93, 0.86), (63.02, 1.58)];

fn main() {
    let (model, ds, dir) = common::load();
    let gpu = GpuModel::default();
    let quick = std::env::args().any(|a| a == "--quick");
    let runs = if quick { 10 } else { 30 };

    // Correctness gate before any timing: the tiled kernel must be
    // bit-identical to the per-image scalar reference AND the
    // cycle-accurate simulator on this model.
    {
        let check_n = 16usize;
        let mut inputs = Vec::new();
        for i in 0..check_n {
            inputs.extend_from_slice(&ds.images[i % ds.len()].words);
        }
        let scalar = model.logits_batch(&inputs, check_n);
        let tiled =
            model.logits_batch_tiled(&inputs, check_n, DEFAULT_BLOCK_ROWS, DEFAULT_TILE_IMGS);
        assert_eq!(tiled, scalar, "tiled kernel diverged from the scalar reference");
        let simd =
            model.logits_batch_simd(&inputs, check_n, DEFAULT_BLOCK_ROWS, DEFAULT_TILE_IMGS);
        assert_eq!(
            simd, scalar,
            "simd kernel ({}) diverged from the scalar reference",
            bnn_fpga::bnn::simd_level().name()
        );
        let pre = bnn_fpga::bnn::PreparedModel::new(&model).unwrap();
        let fused = pre.logits_batch(&inputs, check_n, DEFAULT_TILE_IMGS);
        assert_eq!(fused, scalar, "fused kernel diverged from the scalar reference");
        let mut pipelined = vec![0i32; check_n * 10];
        pre.logits_batch_pipelined(&inputs, check_n, &mut pipelined, DEFAULT_RING_CAP);
        assert_eq!(
            pipelined, scalar,
            "pipelined kernel diverged from the scalar reference"
        );
        let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
        for i in 0..check_n {
            let r = acc.run_image(&ds.images[i % ds.len()]);
            assert_eq!(
                r.scores,
                &scalar[i * 10..(i + 1) * 10],
                "simulator diverged from the scalar reference at image {i}"
            );
        }
        println!("tiled + simd + fused + pipelined kernels verified bit-identical to scalar reference and FPGA simulator\n");
    }
    // panel weights prepared once, outside every timed window (as the
    // engine does at build)
    let prepared = bnn_fpga::bnn::PreparedModel::new(&model).unwrap();

    println!("=== Table 5: inference latency vs batch size (CPU measured, GPU modeled) ===\n");
    common::paper_row_note();
    let mut t = Table::new(&[
        "Batch", "Device", "Mean (ms)", "Per Image (ms)", "Std Dev (ms)", "paper mean",
    ])
    .align(1, Align::Left);

    let engine = match PjrtRuntime::load(&dir) {
        Ok(e) => Some(Arc::new(e)),
        Err(e) => {
            println!("CPU (PJRT) column skipped: {e:#}\n");
            None
        }
    };

    let bench = Bench::quick();
    for (bi, &batch) in BATCHES.iter().enumerate() {
        // CPU: real execution through the batch-matched artifact
        if let Some(engine) = &engine {
            let name = format!("bnn_b{batch}");
            engine.prepare(&name).unwrap();
            let mut input = Vec::with_capacity(batch * 25);
            for i in 0..batch {
                input.extend(ds.images[i % ds.len()].to_u32_words());
            }
            let series: Vec<f64> = bench
                .run_series(runs, || engine.run_u32_to_i32(&name, &input).unwrap())
                .iter()
                .map(|ns| ns / 1e6)
                .collect();
            let s = Summary::of(&series);
            t.row(vec![
                batch.to_string(),
                "CPU".into(),
                format!("{:.3}", s.mean),
                format!("{:.5}", s.mean / batch as f64),
                format!("{:.3}", s.std_dev),
                format!("{:.2}", PAPER[bi].0),
            ]);
        }

        // Native engine: scalar vs blocked vs tiled kernel over the same batch
        let batch_inputs = {
            let mut v = Vec::new();
            for i in 0..batch {
                v.extend_from_slice(&ds.images[i % ds.len()].words);
            }
            v
        };
        for (label, kernel) in [
            ("native scalar", Kernel::Scalar),
            (
                "native blocked",
                Kernel::Blocked {
                    block_rows: DEFAULT_BLOCK_ROWS,
                },
            ),
            (
                "native tiled",
                Kernel::Tiled {
                    block_rows: DEFAULT_BLOCK_ROWS,
                    tile_imgs: DEFAULT_TILE_IMGS,
                },
            ),
            (
                "native simd",
                Kernel::Simd {
                    block_rows: DEFAULT_BLOCK_ROWS,
                    tile_imgs: DEFAULT_TILE_IMGS,
                },
            ),
            (
                "native fused",
                Kernel::Fused {
                    tile_imgs: DEFAULT_TILE_IMGS,
                },
            ),
            (
                "native pipelined",
                Kernel::Pipelined {
                    ring_cap: DEFAULT_RING_CAP,
                },
            ),
        ] {
            let series: Vec<f64> = bench
                .run_series(runs.min(15), || match kernel {
                    Kernel::Scalar => model.logits_batch(&batch_inputs, batch),
                    Kernel::Blocked { block_rows } => {
                        model.logits_batch_blocked(&batch_inputs, batch, block_rows)
                    }
                    Kernel::Tiled {
                        block_rows,
                        tile_imgs,
                    } => model.logits_batch_tiled(&batch_inputs, batch, block_rows, tile_imgs),
                    Kernel::Simd {
                        block_rows,
                        tile_imgs,
                    } => model.logits_batch_simd(&batch_inputs, batch, block_rows, tile_imgs),
                    Kernel::Fused { tile_imgs } => {
                        prepared.logits_batch(&batch_inputs, batch, tile_imgs)
                    }
                    Kernel::Pipelined { ring_cap } => {
                        let mut out = vec![0i32; batch * model.n_classes()];
                        prepared.logits_batch_pipelined(&batch_inputs, batch, &mut out, ring_cap);
                        out
                    }
                })
                .iter()
                .map(|ns| ns / 1e6)
                .collect();
            let s = Summary::of(&series);
            t.row(vec![
                batch.to_string(),
                label.into(),
                format!("{:.3}", s.mean),
                format!("{:.5}", s.mean / batch as f64),
                format!("{:.3}", s.std_dev),
                "-".into(),
            ]);
        }

        // GPU: calibrated model with deterministic jitter
        let g = Summary::of(&gpu.sample_series(batch, runs, 99));
        t.row(vec![
            batch.to_string(),
            "GPU*".into(),
            format!("{:.3}", g.mean),
            format!("{:.5}", g.mean / batch as f64),
            format!("{:.3}", g.std_dev),
            format!("{:.2}", PAPER[bi].1),
        ]);
    }
    t.print();
    println!("\n* GPU column is the calibrated T4 model (no GPU in this environment).");

    // 1-vs-N worker pools over the request path (queue + batcher included),
    // tiled kernel, offered load = the Table 5 batch ladder.
    println!("\n=== worker-pool batch sweep (tiled kernel, end-to-end request path) ===\n");
    let mut pt = Table::new(&["Requests", "Workers", "Wall (ms)", "Throughput (req/s)", "Speedup"]);
    for &n in &[1000usize, 10000] {
        let n = if quick { n / 10 } else { n };
        let images: Vec<_> = (0..n).map(|i| ds.images[i % ds.len()].clone()).collect();
        let mut base = 0.0f64;
        for workers in [1usize, 2, 4] {
            let pool = Engine::builder()
                .native(&model)
                .kernel(Kernel::default())
                .workers(workers)
                .batcher(BatcherConfig {
                    max_batch: 64,
                    max_wait: Duration::from_micros(100),
                })
                .build()
                .unwrap();
            let input = images.clone(); // clone outside the timed window
            let t0 = Instant::now();
            pool.infer_many(input).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            pool.shutdown();
            let rps = n as f64 / wall;
            if workers == 1 {
                base = rps;
            }
            pt.row(vec![
                n.to_string(),
                workers.to_string(),
                format!("{:.1}", wall * 1e3),
                format!("{rps:.0}"),
                format!("{:.2}x", rps / base),
            ]);
        }
    }
    pt.print();

    // FPGA design point for the §4.7.2 comparison sentence
    let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    let fpga = acc.run_image(&ds.images[0]);
    println!(
        "\nFPGA (64x BRAM): {:.1} µs/image at 0.6 W — beats CPU at batch 1 \
         ({:.1}x), loses to GPU only at large batch (paper's conclusion).",
        fpga.latency_ns / 1e3,
        PAPER[0].0 * 1e3 / (fpga.latency_ns / 1e3)
    );
}
