//! Table 5 (§4.7.2): inference latency vs batch size on CPU and GPU.
//!
//! The CPU column is **measured** by executing the batched AOT artifacts on
//! the PJRT CPU client (the paper used TF on a Colab Xeon); the GPU column
//! is the calibrated T4 batch-scaling model (no GPU in this environment —
//! DESIGN.md §Substitutions).  The FPGA design point is appended for the
//! §4.7.2 narrative.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use bnn_fpga::estimate::gpu_model::GpuModel;
use bnn_fpga::runtime::Engine;
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::bench::Bench;
use bnn_fpga::util::stats::Summary;
use bnn_fpga::util::table::{Align, Table};

const BATCHES: [usize; 5] = [1, 10, 100, 1000, 10000];

/// Paper Table 5 means (ms): (cpu, gpu) per batch.
const PAPER: [(f64, f64); 5] = [(1.60, 0.82), (1.01, 0.87), (1.75, 1.22), (6.93, 0.86), (63.02, 1.58)];

fn main() {
    let (model, ds, dir) = common::load();
    let engine = Arc::new(Engine::load(&dir).unwrap());
    let gpu = GpuModel::default();
    let quick = std::env::args().any(|a| a == "--quick");
    let runs = if quick { 10 } else { 30 };

    println!("=== Table 5: inference latency vs batch size (CPU measured, GPU modeled) ===\n");
    common::paper_row_note();
    let mut t = Table::new(&[
        "Batch", "Device", "Mean (ms)", "Per Image (ms)", "Std Dev (ms)", "paper mean",
    ])
    .align(1, Align::Left);

    let bench = Bench::quick();
    for (bi, &batch) in BATCHES.iter().enumerate() {
        // CPU: real execution through the batch-matched artifact
        let name = format!("bnn_b{batch}");
        engine.prepare(&name).unwrap();
        let mut input = Vec::with_capacity(batch * 25);
        for i in 0..batch {
            input.extend(ds.images[i % ds.len()].to_u32_words());
        }
        let series: Vec<f64> = bench
            .run_series(runs, || engine.run_u32_to_i32(&name, &input).unwrap())
            .iter()
            .map(|ns| ns / 1e6)
            .collect();
        let s = Summary::of(&series);
        t.row(vec![
            batch.to_string(),
            "CPU".into(),
            format!("{:.3}", s.mean),
            format!("{:.5}", s.mean / batch as f64),
            format!("{:.3}", s.std_dev),
            format!("{:.2}", PAPER[bi].0),
        ]);

        // GPU: calibrated model with deterministic jitter
        let g = Summary::of(&gpu.sample_series(batch, runs, 99));
        t.row(vec![
            batch.to_string(),
            "GPU*".into(),
            format!("{:.3}", g.mean),
            format!("{:.5}", g.mean / batch as f64),
            format!("{:.3}", g.std_dev),
            format!("{:.2}", PAPER[bi].1),
        ]);
    }
    t.print();
    println!("\n* GPU column is the calibrated T4 model (no GPU in this environment).");

    // FPGA design point for the §4.7.2 comparison sentence
    let mut acc = Accelerator::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
    let fpga = acc.run_image(&ds.images[0]);
    println!(
        "\nFPGA (64x BRAM): {:.1} µs/image at 0.6 W — beats CPU at batch 1 \
         ({:.1}x), loses to GPU only at large batch (paper's conclusion).",
        fpga.latency_ns / 1e3,
        PAPER[0].0 * 1e3 / (fpga.latency_ns / 1e3)
    );
}
