//! Table 4 + Fig. 1 (§4.6): BNN vs CNN CPU inference latency over 100
//! batch-1 runs, measured live through the AOT PJRT artifacts; plus the
//! model-size and training-time comparison from the build log.
//!
//! Output: the Table 4 stats, an ASCII rendering of Fig. 1, and
//! `bench_out/fig1_latency.csv` for external plotting.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use bnn_fpga::runtime::Engine;
use bnn_fpga::util::bench::Bench;
use bnn_fpga::util::plot;
use bnn_fpga::util::stats::Summary;
use bnn_fpga::util::table::{Align, Table};

fn main() {
    let (_model, ds, dir) = common::load();
    let engine = match Engine::load(&dir) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            println!("Table 4 needs the PJRT runtime + AOT artifacts; skipping: {e:#}");
            return;
        }
    };
    engine.prepare("bnn_b1").unwrap();
    engine.prepare("cnn_b1").unwrap();

    // same input for both models, like the paper's fixed test image
    let bnn_input = ds.images[0].to_u32_words();
    let (raw, _, _) =
        bnn_fpga::mem::read_idx_images(&dir.join("data/t10k-images-idx3-ubyte")).unwrap();
    let cnn_input: Vec<f32> = raw[0].iter().map(|&p| p as f32 / 255.0).collect();

    let bench = Bench::default();
    let runs = 100;
    println!("=== Table 4 + Fig. 1: BNN vs CNN CPU latency, {runs} batch-1 runs ===\n");
    common::paper_row_note();

    let bnn_series: Vec<f64> = bench
        .run_series(runs, || engine.run_u32_to_i32("bnn_b1", &bnn_input).unwrap())
        .iter()
        .map(|ns| ns / 1e6)
        .collect();
    let cnn_series: Vec<f64> = bench
        .run_series(runs, || engine.run_f32_to_f32("cnn_b1", &cnn_input).unwrap())
        .iter()
        .map(|ns| ns / 1e6)
        .collect();

    let b = Summary::of(&bnn_series);
    let c = Summary::of(&cnn_series);
    let mut t = Table::new(&[
        "Model", "Mean (ms)", "Min (ms)", "Max (ms)", "Std Dev (ms)", "paper mean",
    ])
    .align(0, Align::Left);
    t.row(vec![
        "BNN".into(),
        format!("{:.4}", b.mean),
        format!("{:.4}", b.min),
        format!("{:.4}", b.max),
        format!("{:.4}", b.std_dev),
        "0.176".into(),
    ]);
    t.row(vec![
        "CNN".into(),
        format!("{:.4}", c.mean),
        format!("{:.4}", c.min),
        format!("{:.4}", c.max),
        format!("{:.4}", c.std_dev),
        "0.213".into(),
    ]);
    t.print();
    println!(
        "\nBNN is {:.0}% faster than CNN (paper: ≈17% on TF/Keras CPU)",
        (c.mean / b.mean - 1.0) * 100.0
    );

    println!("\nFig. 1 — run-by-run latency (ms):\n");
    print!(
        "{}",
        plot::ascii_plot(&[("BNN", &bnn_series), ("CNN", &cnn_series)], 80, 16)
    );
    let csv = plot::to_csv(&[("bnn_ms", &bnn_series), ("cnn_ms", &cnn_series)]);
    let out = common::out_dir().join("fig1_latency.csv");
    std::fs::write(&out, csv).unwrap();
    println!("\nseries written to {}", out.display());

    // §4.6 model size + training time from the build log
    if let Ok(log) = std::fs::read_to_string(dir.join("train_log.json")) {
        let j = bnn_fpga::util::json::Json::parse(&log).unwrap();
        let get = |m: &str, k: &str| j.get(m).unwrap().get(k).unwrap().as_f64().unwrap();
        let bnn_sz = std::fs::metadata(dir.join("params_bnn.npz")).map(|m| m.len()).unwrap_or(0);
        let cnn_sz = std::fs::metadata(dir.join("params_cnn.npz")).map(|m| m.len()).unwrap_or(0);
        println!("\n§4.6 model comparison:");
        println!(
            "  BNN: {:.2}% accuracy, {:.1}s training, {:.2} MB exported   (paper: 87.97%, 15s, 1.4MB)",
            get("bnn", "accuracy") * 100.0,
            get("bnn", "train_seconds"),
            bnn_sz as f64 / 1e6
        );
        println!(
            "  CNN: {:.2}% accuracy, {:.1}s training, {:.2} MB exported   (paper: 99.31%, 71s, 2.7MB)",
            get("cnn", "accuracy") * 100.0,
            get("cnn", "train_seconds"),
            cnn_sz as f64 / 1e6
        );
    }
}
