//! §4.7.1: FPGA vs ASIC (YodaNN) estimate-based comparison — the paper's
//! own arithmetic reproduced from the simulator + power model.

#[path = "common/mod.rs"]
mod common;

use bnn_fpga::estimate::{asic, power};
use bnn_fpga::sim::{Accelerator, MemStyle, SimConfig};
use bnn_fpga::util::table::{Align, Table};
use bnn_fpga::BNN_DIMS;

fn main() {
    let (model, ds, _) = common::load();
    let cfg = SimConfig::new(64, MemStyle::Bram);
    let mut acc = Accelerator::new(&model, cfg).unwrap();
    let r = acc.run_image(&ds.images[0]);
    let pow = power::estimate(&BNN_DIMS, &cfg);

    println!("=== §4.7.1: FPGA vs ASIC (YodaNN) ===\n");
    common::paper_row_note();
    let mut t = Table::new(&[
        "Platform", "Latency (ms)", "Power (W)", "µJ/inference", "Unit cost (USD)",
        "Reconfigurable",
    ])
    .align(0, Align::Left);
    for row in asic::comparison(r.latency_ns / 1e6, pow.total_w) {
        t.row(vec![
            row.platform.into(),
            format!("{:.4}", row.latency_ms),
            format!("{:.5}", row.power_w),
            format!("{:.1}", row.uj_per_inference),
            if row.unit_cost_usd.0 == row.unit_cost_usd.1 {
                format!("~{:.0}", row.unit_cost_usd.0)
            } else {
                format!("{:.0}–{:.0} (+NRE)", row.unit_cost_usd.0, row.unit_cost_usd.1)
            },
            if row.reconfigurable { "yes" } else { "no" }.into(),
        ]);
    }
    t.print();
    println!(
        "\npaper's numbers: FPGA 0.0178 ms / 0.617 W / ≈11.0 µJ; YodaNN 7.5 ms / 0.00034 W / 2.6 µJ"
    );
    println!(
        "inferred ASIC power from the paper's Eq.: 20.1 GOp/s ÷ 59.2 TOp/s/W = {:.5} W",
        asic::yodann_inferred_power_w()
    );
}
