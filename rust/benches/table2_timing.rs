//! Table 2: worst negative slack (WNS) and worst hold slack (WHS) per
//! configuration — Vivado anchors side by side with the structural model
//! (P&R noise makes the published column non-monotonic; §4.3).

#[path = "common/mod.rs"]
mod common;

use bnn_fpga::estimate::timing;
use bnn_fpga::sim::{MemStyle, SimConfig};
use bnn_fpga::util::table::{Align, Table};

fn main() {
    println!("=== Table 2: post-P&R timing slack ===\n");
    common::paper_row_note();
    let mut t = Table::new(&[
        "Parallelization", "WNS (ns)", "WHS (ns)", "model WNS", "model WHS", "Meets 80 MHz",
        "Memory",
    ])
    .align(6, Align::Left);
    for cfg in SimConfig::table1_rows() {
        let anchor = timing::vivado_anchor(cfg.parallelism, cfg.mem_style).unwrap();
        let model = timing::estimate(cfg.parallelism, cfg.mem_style);
        t.row(vec![
            cfg.parallelism.to_string(),
            format!("{:.3}", anchor.wns_ns),
            format!("{:.3}", anchor.whs_ns),
            format!("{:.3}", model.wns_ns),
            format!("{:.3}", model.whs_ns),
            if anchor.meets_80mhz && model.meets_80mhz { "yes" } else { "NO" }.into(),
            cfg.mem_style.name().into(),
        ]);
    }
    t.print();

    // off-grid configurations only the model covers
    println!("\nmodel-only (unpublished) configurations:");
    let mut t2 = Table::new(&["P", "Mem", "WNS (ns)", "WHS (ns)"]).align(1, Align::Left);
    for p in [2usize, 12, 24, 48, 96] {
        for style in [MemStyle::Bram, MemStyle::Lut] {
            if style == MemStyle::Bram && p > 64 {
                continue;
            }
            let m = timing::estimate(p, style);
            t2.row(vec![
                p.to_string(),
                style.name().into(),
                format!("{:.3}", m.wns_ns),
                format!("{:.3}", m.whs_ns),
            ]);
        }
    }
    t2.print();
    println!("\n§4.3 headline: all configurations meet the 80 MHz target (WNS > 0) — holds in both columns.");
}
