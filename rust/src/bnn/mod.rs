//! Bit-packed XNOR-popcount BNN inference (the paper's Algorithm 1 in
//! software — the native backend and the reference the simulator and PJRT
//! paths are checked against).

pub mod conv;
pub mod model;
pub mod packing;
pub mod pipeline;

pub use conv::{conv_out_dim, random_conv_model, BinaryConvLayer, LayerKind};
pub use model::{
    random_model, BinaryDenseLayer, BnnModel, PreparedConvLayer, PreparedModel,
    PreparedPanelLayer, Scratch, DEFAULT_BLOCK_ROWS, DEFAULT_TILE_IMGS, FUSED_PAR_MIN_CHUNK,
};
pub use pipeline::{spsc_ring, RingDisconnected, RingReceiver, RingSender, DEFAULT_RING_CAP};
pub use packing::{
    copy_bits, pack_bits_u32, pack_bits_u64, read_bits, simd_level, splice_bits, unpack_bits_u64,
    words_u32, words_u64, Packed, SimdLevel, PANEL_ROWS,
};

/// Argmax with lowest-index tie-break — exactly the FSM's iterative
/// comparison (§3.4: "identifies the class index with the highest output
/// score through iterative comparison", strict `>` keeps the first max).
pub fn argmax_i32(scores: &[i32]) -> usize {
    let mut best = 0usize;
    for (i, &s) in scores.iter().enumerate().skip(1) {
        if s > scores[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ties_prefer_lowest_index() {
        assert_eq!(argmax_i32(&[1, 3, 3, 2]), 1);
        assert_eq!(argmax_i32(&[5]), 0);
        assert_eq!(argmax_i32(&[-4, -2, -2]), 1);
    }
}
