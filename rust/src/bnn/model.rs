//! The BNN model: layers of packed weight rows + folded thresholds.
//!
//! This is the native software implementation of the paper's Algorithm 1 —
//! the semantics reference for the FPGA simulator (`sim`) and the check
//! against the PJRT artifacts (`runtime`).  The hot path
//! ([`BnnModel::logits_into`]) is allocation-free.

use anyhow::{bail, Result};

use super::conv::BinaryConvLayer;
use super::packing;
use crate::util::prng::Xoshiro256;

/// Default row-block size for the blocked kernel path — chosen from the
/// `hotpath` bench sweep (register-tile multiples; 16 rows keeps the tile
/// loop hot without spilling) and mirroring the paper's mid-range
/// parallelism sweet spot.  Override per deployment via `--block-rows` /
/// `[coordinator] block_rows`.
pub const DEFAULT_BLOCK_ROWS: usize = 16;

/// Default image-tile width for the weight-stationary batch kernel path
/// ([`BnnModel::logits_batch_into_tiled`]): how many images stream past
/// each weight-row block per pass.  8 keeps a full dynamic batch inside
/// one or two tiles at typical serve batch sizes while the per-tile
/// activation arena (`8 × max_act_words` words) stays L1-resident.
/// Override per deployment via `--tile-imgs` / `[coordinator] tile_imgs`.
pub const DEFAULT_TILE_IMGS: usize = 8;

/// Minimum images per scoped thread before the fused batch walk
/// ([`PreparedModel::logits_batch_into`]) splits a batch across
/// `std::thread::scope` threads.  Serving batches (bounded by the
/// batcher's `max_batch`, typically ≤ 64) stay on the worker's own thread
/// — the split targets large offline/bench batches, where thread-spawn
/// cost amortizes over ≥ this many images per thread.
pub const FUSED_PAR_MIN_CHUNK: usize = 128;

/// One binary dense layer: `n_out` packed weight rows (neuron-major — the
/// paper's transposed ROM layout) and, for hidden layers, folded integer
/// thresholds.
#[derive(Clone, Debug)]
pub struct BinaryDenseLayer {
    pub n_in: usize,
    pub n_out: usize,
    /// Row-major: `n_out` rows × `words_per_row` u64 words.
    pub weights: Vec<u64>,
    pub words_per_row: usize,
    /// `Some` for hidden layers (activation = z ≥ θ), `None` for the output
    /// layer (raw sums retained, §3.4).
    pub thresholds: Option<Vec<i32>>,
}

impl BinaryDenseLayer {
    /// Build from per-row u32 interchange words (weights.json layout).
    pub fn from_u32_rows(
        n_in: usize,
        rows: &[Vec<u32>],
        thresholds: Option<Vec<i32>>,
    ) -> Result<Self> {
        let words_per_row = packing::words_u64(n_in);
        let mut weights = Vec::with_capacity(rows.len() * words_per_row);
        for row in rows {
            if row.len() != packing::words_u32(n_in) {
                bail!(
                    "weight row has {} u32 words, expected {}",
                    row.len(),
                    packing::words_u32(n_in)
                );
            }
            weights.extend(packing::u32_words_to_u64(row, n_in));
        }
        if let Some(t) = &thresholds {
            if t.len() != rows.len() {
                bail!("{} thresholds for {} neurons", t.len(), rows.len());
            }
        }
        Ok(Self {
            n_in,
            n_out: rows.len(),
            weights,
            words_per_row,
            thresholds,
        })
    }

    /// Weight row for neuron `j` as a word slice.
    #[inline]
    pub fn row(&self, j: usize) -> &[u64] {
        &self.weights[j * self.words_per_row..(j + 1) * self.words_per_row]
    }

    /// Pre-activation sum for neuron `j`: `z = n − 2·popcount(x ⊕ w_j)`.
    #[inline]
    pub fn z(&self, x_words: &[u64], j: usize) -> i32 {
        packing::xnor_popcount_z(x_words, self.row(j), self.n_in)
    }

    /// Pre-activation sums for the `out.len()` neurons starting at `first`,
    /// in one blocked pass over the input
    /// ([`packing::xnor_popcount_z_block`]).  Bit-identical to calling
    /// [`Self::z`] per neuron.
    #[inline]
    pub fn z_block(&self, x_words: &[u64], first: usize, out: &mut [i32]) {
        let rows =
            &self.weights[first * self.words_per_row..(first + out.len()) * self.words_per_row];
        packing::xnor_popcount_z_block(x_words, rows, self.words_per_row, self.n_in, out);
    }
}

/// A full network: an optional binary-convolution prefix
/// ([`BinaryConvLayer`], format v2), then hidden dense layers
/// (thresholded), then one logits layer.  Dense-only models (`conv`
/// empty) are exactly the v1 format and behave byte-identically.
#[derive(Clone, Debug)]
pub struct BnnModel {
    /// Conv prefix, executed first (may be empty).  Every conv layer is
    /// thresholded; the last one's `out_bits()` must equal
    /// `layers[0].n_in`.
    pub conv: Vec<BinaryConvLayer>,
    /// The dense stack (hidden + output) — non-empty, exactly as v1.
    pub layers: Vec<BinaryDenseLayer>,
}

/// Reusable per-inference scratch to keep the hot path allocation-free.
///
/// One instance serves every kernel schedule: the single-image paths use
/// the `a`/`b` ping-pong buffers, the batch-tiled path
/// ([`BnnModel::logits_batch_into_tiled`]) uses the flat activation arenas
/// `ta`/`tb` (`tile_imgs` images × per-layer word stride, swapped by
/// pointer between layers) plus the `zt` pre-activation tile.  The fused
/// path ([`PreparedModel::logits_batch_into`]) needs only `ta`/`tb`:
/// its hidden-layer sums never leave registers, so `zt` (and the per-tile
/// `i32` traffic it implies) stays empty — the slimmest steady state of
/// any schedule.  All buffers grow to their steady-state size on first
/// use and are reused thereafter, so a worker that owns one `Scratch`
/// performs zero forward-pass allocations after warmup.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    a: Vec<u64>,
    b: Vec<u64>,
    /// Per-block pre-activation sums (blocked path only).
    z: Vec<i32>,
    /// Tiled path: flat packed-activation arena, `tile_imgs × words` row-major.
    ta: Vec<u64>,
    /// Tiled path: the other half of the layer ping-pong.
    tb: Vec<u64>,
    /// Tiled path: `tile_imgs × block_rows` pre-activation sums.
    zt: Vec<i32>,
    /// Conv front: reusable im2col patch arena (one packed patch row).
    patch: Vec<u64>,
    /// Conv front: packed-activation ping-pong between chained conv layers
    /// (only grown when the model has ≥ 2 conv layers).
    ca: Vec<u64>,
    /// Conv front: the other half of the conv-chain ping-pong.
    cb: Vec<u64>,
    /// Conv front, batch paths: flat dense-level input arena
    /// (`batch × dense_input_words`), filled once per batch so the dense
    /// walk runs unchanged over it.
    cf: Vec<u64>,
}

impl BnnModel {
    /// Dense-only model (the v1 format) — the conv prefix stays empty.
    pub fn dense(layers: Vec<BinaryDenseLayer>) -> Self {
        Self {
            conv: Vec::new(),
            layers,
        }
    }

    /// Mixed conv→dense model (format v2).  Call [`Self::validate`] after
    /// construction — the chain geometry is checked there.
    pub fn with_conv(conv: Vec<BinaryConvLayer>, layers: Vec<BinaryDenseLayer>) -> Self {
        Self { conv, layers }
    }

    /// Validate layer chaining: conv layers (if any) chain spatially and
    /// flatten into the first dense layer; dense layer i's n_out feeds
    /// layer i+1's n_in; all hidden layers thresholded, output layer not.
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("empty model (the dense stack must hold at least the output layer)");
        }
        for (i, cl) in self.conv.iter().enumerate() {
            if let Err(e) = cl.validate() {
                bail!("conv layer {i}: {e}");
            }
            if i + 1 < self.conv.len() {
                let next = &self.conv[i + 1];
                let out_shape = (cl.out_ch(), cl.out_h(), cl.out_w());
                let in_shape = (next.in_ch, next.in_h, next.in_w);
                if out_shape != in_shape {
                    bail!(
                        "conv layer {i} outputs {}×{}×{} but conv layer {} expects {}×{}×{}",
                        out_shape.0,
                        out_shape.1,
                        out_shape.2,
                        i + 1,
                        in_shape.0,
                        in_shape.1,
                        in_shape.2
                    );
                }
            }
        }
        if let Some(last) = self.conv.last() {
            if last.out_bits() != self.layers[0].n_in {
                bail!(
                    "conv prefix flattens to {} bits but the first dense layer expects {}",
                    last.out_bits(),
                    self.layers[0].n_in
                );
            }
        }
        for (i, pair) in self.layers.windows(2).enumerate() {
            if pair[0].n_out != pair[1].n_in {
                bail!(
                    "layer {} outputs {} but layer {} expects {}",
                    i,
                    pair[0].n_out,
                    i + 1,
                    pair[1].n_in
                );
            }
            if pair[0].thresholds.is_none() {
                bail!("hidden layer {i} missing thresholds");
            }
        }
        if self.layers.last().unwrap().thresholds.is_some() {
            bail!("output layer must not have thresholds (raw sums, §3.4)");
        }
        Ok(())
    }

    /// Model input width in bits: the conv prefix's image bits
    /// (`C_in·H·W`) when present, else the first dense layer's `n_in`.
    pub fn n_in(&self) -> usize {
        self.conv.first().map_or(self.layers[0].n_in, |c| c.in_bits())
    }

    pub fn n_classes(&self) -> usize {
        self.layers.last().unwrap().n_out
    }

    pub fn input_words(&self) -> usize {
        packing::words_u64(self.n_in())
    }

    /// The dense stack's input width in bits (= the conv prefix's
    /// flattened output; equals [`Self::n_in`] for dense-only models).
    #[inline]
    pub fn dense_n_in(&self) -> usize {
        self.layers[0].n_in
    }

    /// Packed words per dense-level input row
    /// (`words_u64(dense_n_in())`).
    #[inline]
    pub fn dense_input_words(&self) -> usize {
        packing::words_u64(self.dense_n_in())
    }

    /// Total layer count across the conv prefix and the dense stack.
    #[inline]
    pub fn n_layers(&self) -> usize {
        self.conv.len() + self.layers.len()
    }

    /// Input image geometry `(channels, height, width)` — `Some` only for
    /// conv models, where the spatial shape is part of the format.
    pub fn input_geometry(&self) -> Option<(usize, usize, usize)> {
        self.conv.first().map(|c| (c.in_ch, c.in_h, c.in_w))
    }

    /// Widest packed activation buffer needed between layers (incl. input).
    #[inline]
    pub fn max_act_words(&self) -> usize {
        let dense = self
            .layers
            .iter()
            .map(|l| packing::words_u64(l.n_out).max(packing::words_u64(l.n_in)))
            .max()
            .unwrap();
        let conv = self
            .conv
            .iter()
            .map(|c| packing::words_u64(c.in_bits()).max(packing::words_u64(c.out_bits())))
            .max()
            .unwrap_or(0);
        dense.max(conv)
    }

    /// Run the conv prefix on one packed image, leaving the dense-level
    /// input in `dst` (`dense_input_words()` words).  Chained conv layers
    /// ping-pong through the `ca`/`cb` arenas; the final layer writes
    /// `dst` directly.  Must only be called when the prefix is non-empty.
    fn conv_front_into(&self, x: &[u64], dst: &mut [u64], scratch: &mut Scratch) {
        let (last, chain) = self.conv.split_last().expect("conv prefix is non-empty");
        if chain.is_empty() {
            return last.forward(x, dst, &mut scratch.patch);
        }
        let mut a = std::mem::take(&mut scratch.ca);
        let mut b = std::mem::take(&mut scratch.cb);
        a.clear();
        a.resize(packing::words_u64(chain[0].out_bits()), 0);
        chain[0].forward(x, &mut a, &mut scratch.patch);
        for cl in &chain[1..] {
            b.clear();
            b.resize(packing::words_u64(cl.out_bits()), 0);
            cl.forward(&a, &mut b, &mut scratch.patch);
            std::mem::swap(&mut a, &mut b);
        }
        last.forward(&a, dst, &mut scratch.patch);
        scratch.ca = a;
        scratch.cb = b;
    }

    /// Conv front over a whole batch into the flat `cf` arena
    /// (`batch × dense_input_words` row-major), returned to the caller so
    /// the dense walk can borrow it alongside `scratch`.  Restore it with
    /// `scratch.cf = cf` when done — the arena (like every `Scratch`
    /// buffer) keeps its high-water capacity across batches.
    fn conv_front_batch(&self, inputs: &[u64], batch: usize, scratch: &mut Scratch) -> Vec<u64> {
        let iw = self.input_words();
        let dw = self.dense_input_words();
        let mut cf = std::mem::take(&mut scratch.cf);
        cf.clear();
        cf.resize(batch * dw, 0);
        for i in 0..batch {
            self.conv_front_into(
                &inputs[i * iw..(i + 1) * iw],
                &mut cf[i * dw..(i + 1) * dw],
                scratch,
            );
        }
        cf
    }

    /// Full forward pass: packed input words → integer logits (allocates).
    pub fn logits(&self, x_words: &[u64]) -> Vec<i32> {
        let mut scratch = Scratch::default();
        let mut out = vec![0i32; self.n_classes()];
        self.logits_into(x_words, &mut scratch, &mut out);
        out
    }

    /// Allocation-free forward pass (steady-state serve loop).
    ///
    /// Perf note (§Perf iteration 2): `max_words` is a per-model constant;
    /// deriving it per call cost an iterator walk per inference in the
    /// batch loop — callers reuse one `Scratch`, so `resize` is a no-op
    /// after the first call.
    ///
    /// This is the scalar (one neuron per pass) semantics reference; the
    /// serving hot path uses [`Self::logits_into_blocked`], which is
    /// asserted bit-identical.
    ///
    /// ```
    /// use bnn_fpga::bnn::model::{random_model, Scratch};
    /// use bnn_fpga::bnn::packing::pack_bits_u64;
    ///
    /// let model = random_model(&[16, 8, 4], 1);
    /// let x = pack_bits_u64(&[1u8; 16]);
    /// let mut scratch = Scratch::default(); // reuse across calls
    /// let mut logits = vec![0i32; 4];
    /// model.logits_into(&x, &mut scratch, &mut logits);
    /// assert_eq!(logits, model.logits(&x));
    /// ```
    pub fn logits_into(&self, x_words: &[u64], scratch: &mut Scratch, out: &mut [i32]) {
        debug_assert_eq!(x_words.len(), self.input_words());
        if self.conv.is_empty() {
            return self.dense_logits_into(x_words, scratch, out);
        }
        // conv front first (batch of 1 through the flat arena), then the
        // unchanged dense walk over the flattened activations
        let cf = self.conv_front_batch(x_words, 1, scratch);
        self.dense_logits_into(&cf, scratch, out);
        scratch.cf = cf;
    }

    /// The dense-stack scalar walk ([`Self::logits_into`] for dense-only
    /// models; the conv front feeds it the flattened activations).
    fn dense_logits_into(&self, x_words: &[u64], scratch: &mut Scratch, out: &mut [i32]) {
        debug_assert_eq!(x_words.len(), self.dense_input_words());
        debug_assert_eq!(out.len(), self.n_classes());
        let max_words = self.max_act_words();
        scratch.a.clear();
        scratch.a.extend_from_slice(x_words);
        scratch.b.resize(max_words, 0);

        for layer in &self.layers {
            match &layer.thresholds {
                Some(thr) => {
                    // hidden layer: threshold and re-pack activations
                    let out_words = packing::words_u64(layer.n_out);
                    scratch.b[..out_words].fill(0);
                    for j in 0..layer.n_out {
                        let z = layer.z(&scratch.a, j);
                        if z >= thr[j] {
                            scratch.b[j / 64] |= 1u64 << (j % 64);
                        }
                    }
                    scratch.a.clear();
                    scratch.a.extend_from_slice(&scratch.b[..out_words]);
                }
                None => {
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = layer.z(&scratch.a, j);
                    }
                }
            }
        }
    }

    /// Blocked forward pass: computes `block_rows` output neurons per pass
    /// over the packed activations — the software analogue of the FPGA's
    /// parallelism parameter `P` (§3.3), via
    /// [`packing::xnor_popcount_z_block`].  Bit-identical to
    /// [`Self::logits_into`]; `block_rows` only changes the compute
    /// schedule, never the result.
    ///
    /// ```
    /// use bnn_fpga::bnn::model::{random_model, Scratch};
    /// use bnn_fpga::bnn::packing::pack_bits_u64;
    ///
    /// let model = random_model(&[784, 128, 64, 10], 7);
    /// let x = pack_bits_u64(&vec![1u8; 784]);
    /// let mut scratch = Scratch::default();
    /// let mut fast = vec![0i32; 10];
    /// model.logits_into_blocked(&x, &mut scratch, &mut fast, 16);
    /// assert_eq!(fast, model.logits(&x)); // bit-identical to the scalar path
    /// ```
    pub fn logits_into_blocked(
        &self,
        x_words: &[u64],
        scratch: &mut Scratch,
        out: &mut [i32],
        block_rows: usize,
    ) {
        assert!(block_rows >= 1, "block_rows must be ≥ 1");
        debug_assert_eq!(x_words.len(), self.input_words());
        if self.conv.is_empty() {
            return self.dense_logits_into_blocked(x_words, scratch, out, block_rows);
        }
        let cf = self.conv_front_batch(x_words, 1, scratch);
        self.dense_logits_into_blocked(&cf, scratch, out, block_rows);
        scratch.cf = cf;
    }

    /// The dense-stack blocked walk (see [`Self::logits_into_blocked`]).
    fn dense_logits_into_blocked(
        &self,
        x_words: &[u64],
        scratch: &mut Scratch,
        out: &mut [i32],
        block_rows: usize,
    ) {
        debug_assert_eq!(x_words.len(), self.dense_input_words());
        debug_assert_eq!(out.len(), self.n_classes());
        let max_words = self.max_act_words();
        scratch.a.clear();
        scratch.a.extend_from_slice(x_words);
        scratch.b.resize(max_words, 0);
        scratch.z.resize(block_rows, 0);

        for layer in &self.layers {
            match &layer.thresholds {
                Some(thr) => {
                    let out_words = packing::words_u64(layer.n_out);
                    scratch.b[..out_words].fill(0);
                    let mut j = 0;
                    while j < layer.n_out {
                        let b = block_rows.min(layer.n_out - j);
                        layer.z_block(&scratch.a, j, &mut scratch.z[..b]);
                        for (k, &z) in scratch.z[..b].iter().enumerate() {
                            if z >= thr[j + k] {
                                scratch.b[(j + k) / 64] |= 1u64 << ((j + k) % 64);
                            }
                        }
                        j += b;
                    }
                    scratch.a.clear();
                    scratch.a.extend_from_slice(&scratch.b[..out_words]);
                }
                None => {
                    let mut j = 0;
                    while j < layer.n_out {
                        let b = block_rows.min(layer.n_out - j);
                        layer.z_block(&scratch.a, j, &mut out[j..j + b]);
                        j += b;
                    }
                }
            }
        }
    }

    /// Blocked forward pass, allocating convenience (tests/tools).
    pub fn logits_blocked(&self, x_words: &[u64], block_rows: usize) -> Vec<i32> {
        let mut scratch = Scratch::default();
        let mut out = vec![0i32; self.n_classes()];
        self.logits_into_blocked(x_words, &mut scratch, &mut out, block_rows);
        out
    }

    /// Predicted digit for one packed input (allocating convenience over
    /// [`Self::predict_into`]).
    pub fn predict(&self, x_words: &[u64]) -> usize {
        let mut scratch = Scratch::default();
        let mut logits = vec![0i32; self.n_classes()];
        self.predict_into(x_words, &mut scratch, &mut logits)
    }

    /// Allocation-free single-image predict: [`Self::logits_into`] into a
    /// caller-owned logits row, then top-1 ([`super::argmax_i32`]).
    /// `logits` must hold `n_classes` entries.  Steady-state single-image
    /// callers (the v1 wire path serves through
    /// `InferOptions::digits_only`, and the CLI `infer` loop reuses worker
    /// arenas) lean on this so [`Self::predict`]'s per-call `Vec` never
    /// appears on a hot path.
    ///
    /// ```
    /// use bnn_fpga::bnn::model::{random_model, Scratch};
    /// use bnn_fpga::bnn::packing::pack_bits_u64;
    ///
    /// let model = random_model(&[784, 128, 64, 10], 1);
    /// let x = pack_bits_u64(&vec![1u8; 784]);
    /// let mut scratch = Scratch::default(); // reuse across calls
    /// let mut logits = vec![0i32; 10];
    /// let digit = model.predict_into(&x, &mut scratch, &mut logits);
    /// assert_eq!(digit, model.predict(&x));
    /// assert_eq!(logits, model.logits(&x)); // the row is the full logits
    /// ```
    pub fn predict_into(
        &self,
        x_words: &[u64],
        scratch: &mut Scratch,
        logits: &mut [i32],
    ) -> usize {
        self.logits_into(x_words, scratch, logits);
        super::argmax_i32(logits)
    }

    /// Batch inference: `inputs` is `batch × input_words` row-major; returns
    /// `batch × n_classes` logits row-major.
    pub fn logits_batch(&self, inputs: &[u64], batch: usize) -> Vec<i32> {
        let iw = self.input_words();
        assert_eq!(inputs.len(), batch * iw, "batch input length");
        let mut scratch = Scratch::default();
        let nc = self.n_classes();
        let mut out = vec![0i32; batch * nc];
        for b in 0..batch {
            self.logits_into(
                &inputs[b * iw..(b + 1) * iw],
                &mut scratch,
                &mut out[b * nc..(b + 1) * nc],
            );
        }
        out
    }

    /// Batch inference through the blocked kernel (layout as
    /// [`Self::logits_batch`]).
    pub fn logits_batch_blocked(&self, inputs: &[u64], batch: usize, block_rows: usize) -> Vec<i32> {
        let iw = self.input_words();
        assert_eq!(inputs.len(), batch * iw, "batch input length");
        let mut scratch = Scratch::default();
        let nc = self.n_classes();
        let mut out = vec![0i32; batch * nc];
        for b in 0..batch {
            self.logits_into_blocked(
                &inputs[b * iw..(b + 1) * iw],
                &mut scratch,
                &mut out[b * nc..(b + 1) * nc],
                block_rows,
            );
        }
        out
    }

    /// Weight-stationary batch-tiled forward pass — the serving hot path.
    ///
    /// Where [`Self::logits_batch`] re-walks the entire packed weight
    /// matrix once per image, this pass streams the batch through the
    /// weights in `tile_imgs`-image tiles: per layer, each `block_rows`
    /// weight-row block is loaded once per **tile** and XNOR'd against
    /// every image in it ([`packing::xnor_popcount_z_tile`]), cutting
    /// weight-matrix traversals by `tile_imgs×` (DESIGN.md §Batch tiling).
    ///
    /// Layout: `inputs` is `batch × input_words` row-major (as
    /// [`Self::logits_batch`]); `out` is `batch × n_classes` row-major.
    /// All intermediate state lives in `scratch`'s flat activation arenas,
    /// so the call performs **zero allocations** once `scratch` has warmed
    /// up.  Bit-identical to the scalar reference for every batch size and
    /// tile shape — `block_rows`/`tile_imgs` only change the compute
    /// schedule, never the result (property-tested below and asserted
    /// against the cycle-accurate simulator in
    /// `rust/tests/integration.rs`).
    ///
    /// ```
    /// use bnn_fpga::bnn::model::{random_model, Scratch};
    /// use bnn_fpga::bnn::packing::pack_bits_u64;
    ///
    /// let model = random_model(&[784, 128, 64, 10], 7);
    /// let mut inputs = Vec::new();
    /// for seed in 0..3u8 {
    ///     inputs.extend(pack_bits_u64(&vec![seed & 1; 784]));
    /// }
    /// let mut scratch = Scratch::default(); // reuse across batches
    /// let mut tiled = vec![0i32; 3 * 10];
    /// model.logits_batch_into_tiled(&inputs, 3, &mut scratch, &mut tiled, 16, 8);
    /// assert_eq!(tiled, model.logits_batch(&inputs, 3)); // bit-identical
    /// ```
    pub fn logits_batch_into_tiled(
        &self,
        inputs: &[u64],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut [i32],
        block_rows: usize,
        tile_imgs: usize,
    ) {
        self.logits_batch_into_with(
            inputs,
            batch,
            scratch,
            out,
            block_rows,
            tile_imgs,
            packing::xnor_popcount_z_tile,
        )
    }

    /// Explicitly vectorized batch forward pass — `Kernel::Simd`.
    ///
    /// The same weight-stationary walk as [`Self::logits_batch_into_tiled`]
    /// (identical `Scratch` arenas, tile schedule and layout contracts),
    /// with every `tile_imgs × block_rows` pre-activation tile computed by
    /// [`packing::xnor_popcount_z_simd`]: AVX2 on x86_64, NEON on aarch64
    /// (runtime-detected, [`packing::simd_level`]), the tiled kernel on
    /// other targets or under `BNN_FORCE_SCALAR=1`.  Bit-identical to the
    /// scalar reference on every path — the vector level only changes how
    /// popcounts are computed, never the result (pinned by the
    /// golden-vector and differential suites in
    /// `rust/tests/kernel_conformance.rs`).
    ///
    /// ```
    /// use bnn_fpga::bnn::model::{random_model, Scratch};
    /// use bnn_fpga::bnn::packing::pack_bits_u64;
    ///
    /// let model = random_model(&[784, 128, 64, 10], 7);
    /// let mut inputs = Vec::new();
    /// for seed in 0..3u8 {
    ///     inputs.extend(pack_bits_u64(&vec![seed & 1; 784]));
    /// }
    /// let mut scratch = Scratch::default(); // reuse across batches
    /// let mut simd = vec![0i32; 3 * 10];
    /// model.logits_batch_into_simd(&inputs, 3, &mut scratch, &mut simd, 16, 8);
    /// assert_eq!(simd, model.logits_batch(&inputs, 3)); // bit-identical
    /// ```
    pub fn logits_batch_into_simd(
        &self,
        inputs: &[u64],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut [i32],
        block_rows: usize,
        tile_imgs: usize,
    ) {
        self.logits_batch_into_with(
            inputs,
            batch,
            scratch,
            out,
            block_rows,
            tile_imgs,
            packing::xnor_popcount_z_simd,
        )
    }

    /// The shared weight-stationary batch walk behind the tiled and SIMD
    /// paths: `tile_kernel` computes one `t × b` pre-activation tile under
    /// the [`packing::xnor_popcount_z_tile`] contract (row-major
    /// `imgs`/`rows`, strided `out`); everything else — tile schedule,
    /// thresholding, arena ping-pong, logits layout — is identical across
    /// kernels by construction.
    #[allow(clippy::too_many_arguments)]
    fn logits_batch_into_with(
        &self,
        inputs: &[u64],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut [i32],
        block_rows: usize,
        tile_imgs: usize,
        tile_kernel: fn(&[u64], usize, &[u64], usize, usize, &mut [i32], usize),
    ) {
        assert!(block_rows >= 1, "block_rows must be ≥ 1");
        assert!(tile_imgs >= 1, "tile_imgs must be ≥ 1");
        assert_eq!(inputs.len(), batch * self.input_words(), "batch input length");
        assert_eq!(out.len(), batch * self.n_classes(), "batch output length");
        if self.conv.is_empty() {
            return self
                .dense_batch_walk(inputs, batch, scratch, out, block_rows, tile_imgs, tile_kernel);
        }
        // conv front once per batch into the flat dense-level arena, then
        // the unchanged weight-stationary dense walk streams over it
        let cf = self.conv_front_batch(inputs, batch, scratch);
        self.dense_batch_walk(&cf, batch, scratch, out, block_rows, tile_imgs, tile_kernel);
        scratch.cf = cf;
    }

    /// The dense-stack weight-stationary batch walk (`inputs` is at the
    /// dense level: `batch × dense_input_words` row-major).
    #[allow(clippy::too_many_arguments)]
    fn dense_batch_walk(
        &self,
        inputs: &[u64],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut [i32],
        block_rows: usize,
        tile_imgs: usize,
        tile_kernel: fn(&[u64], usize, &[u64], usize, usize, &mut [i32], usize),
    ) {
        let iw = self.dense_input_words();
        debug_assert_eq!(inputs.len(), batch * iw, "dense-level batch input length");
        let nc = self.n_classes();
        let maxw = self.max_act_words();
        scratch.ta.resize(tile_imgs * maxw, 0);
        scratch.tb.resize(tile_imgs * maxw, 0);
        scratch.zt.resize(tile_imgs * block_rows, 0);

        let mut i0 = 0;
        while i0 < batch {
            let t = tile_imgs.min(batch - i0);
            scratch.ta[..t * iw].copy_from_slice(&inputs[i0 * iw..(i0 + t) * iw]);
            let out_tile = &mut out[i0 * nc..(i0 + t) * nc];
            for layer in &self.layers {
                let wpr = layer.words_per_row;
                match &layer.thresholds {
                    Some(thr) => {
                        // hidden layer: tile of sums, threshold, re-pack
                        // into the other arena with the next layer's stride
                        let ow = packing::words_u64(layer.n_out);
                        scratch.tb[..t * ow].fill(0);
                        let mut j = 0;
                        while j < layer.n_out {
                            let b = block_rows.min(layer.n_out - j);
                            let rows = &layer.weights[j * wpr..(j + b) * wpr];
                            tile_kernel(
                                &scratch.ta[..t * wpr],
                                t,
                                rows,
                                wpr,
                                layer.n_in,
                                &mut scratch.zt[..t * b],
                                b,
                            );
                            for i in 0..t {
                                for (k, &z) in scratch.zt[i * b..(i + 1) * b].iter().enumerate() {
                                    if z >= thr[j + k] {
                                        scratch.tb[i * ow + (j + k) / 64] |=
                                            1u64 << ((j + k) % 64);
                                    }
                                }
                            }
                            j += b;
                        }
                        std::mem::swap(&mut scratch.ta, &mut scratch.tb);
                    }
                    None => {
                        // output layer: row blocks land directly in the
                        // caller's flat logits rows (stride = n_classes)
                        let mut j = 0;
                        while j < layer.n_out {
                            let b = block_rows.min(layer.n_out - j);
                            let rows = &layer.weights[j * wpr..(j + b) * wpr];
                            tile_kernel(
                                &scratch.ta[..t * wpr],
                                t,
                                rows,
                                wpr,
                                layer.n_in,
                                &mut out_tile[j..],
                                nc,
                            );
                            j += b;
                        }
                    }
                }
            }
            i0 += t;
        }
    }

    /// Tiled batch inference, allocating convenience (tests/benches).
    pub fn logits_batch_tiled(
        &self,
        inputs: &[u64],
        batch: usize,
        block_rows: usize,
        tile_imgs: usize,
    ) -> Vec<i32> {
        let mut scratch = Scratch::default();
        let mut out = vec![0i32; batch * self.n_classes()];
        self.logits_batch_into_tiled(inputs, batch, &mut scratch, &mut out, block_rows, tile_imgs);
        out
    }

    /// SIMD batch inference, allocating convenience (tests/benches).
    pub fn logits_batch_simd(
        &self,
        inputs: &[u64],
        batch: usize,
        block_rows: usize,
        tile_imgs: usize,
    ) -> Vec<i32> {
        let mut scratch = Scratch::default();
        let mut out = vec![0i32; batch * self.n_classes()];
        self.logits_batch_into_simd(inputs, batch, &mut scratch, &mut out, block_rows, tile_imgs);
        out
    }
}

/// One hidden layer re-laid out for the fused threshold-pack walk
/// (`Kernel::Fused`): weight rows grouped into
/// [`packing::PANEL_ROWS`]-row panels whose rows are **quad-interleaved**
/// word by word — word `k` of row `64p + 4q + lane` lives at
/// `panels[p·64·wpr + (q·wpr + k)·4 + lane]` — so
/// [`packing::xnor_threshold_pack`] streams each panel strictly linearly
/// (one 256-bit load per quad step on AVX2) instead of hopping per-row
/// [`BinaryDenseLayer::row`] slices.  The folded thresholds ride along
/// sliced per panel ([`Self::panel_thresholds`]).  Rows padding the last
/// quad are zero and never packed, so the padding-bit contract (bits ≥
/// `n_out` are 0) holds for the next layer by construction.
#[derive(Clone, Debug)]
pub struct PreparedPanelLayer {
    pub n_in: usize,
    pub n_out: usize,
    pub words_per_row: usize,
    /// `n_panels() × PANEL_ROWS × words_per_row` words, panel-major,
    /// quad-interleaved within each panel (zero rows pad the tail).
    panels: Vec<u64>,
    /// Folded thresholds in row order; panel `p`'s slice is
    /// `[p·PANEL_ROWS, p·PANEL_ROWS + rows_in_panel(p))`.
    thresholds: Vec<i32>,
}

impl PreparedPanelLayer {
    fn from_layer(layer: &BinaryDenseLayer) -> Result<Self> {
        let Some(thresholds) = layer.thresholds.clone() else {
            bail!("fused panels need a thresholded (hidden) layer");
        };
        let wpr = layer.words_per_row;
        let n_panels = packing::words_u64(layer.n_out);
        let mut panels = vec![0u64; n_panels * packing::PANEL_ROWS * wpr];
        for j in 0..layer.n_out {
            let (p, r) = (j / packing::PANEL_ROWS, j % packing::PANEL_ROWS);
            let (q, lane) = (r / 4, r % 4);
            let base = p * packing::PANEL_ROWS * wpr + q * 4 * wpr;
            for (k, &w) in layer.row(j).iter().enumerate() {
                panels[base + 4 * k + lane] = w;
            }
        }
        Ok(Self {
            n_in: layer.n_in,
            n_out: layer.n_out,
            words_per_row: wpr,
            panels,
            thresholds,
        })
    }

    /// Number of 64-row panels — which is also the packed activation words
    /// per image this layer emits (`words_u64(n_out)`).
    #[inline]
    pub fn n_panels(&self) -> usize {
        packing::words_u64(self.n_out)
    }

    /// Real (non-padding) rows in panel `p`.
    #[inline]
    pub fn rows_in_panel(&self, p: usize) -> usize {
        packing::PANEL_ROWS.min(self.n_out - p * packing::PANEL_ROWS)
    }

    /// Panel `p`'s quad-interleaved weight words — exactly the quads that
    /// hold real rows (a short last panel's trailing zero quads are not
    /// exposed, so the kernel never computes them).
    #[inline]
    pub fn panel(&self, p: usize) -> &[u64] {
        let n_quads = self.rows_in_panel(p).div_ceil(4);
        let start = p * packing::PANEL_ROWS * self.words_per_row;
        &self.panels[start..start + n_quads * 4 * self.words_per_row]
    }

    /// Panel `p`'s thresholds (length = `rows_in_panel(p)`).
    #[inline]
    pub fn panel_thresholds(&self, p: usize) -> &[i32] {
        let start = p * packing::PANEL_ROWS;
        &self.thresholds[start..start + self.rows_in_panel(p)]
    }

    /// Reconstruct row `j` from the panel layout (round-trip
    /// checks/tooling — the hot path never de-interleaves).
    pub fn row(&self, j: usize) -> Vec<u64> {
        let (p, r) = (j / packing::PANEL_ROWS, j % packing::PANEL_ROWS);
        let (q, lane) = (r / 4, r % 4);
        let base =
            p * packing::PANEL_ROWS * self.words_per_row + q * 4 * self.words_per_row;
        (0..self.words_per_row)
            .map(|k| self.panels[base + 4 * k + lane])
            .collect()
    }

    /// Row `j`'s folded threshold.
    #[inline]
    pub fn threshold(&self, j: usize) -> i32 {
        self.thresholds[j]
    }
}

/// One conv layer prepared for the fused walk: the geometry rides along
/// unchanged while the dense core is re-laid out into 64-channel
/// [`PreparedPanelLayer`] panels — per output patch, each panel is one
/// [`packing::xnor_threshold_pack`] call whose u64 result is spliced into
/// the flat packed output at bit `pos·C_out + 64·panel`
/// ([`packing::splice_bits`]; `C_out` need not be word-aligned).
#[derive(Clone, Debug)]
pub struct PreparedConvLayer {
    layer: BinaryConvLayer,
    panels: PreparedPanelLayer,
}

impl PreparedConvLayer {
    fn from_layer(cl: &BinaryConvLayer) -> Result<Self> {
        Ok(Self {
            panels: PreparedPanelLayer::from_layer(&cl.core)?,
            layer: cl.clone(),
        })
    }

    /// The source conv layer (geometry + row-major core).
    pub fn layer(&self) -> &BinaryConvLayer {
        &self.layer
    }

    /// The core's 64-channel panel layout.
    pub fn panels(&self) -> &PreparedPanelLayer {
        &self.panels
    }

    /// Fused forward pass over one packed image: im2col gather per patch,
    /// then threshold-pack per 64-channel panel straight into the packed
    /// output — the per-channel `i32` sums never touch memory, exactly as
    /// the dense fused tier.  Bit-identical to
    /// [`BinaryConvLayer::forward`].
    fn forward(&self, x: &[u64], out: &mut [u64], patch: &mut Vec<u64>) {
        let cl = &self.layer;
        debug_assert!(x.len() >= packing::words_u64(cl.in_bits()));
        assert_eq!(out.len(), packing::words_u64(cl.out_bits()), "conv output arena");
        out.fill(0);
        let wpr = self.panels.words_per_row;
        patch.clear();
        patch.resize(wpr, 0);
        let (oc, ow, n_bits) = (cl.out_ch(), cl.out_w(), cl.patch_bits());
        for oy in 0..cl.out_h() {
            for ox in 0..ow {
                let pos = oy * ow + ox;
                cl.gather_patch(x, oy, ox, patch);
                for p in 0..self.panels.n_panels() {
                    let word = packing::xnor_threshold_pack_simd(
                        patch,
                        self.panels.panel(p),
                        wpr,
                        n_bits,
                        self.panels.panel_thresholds(p),
                    );
                    packing::splice_bits(
                        out,
                        pos * oc + 64 * p,
                        word,
                        self.panels.rows_in_panel(p),
                    );
                }
            }
        }
    }
}

/// A [`BnnModel`] re-laid out **once** for the fused threshold-pack walk —
/// built at engine construction (`Engine::build()` →
/// `NativeBackend::with_kernel` when the kernel is `Fused`), never per
/// request.  Conv layers become [`PreparedConvLayer`]s (panelled cores +
/// geometry); hidden dense layers become [`PreparedPanelLayer`] panels;
/// the output layer keeps its row-major form (its raw sums *are* the
/// logits, §3.4 — there is no threshold to fuse).  Zero padding rounds
/// each hidden layer up to the next 64-row panel boundary.
#[derive(Clone, Debug)]
pub struct PreparedModel {
    conv: Vec<PreparedConvLayer>,
    hidden: Vec<PreparedPanelLayer>,
    output: BinaryDenseLayer,
    n_in: usize,
    n_classes: usize,
    input_words: usize,
    dense_input_words: usize,
    max_act_words: usize,
}

impl PreparedModel {
    /// Build the fused panel layout from a model (validates first: fused
    /// panels only make sense on a well-formed hidden/output split).
    pub fn new(model: &BnnModel) -> Result<Self> {
        model.validate()?;
        let conv = model
            .conv
            .iter()
            .map(PreparedConvLayer::from_layer)
            .collect::<Result<Vec<_>>>()?;
        let (last, hidden) = model.layers.split_last().expect("validated: non-empty");
        let hidden = hidden
            .iter()
            .map(PreparedPanelLayer::from_layer)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            conv,
            hidden,
            output: last.clone(),
            n_in: model.n_in(),
            n_classes: model.n_classes(),
            input_words: model.input_words(),
            dense_input_words: model.dense_input_words(),
            max_act_words: model.max_act_words(),
        })
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Packed words per dense-level input row (= `input_words()` for
    /// dense-only models; the layer pipeline feeds its first ring at this
    /// width).
    pub fn dense_input_words(&self) -> usize {
        self.dense_input_words
    }

    /// The conv prefix in fused layout (empty for dense-only models).
    pub fn conv_layers(&self) -> &[PreparedConvLayer] {
        &self.conv
    }

    /// The hidden layers in panel layout (round-trip checks/tooling).
    pub fn hidden_layers(&self) -> &[PreparedPanelLayer] {
        &self.hidden
    }

    /// The output (non-thresholded) layer in row-major form — the
    /// dataflow pipeline's final stage consumes it directly.
    pub fn output_layer(&self) -> &BinaryDenseLayer {
        &self.output
    }

    /// Fused batch forward pass — `Kernel::Fused`, the memory-traffic
    /// optimisation of the serving hot path.
    ///
    /// Where the tiled/simd walks materialize every hidden layer's
    /// `tile_imgs × block_rows` pre-activation tile in the `i32` arena and
    /// threshold/re-pack it in a second pass, this walk calls
    /// [`packing::xnor_threshold_pack_simd`] once per (image, panel):
    /// popcount → threshold-compare → activation bit-pack happen in
    /// registers and exactly **one `u64` is written per 64 neurons** —
    /// the sums never touch memory, and because every arena word is
    /// assigned (not OR-ed) there is no zero-fill pass either.  Only the
    /// output layer still writes `i32` logits, directly into the caller's
    /// rows.  Batches of ≥ `2 ×` [`FUSED_PAR_MIN_CHUNK`] images split
    /// across `std::thread::scope` threads (per-image results are
    /// independent, so the split is bit-identical to the serial walk).
    ///
    /// Layout contracts match [`BnnModel::logits_batch_into_tiled`]:
    /// `inputs` is `batch × input_words` row-major, `out` is
    /// `batch × n_classes` row-major, and the call is allocation-free once
    /// `scratch` has warmed up (the parallel split is the one exception —
    /// each scoped thread owns a fresh local `Scratch`, amortized over its
    /// ≥ 128-image chunk).  The split itself is dispatched through
    /// `run_batch_split` in [`crate::bnn::pipeline`], the shared stage
    /// scheduler the `Kernel::Pipelined` dataflow tier also lives in.
    /// Bit-identical to the scalar reference for
    /// every batch size and tile width (property-tested below and pinned
    /// by the golden-vector + differential conformance suites).
    pub fn logits_batch_into(
        &self,
        inputs: &[u64],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut [i32],
        tile_imgs: usize,
    ) {
        assert!(tile_imgs >= 1, "tile_imgs must be ≥ 1");
        let iw = self.input_words;
        assert_eq!(inputs.len(), batch * iw, "batch input length");
        let nc = self.n_classes;
        assert_eq!(out.len(), batch * nc, "batch output length");
        super::pipeline::run_batch_split(
            inputs,
            batch,
            scratch,
            out,
            iw,
            nc,
            FUSED_PAR_MIN_CHUNK,
            &|in_c: &[u64], n: usize, sc: &mut Scratch, out_c: &mut [i32]| {
                self.fused_walk(in_c, n, sc, out_c, tile_imgs)
            },
        );
    }

    /// Fused batch inference, allocating convenience (tests/benches).
    ///
    /// ```
    /// use bnn_fpga::bnn::model::{random_model, PreparedModel};
    /// use bnn_fpga::bnn::packing::pack_bits_u64;
    ///
    /// let model = random_model(&[784, 128, 64, 10], 7);
    /// let prepared = PreparedModel::new(&model).unwrap();
    /// let mut inputs = Vec::new();
    /// for seed in 0..3u8 {
    ///     inputs.extend(pack_bits_u64(&vec![seed & 1; 784]));
    /// }
    /// assert_eq!(
    ///     prepared.logits_batch(&inputs, 3, 8),
    ///     model.logits_batch(&inputs, 3) // bit-identical to scalar
    /// );
    /// ```
    pub fn logits_batch(&self, inputs: &[u64], batch: usize, tile_imgs: usize) -> Vec<i32> {
        let mut scratch = Scratch::default();
        let mut out = vec![0i32; batch * self.n_classes];
        self.logits_batch_into(inputs, batch, &mut scratch, &mut out, tile_imgs);
        out
    }

    /// Streaming layer-pipelined batch forward pass — `Kernel::Pipelined`,
    /// the throughput tentpole of the serving hot path.
    ///
    /// One stage worker thread per hidden layer, chained by
    /// `ring_cap`-deep SPSC rings of packed `u64` activation words; the
    /// output stage runs on the calling thread (see
    /// [`crate::bnn::pipeline`] for the stage graph and ring sizing
    /// model).  Layout contracts match [`Self::logits_batch_into`]:
    /// `inputs` is `batch × input_words` row-major, `out` is
    /// `batch × n_classes` row-major.  Bit-identical to the scalar
    /// reference at every ring capacity and batch size — including
    /// batch = 1 and no-hidden-layer models, which degenerate to the
    /// output stage inline — pinned by `tests/pipeline_conformance.rs`.
    pub fn logits_batch_pipelined(
        &self,
        inputs: &[u64],
        batch: usize,
        out: &mut [i32],
        ring_cap: usize,
    ) {
        super::pipeline::run_layer_pipeline(self, inputs, batch, out, ring_cap);
    }

    /// The serial fused walk over one image range (the parallel split
    /// dispatches per-chunk copies of this).  A conv prefix is lowered
    /// first — fused threshold-pack per patch into the `cf` arena — then
    /// the dense walk consumes the dense-level activations unchanged.
    fn fused_walk(
        &self,
        inputs: &[u64],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut [i32],
        tile_imgs: usize,
    ) {
        if self.conv.is_empty() {
            return self.fused_dense_walk(inputs, batch, scratch, out, tile_imgs);
        }
        let cf = self.conv_front_batch(inputs, batch, scratch);
        self.fused_dense_walk(&cf, batch, scratch, out, tile_imgs);
        scratch.cf = cf;
    }

    /// Run the fused conv prefix over one image into `dst` (dense-level
    /// packed activations).  Same arena discipline as
    /// [`BnnModel::conv_front_into`]: `ca`/`cb` ping-pong through the
    /// chain, `patch` holds the im2col gather.
    fn conv_front_into(&self, x: &[u64], dst: &mut [u64], scratch: &mut Scratch) {
        let (last, chain) = self.conv.split_last().expect("conv prefix is non-empty");
        if chain.is_empty() {
            return last.forward(x, dst, &mut scratch.patch);
        }
        let mut a = std::mem::take(&mut scratch.ca);
        let mut b = std::mem::take(&mut scratch.cb);
        a.clear();
        a.resize(packing::words_u64(chain[0].layer.out_bits()), 0);
        chain[0].forward(x, &mut a, &mut scratch.patch);
        for cl in &chain[1..] {
            b.clear();
            b.resize(packing::words_u64(cl.layer.out_bits()), 0);
            cl.forward(&a, &mut b, &mut scratch.patch);
            std::mem::swap(&mut a, &mut b);
        }
        last.forward(&a, dst, &mut scratch.patch);
        scratch.ca = a;
        scratch.cb = b;
    }

    /// Lower the conv prefix over a whole batch into the taken-out `cf`
    /// arena (caller restores it to `scratch` afterwards).  `pub(crate)`
    /// so the layer pipeline can materialize dense-level inputs before
    /// feeding its first ring.
    pub(crate) fn conv_front_batch(
        &self,
        inputs: &[u64],
        batch: usize,
        scratch: &mut Scratch,
    ) -> Vec<u64> {
        let iw = self.input_words;
        let dw = self.dense_input_words;
        let mut cf = std::mem::take(&mut scratch.cf);
        cf.clear();
        cf.resize(batch * dw, 0);
        for i in 0..batch {
            let img = &inputs[i * iw..(i + 1) * iw];
            self.conv_front_into(img, &mut cf[i * dw..(i + 1) * dw], scratch);
        }
        cf
    }

    /// The dense fused walk proper.  Hidden layers run
    /// panel-outer/image-inner so each panel stays cache-hot while the
    /// tile's images stream through it; the fused path needs only the
    /// `ta`/`tb` word arenas — `Scratch.zt` (the tiled walk's `i32` tile)
    /// is never grown.
    fn fused_dense_walk(
        &self,
        inputs: &[u64],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut [i32],
        tile_imgs: usize,
    ) {
        let iw = self.dense_input_words;
        let nc = self.n_classes;
        let maxw = self.max_act_words;
        scratch.ta.resize(tile_imgs * maxw, 0);
        scratch.tb.resize(tile_imgs * maxw, 0);
        let mut i0 = 0;
        while i0 < batch {
            let t = tile_imgs.min(batch - i0);
            scratch.ta[..t * iw].copy_from_slice(&inputs[i0 * iw..(i0 + t) * iw]);
            for layer in &self.hidden {
                let wpr = layer.words_per_row;
                let ow = layer.n_panels();
                for p in 0..ow {
                    let panel = layer.panel(p);
                    let thr = layer.panel_thresholds(p);
                    for i in 0..t {
                        let x = &scratch.ta[i * wpr..(i + 1) * wpr];
                        scratch.tb[i * ow + p] =
                            packing::xnor_threshold_pack_simd(x, panel, wpr, layer.n_in, thr);
                    }
                }
                std::mem::swap(&mut scratch.ta, &mut scratch.tb);
            }
            // output layer: raw-sum row blocks land directly in the
            // caller's flat logits rows (stride = n_classes, §3.4)
            let lo = &self.output;
            let wpr = lo.words_per_row;
            let out_tile = &mut out[i0 * nc..(i0 + t) * nc];
            let mut j = 0;
            while j < lo.n_out {
                let b = DEFAULT_BLOCK_ROWS.min(lo.n_out - j);
                let rows = &lo.weights[j * wpr..(j + b) * wpr];
                packing::xnor_popcount_z_simd(
                    &scratch.ta[..t * wpr],
                    t,
                    rows,
                    wpr,
                    lo.n_in,
                    &mut out_tile[j..],
                    nc,
                );
                j += b;
            }
            i0 += t;
        }
    }
}

/// Deterministic random ±1 model with zero thresholds — the artifact-free
/// stand-in used by tests, benches and examples when `make artifacts` has
/// not run.  Kernel equivalence, cycle counts and serving mechanics only
/// depend on the layer dimensions, not on trained weights.
pub fn random_model(dims: &[usize], seed: u64) -> BnnModel {
    assert!(dims.len() >= 2, "need at least one layer");
    let mut rng = Xoshiro256::new(seed);
    let mut spec = Vec::new();
    for (li, w) in dims.windows(2).enumerate() {
        let rows: Vec<Vec<i8>> = (0..w[1])
            .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
            .collect();
        let thr = (li + 2 < dims.len()).then(|| vec![0i32; w[1]]);
        spec.push((rows, thr));
    }
    model_from_sign_rows(spec).expect("random spec is well-formed")
}

/// Build a model directly from ±1 float-sign rows (tests/tools).
pub fn model_from_sign_rows(
    layers: Vec<(Vec<Vec<i8>>, Option<Vec<i32>>)>, // (rows of ±1, thresholds)
) -> Result<BnnModel> {
    let mut out = Vec::new();
    for (rows, thr) in layers {
        let n_in = rows[0].len();
        let rows_u32: Vec<Vec<u32>> = rows
            .iter()
            .map(|r| {
                let bits: Vec<u8> = r.iter().map(|&v| u8::from(v >= 0)).collect();
                packing::pack_bits_u32(&bits)
            })
            .collect();
        out.push(BinaryDenseLayer::from_u32_rows(n_in, &rows_u32, thr)?);
    }
    let model = BnnModel::dense(out);
    model.validate()?;
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    /// Naive float reference implementing Algorithm 1 literally.
    fn naive_forward(
        layers: &[(Vec<Vec<i8>>, Option<Vec<i32>>)],
        input_bits: &[u8],
    ) -> Vec<i32> {
        let mut a: Vec<i32> = input_bits.iter().map(|&b| if b == 1 { 1 } else { -1 }).collect();
        let mut logits = Vec::new();
        for (rows, thr) in layers {
            let z: Vec<i32> = rows
                .iter()
                .map(|row| row.iter().zip(&a).map(|(&w, &x)| w as i32 * x).sum())
                .collect();
            match thr {
                Some(t) => {
                    a = z
                        .iter()
                        .zip(t)
                        .map(|(&z, &t)| if z >= t { 1 } else { -1 })
                        .collect();
                }
                None => logits = z,
            }
        }
        logits
    }

    fn random_net(rng: &mut Xoshiro256, dims: &[usize]) -> Vec<(Vec<Vec<i8>>, Option<Vec<i32>>)> {
        let mut layers = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let (n_in, n_out) = (w[0], w[1]);
            let rows: Vec<Vec<i8>> = (0..n_out)
                .map(|_| (0..n_in).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            let thr = if li + 2 < dims.len() {
                Some(
                    (0..n_out)
                        .map(|_| rng.range_i64(-(n_in as i64), n_in as i64) as i32)
                        .collect(),
                )
            } else {
                None
            };
            layers.push((rows, thr));
        }
        layers
    }

    #[test]
    fn model_matches_naive_reference() {
        let mut rng = Xoshiro256::new(2025);
        for _ in 0..20 {
            let dims = [784usize, 128, 64, 10];
            let spec = random_net(&mut rng, &dims);
            let model = model_from_sign_rows(spec.clone()).unwrap();
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            let x = packing::pack_bits_u64(&bits);
            assert_eq!(model.logits(&x), naive_forward(&spec, &bits));
        }
    }

    #[test]
    fn odd_dims_work() {
        // widths not multiples of 64 or 32 must still chain correctly
        let mut rng = Xoshiro256::new(7);
        let dims = [37usize, 19, 11, 3];
        let spec = random_net(&mut rng, &dims);
        let model = model_from_sign_rows(spec.clone()).unwrap();
        let bits: Vec<u8> = (0..37).map(|_| rng.bool() as u8).collect();
        let x = packing::pack_bits_u64(&bits);
        assert_eq!(model.logits(&x), naive_forward(&spec, &bits));
    }

    #[test]
    fn batch_equals_sequential() {
        let mut rng = Xoshiro256::new(3);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        let iw = model.input_words();
        let batch = 5;
        let mut inputs = Vec::new();
        let mut expected = Vec::new();
        for _ in 0..batch {
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            let x = packing::pack_bits_u64(&bits);
            expected.extend(model.logits(&x));
            inputs.extend(x);
        }
        assert_eq!(inputs.len(), batch * iw);
        assert_eq!(model.logits_batch(&inputs, batch), expected);
    }

    #[test]
    fn validate_catches_bad_chaining() {
        let mut rng = Xoshiro256::new(4);
        let mut spec = random_net(&mut rng, &[784, 128, 64, 10]);
        spec[1].0.pop(); // layer 1 now outputs 63 ≠ 64
        assert!(model_from_sign_rows(spec).is_err());
    }

    #[test]
    fn validate_requires_raw_output_layer() {
        let mut rng = Xoshiro256::new(5);
        let mut spec = random_net(&mut rng, &[16, 8, 4]);
        spec[1].1 = Some(vec![0; 4]); // output layer must not threshold
        assert!(model_from_sign_rows(spec).is_err());
    }

    #[test]
    fn blocked_equals_scalar_for_all_block_sizes() {
        // Every block size — unaligned, tile-sized, layer-sized, oversized —
        // must be bit-identical to the scalar reference on the paper dims.
        let mut rng = Xoshiro256::new(77);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        for trial in 0..5 {
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            let x = packing::pack_bits_u64(&bits);
            let scalar = model.logits(&x);
            for block in [1, 2, 3, 4, 5, 7, 8, 16, 64, 128, 200] {
                assert_eq!(
                    model.logits_blocked(&x, block),
                    scalar,
                    "trial {trial}, block {block}"
                );
            }
        }
    }

    #[test]
    fn blocked_equals_scalar_on_odd_dims() {
        // widths that straddle both the u64 word and the 4-row tile
        let mut rng = Xoshiro256::new(78);
        for dims in [[37usize, 19, 11, 3], [65, 63, 5, 1], [130, 129, 67, 9]] {
            let spec = random_net(&mut rng, &dims);
            let model = model_from_sign_rows(spec).unwrap();
            let bits: Vec<u8> = (0..dims[0]).map(|_| rng.bool() as u8).collect();
            let x = packing::pack_bits_u64(&bits);
            let scalar = model.logits(&x);
            for block in [1, 3, 4, 6, 33] {
                assert_eq!(model.logits_blocked(&x, block), scalar, "{dims:?} block {block}");
            }
        }
    }

    #[test]
    fn blocked_batch_matches_scalar_batch() {
        let mut rng = Xoshiro256::new(79);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        let batch = 7;
        let mut inputs = Vec::new();
        for _ in 0..batch {
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            inputs.extend(packing::pack_bits_u64(&bits));
        }
        assert_eq!(
            model.logits_batch_blocked(&inputs, batch, DEFAULT_BLOCK_ROWS),
            model.logits_batch(&inputs, batch)
        );
    }

    #[test]
    fn tiled_batch_equals_scalar_for_all_tile_shapes() {
        // Every (block_rows, tile_imgs) shape — unaligned, tile-sized,
        // layer-sized, oversized — must be bit-identical to the per-image
        // scalar reference on the paper dims.
        let mut rng = Xoshiro256::new(80);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        for batch in [1usize, 3, 8, 17] {
            let mut inputs = Vec::new();
            for _ in 0..batch {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                inputs.extend(packing::pack_bits_u64(&bits));
            }
            let scalar = model.logits_batch(&inputs, batch);
            for block in [1usize, 3, 16, 128, 200] {
                for tile in [1usize, 2, 5, 8, 32] {
                    assert_eq!(
                        model.logits_batch_tiled(&inputs, batch, block, tile),
                        scalar,
                        "batch {batch}, block {block}, tile {tile}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiled_batch_equals_scalar_on_odd_dims() {
        // widths that straddle the u64 word, the 4-row quad and the
        // 2-image pair all at once
        let mut rng = Xoshiro256::new(81);
        for dims in [[37usize, 19, 11, 3], [65, 63, 5, 1], [130, 129, 67, 9]] {
            let spec = random_net(&mut rng, &dims);
            let model = model_from_sign_rows(spec).unwrap();
            let batch = 7;
            let mut inputs = Vec::new();
            for _ in 0..batch {
                let bits: Vec<u8> = (0..dims[0]).map(|_| rng.bool() as u8).collect();
                inputs.extend(packing::pack_bits_u64(&bits));
            }
            let scalar = model.logits_batch(&inputs, batch);
            for (block, tile) in [(1usize, 1usize), (4, 2), (6, 3), (33, 8)] {
                assert_eq!(
                    model.logits_batch_tiled(&inputs, batch, block, tile),
                    scalar,
                    "{dims:?} block {block} tile {tile}"
                );
            }
        }
    }

    #[test]
    fn tiled_batch_equals_per_image_property() {
        // The acceptance property: `logits_batch_into_tiled` is
        // bit-identical to per-image `logits_into` across batch sizes
        // {1, 2, 7, 64}, random tile shapes, and edge input widths
        // (including non-multiples of 64).
        use crate::util::proptest_lite::{gens, Runner};
        let mut rng = Xoshiro256::new(82);
        let models: Vec<BnnModel> = [
            vec![784usize, 128, 64, 10],
            vec![65, 63, 5, 3], // word-straddling widths
        ]
        .iter()
        .map(|dims| model_from_sign_rows(random_net(&mut rng, dims)).unwrap())
        .collect();
        Runner::new("tiled-batch-vs-per-image").cases(10).run(
            &gens::Pair(gens::U64(1..=40), gens::U64(1..=12)),
            |(block, tile)| {
                let (block, tile) = (*block as usize, *tile as usize);
                models.iter().all(|model| {
                    [1usize, 2, 7, 64].iter().all(|&batch| {
                        let n_in = model.n_in();
                        let mut case_rng =
                            Xoshiro256::new((block * 1009 + tile * 31 + batch) as u64);
                        let mut inputs = Vec::new();
                        for _ in 0..batch {
                            let bits: Vec<u8> =
                                (0..n_in).map(|_| case_rng.bool() as u8).collect();
                            inputs.extend(packing::pack_bits_u64(&bits));
                        }
                        let tiled = model.logits_batch_tiled(&inputs, batch, block, tile);
                        let iw = model.input_words();
                        let nc = model.n_classes();
                        let mut scratch = Scratch::default();
                        let mut want = vec![0i32; nc];
                        (0..batch).all(|b| {
                            model.logits_into(
                                &inputs[b * iw..(b + 1) * iw],
                                &mut scratch,
                                &mut want,
                            );
                            tiled[b * nc..(b + 1) * nc] == want[..]
                        })
                    })
                })
            },
        );
    }

    #[test]
    fn simd_batch_equals_scalar_for_all_tile_shapes() {
        // The SIMD walk shares the tiled schedule; whatever vector level
        // this host dispatches to must be bit-identical to the per-image
        // scalar reference for every (block_rows, tile_imgs) shape.
        let mut rng = Xoshiro256::new(84);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        for batch in [1usize, 3, 8, 17] {
            let mut inputs = Vec::new();
            for _ in 0..batch {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                inputs.extend(packing::pack_bits_u64(&bits));
            }
            let scalar = model.logits_batch(&inputs, batch);
            for block in [1usize, 3, 16, 128, 200] {
                for tile in [1usize, 2, 5, 8, 32] {
                    assert_eq!(
                        model.logits_batch_simd(&inputs, batch, block, tile),
                        scalar,
                        "batch {batch}, block {block}, tile {tile}"
                    );
                }
            }
        }
    }

    #[test]
    fn simd_batch_equals_scalar_on_odd_dims() {
        // widths that straddle the u64 word, the vector width (4 words on
        // AVX2, 2 on NEON) and the row pair all at once
        let mut rng = Xoshiro256::new(85);
        for dims in [[37usize, 19, 11, 3], [65, 63, 5, 1], [130, 129, 67, 9]] {
            let spec = random_net(&mut rng, &dims);
            let model = model_from_sign_rows(spec).unwrap();
            let batch = 7;
            let mut inputs = Vec::new();
            for _ in 0..batch {
                let bits: Vec<u8> = (0..dims[0]).map(|_| rng.bool() as u8).collect();
                inputs.extend(packing::pack_bits_u64(&bits));
            }
            let scalar = model.logits_batch(&inputs, batch);
            for (block, tile) in [(1usize, 1usize), (4, 2), (6, 3), (33, 8)] {
                assert_eq!(
                    model.logits_batch_simd(&inputs, batch, block, tile),
                    scalar,
                    "{dims:?} block {block} tile {tile}"
                );
            }
        }
    }

    #[test]
    fn tiled_scratch_is_reusable_across_batch_sizes() {
        // One Scratch must serve growing and shrinking batches (the worker
        // arena pattern) without residue from earlier batches.
        let mut rng = Xoshiro256::new(83);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        let mut scratch = Scratch::default();
        for &batch in &[5usize, 1, 8, 3] {
            let mut inputs = Vec::new();
            for _ in 0..batch {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                inputs.extend(packing::pack_bits_u64(&bits));
            }
            let mut out = vec![0i32; batch * 10];
            model.logits_batch_into_tiled(
                &inputs,
                batch,
                &mut scratch,
                &mut out,
                DEFAULT_BLOCK_ROWS,
                DEFAULT_TILE_IMGS,
            );
            assert_eq!(out, model.logits_batch(&inputs, batch), "batch {batch}");
        }
    }

    #[test]
    fn prepared_model_round_trips_rows_and_thresholds() {
        // The acceptance property (ISSUE 5): panel layout → reconstructed
        // rows == original rows and thresholds preserved, across edge
        // widths {1, 37, 63, 64, 65, 784} and hidden row counts that are
        // not multiples of 64 (or of the 4-row quad).
        let mut rng = Xoshiro256::new(90);
        for dims in [
            vec![1usize, 1, 1],
            vec![37, 63, 3],
            vec![63, 64, 5],
            vec![64, 65, 10],
            vec![65, 37, 1],
            vec![784, 128, 64, 10],
            vec![784, 100, 10],
            vec![128, 130, 67, 9],
        ] {
            let spec = random_net(&mut rng, &dims);
            let model = model_from_sign_rows(spec).unwrap();
            let prepared = PreparedModel::new(&model).unwrap();
            let hidden = prepared.hidden_layers();
            assert_eq!(hidden.len(), model.layers.len() - 1, "{dims:?}");
            for (li, layer) in model.layers[..model.layers.len() - 1].iter().enumerate() {
                let pl = &hidden[li];
                assert_eq!((pl.n_in, pl.n_out), (layer.n_in, layer.n_out), "{dims:?}");
                assert_eq!(pl.n_panels(), packing::words_u64(layer.n_out));
                let thr = layer.thresholds.as_ref().unwrap();
                for j in 0..layer.n_out {
                    assert_eq!(pl.row(j), layer.row(j), "{dims:?} layer {li} row {j}");
                    assert_eq!(pl.threshold(j), thr[j], "{dims:?} layer {li} thr {j}");
                }
                // per-panel slices tile the layer exactly
                let total: usize = (0..pl.n_panels()).map(|p| pl.rows_in_panel(p)).sum();
                assert_eq!(total, layer.n_out, "{dims:?} layer {li}");
                for p in 0..pl.n_panels() {
                    let rows = pl.rows_in_panel(p);
                    assert_eq!(pl.panel_thresholds(p), &thr[p * 64..p * 64 + rows]);
                    assert_eq!(
                        pl.panel(p).len(),
                        rows.div_ceil(4) * 4 * pl.words_per_row,
                        "{dims:?} layer {li} panel {p}"
                    );
                }
            }
        }
        // building from a model with an un-thresholded hidden layer fails
        let mut rng = Xoshiro256::new(91);
        let mut spec = random_net(&mut rng, &[16, 8, 4]);
        spec[0].1 = None;
        let broken = BnnModel {
            conv: Vec::new(),
            layers: spec
                .into_iter()
                .map(|(rows, thr)| {
                    let n_in = rows[0].len();
                    let rows_u32: Vec<Vec<u32>> = rows
                        .iter()
                        .map(|r| {
                            let bits: Vec<u8> = r.iter().map(|&v| u8::from(v >= 0)).collect();
                            packing::pack_bits_u32(&bits)
                        })
                        .collect();
                    BinaryDenseLayer::from_u32_rows(n_in, &rows_u32, thr).unwrap()
                })
                .collect(),
        };
        assert!(PreparedModel::new(&broken).is_err());
    }

    #[test]
    fn fused_batch_equals_scalar_for_all_tile_widths() {
        // The fused threshold-pack walk must be bit-identical to the
        // per-image scalar reference for every batch size and tile width
        // on the paper dims.
        let mut rng = Xoshiro256::new(92);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        let prepared = PreparedModel::new(&model).unwrap();
        for batch in [1usize, 3, 8, 17] {
            let mut inputs = Vec::new();
            for _ in 0..batch {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                inputs.extend(packing::pack_bits_u64(&bits));
            }
            let scalar = model.logits_batch(&inputs, batch);
            for tile in [1usize, 2, 5, 8, 32] {
                assert_eq!(
                    prepared.logits_batch(&inputs, batch, tile),
                    scalar,
                    "batch {batch}, tile {tile}"
                );
            }
        }
    }

    #[test]
    fn fused_batch_equals_scalar_on_odd_dims() {
        // widths that straddle the u64 word, the 64-row panel and the
        // 4-row quad all at once — including a no-hidden-layer model,
        // where the fused walk is output-layer only
        let mut rng = Xoshiro256::new(93);
        for dims in [
            vec![37usize, 19, 11, 3],
            vec![65, 63, 5, 1],
            vec![130, 129, 67, 9],
            vec![64, 65, 10],
            vec![64, 10],
        ] {
            let spec = random_net(&mut rng, &dims);
            let model = model_from_sign_rows(spec).unwrap();
            let prepared = PreparedModel::new(&model).unwrap();
            let batch = 7;
            let mut inputs = Vec::new();
            for _ in 0..batch {
                let bits: Vec<u8> = (0..dims[0]).map(|_| rng.bool() as u8).collect();
                inputs.extend(packing::pack_bits_u64(&bits));
            }
            let scalar = model.logits_batch(&inputs, batch);
            for tile in [1usize, 3, 8] {
                assert_eq!(
                    prepared.logits_batch(&inputs, batch, tile),
                    scalar,
                    "{dims:?} tile {tile}"
                );
            }
        }
    }

    #[test]
    fn fused_parallel_split_is_bit_identical() {
        // A batch large enough to trigger the scoped-thread split must
        // produce exactly the serial result (per-image independence).
        let mut rng = Xoshiro256::new(94);
        let spec = random_net(&mut rng, &[128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        let prepared = PreparedModel::new(&model).unwrap();
        let batch = 2 * FUSED_PAR_MIN_CHUNK + 37; // odd tail chunk included
        let mut inputs = Vec::new();
        for _ in 0..batch {
            let bits: Vec<u8> = (0..128).map(|_| rng.bool() as u8).collect();
            inputs.extend(packing::pack_bits_u64(&bits));
        }
        let got = prepared.logits_batch(&inputs, batch, DEFAULT_TILE_IMGS);
        // serial oracle: walk the same range through the private serial path
        let mut scratch = Scratch::default();
        let mut want = vec![0i32; batch * 10];
        prepared.fused_walk(&inputs, batch, &mut scratch, &mut want, DEFAULT_TILE_IMGS);
        assert_eq!(got, want);
        assert_eq!(want, model.logits_batch(&inputs, batch));
    }

    #[test]
    fn fused_walk_leaves_the_i32_tile_empty() {
        // Scratch slimming: the fused path's hidden-layer sums never touch
        // memory, so the zt arena (the tiled walk's i32 tile) must stay
        // unallocated after a fused batch.
        let mut rng = Xoshiro256::new(95);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        let prepared = PreparedModel::new(&model).unwrap();
        let mut inputs = Vec::new();
        for _ in 0..5 {
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            inputs.extend(packing::pack_bits_u64(&bits));
        }
        let mut scratch = Scratch::default();
        let mut out = vec![0i32; 5 * 10];
        prepared.logits_batch_into(&inputs, 5, &mut scratch, &mut out, DEFAULT_TILE_IMGS);
        assert_eq!(out, model.logits_batch(&inputs, 5));
        assert!(scratch.zt.is_empty(), "fused walk must not grow the i32 tile");
        assert!(!scratch.ta.is_empty(), "fused walk runs on the word arenas");
    }

    #[test]
    fn predict_into_matches_predict_and_reuses_scratch() {
        let mut rng = Xoshiro256::new(96);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        let mut scratch = Scratch::default();
        let mut logits = vec![0i32; 10];
        for _ in 0..5 {
            let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
            let x = packing::pack_bits_u64(&bits);
            let digit = model.predict_into(&x, &mut scratch, &mut logits);
            assert_eq!(digit, model.predict(&x));
            assert_eq!(logits, model.logits(&x));
        }
    }

    #[test]
    fn random_model_is_deterministic_and_valid() {
        let a = random_model(&[784, 128, 64, 10], 1);
        let b = random_model(&[784, 128, 64, 10], 1);
        assert!(a.validate().is_ok());
        assert_eq!(a.layers[0].weights, b.layers[0].weights);
        let c = random_model(&[784, 128, 64, 10], 2);
        assert_ne!(a.layers[0].weights, c.layers[0].weights);
    }

    #[test]
    fn logits_into_is_deterministic_and_reusable() {
        let mut rng = Xoshiro256::new(6);
        let spec = random_net(&mut rng, &[784, 128, 64, 10]);
        let model = model_from_sign_rows(spec).unwrap();
        let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
        let x = packing::pack_bits_u64(&bits);
        let mut scratch = Scratch::default();
        let mut out1 = vec![0i32; 10];
        let mut out2 = vec![0i32; 10];
        model.logits_into(&x, &mut scratch, &mut out1);
        model.logits_into(&x, &mut scratch, &mut out2); // reused scratch
        assert_eq!(out1, out2);
        assert_eq!(out1, model.logits(&x));
    }

    /// Random packed inputs at a conv model's image width.
    fn conv_inputs(model: &BnnModel, batch: usize, rng: &mut Xoshiro256) -> Vec<u64> {
        let mut inputs = Vec::new();
        for _ in 0..batch {
            let bits: Vec<u8> = (0..model.n_in()).map(|_| rng.bool() as u8).collect();
            inputs.extend(packing::pack_bits_u64(&bits));
        }
        inputs
    }

    #[test]
    fn conv_models_agree_across_every_walk() {
        // Every execution path — scalar, blocked, tiled, SIMD, fused
        // prepared, pipelined — must produce bit-identical logits on
        // mixed conv→dense stacks, including a two-conv chain and a
        // 66-channel layer that straddles the 64-row panel boundary.
        use crate::bnn::conv::random_conv_model;
        let specs: [(&str, BnnModel); 3] = [
            ("mnist-conv", random_conv_model((1, 28, 28), &[(8, 3, 1, 1)], &[64, 10], 31)),
            (
                "conv-stack",
                random_conv_model((3, 9, 9), &[(5, 3, 1, 1), (7, 3, 2, 0)], &[33, 10], 32),
            ),
            ("panel-straddle", random_conv_model((2, 6, 6), &[(66, 1, 1, 0)], &[17, 5], 33)),
        ];
        let mut rng = Xoshiro256::new(97);
        for (name, model) in &specs {
            model.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            let prepared = PreparedModel::new(model).unwrap();
            let batch = 5;
            let inputs = conv_inputs(model, batch, &mut rng);
            let scalar = model.logits_batch(&inputs, batch);
            assert_eq!(model.logits_batch_blocked(&inputs, batch, 16), scalar, "{name} blocked");
            let mut scratch = Scratch::default();
            let mut got = vec![0i32; batch * model.n_classes()];
            for (br, ti) in [(1, 1), (16, 4), (64, 8)] {
                got.fill(0);
                model.logits_batch_into_tiled(&inputs, batch, &mut scratch, &mut got, br, ti);
                assert_eq!(got, scalar, "{name} tiled {br}x{ti}");
                got.fill(0);
                model.logits_batch_into_simd(&inputs, batch, &mut scratch, &mut got, br, ti);
                assert_eq!(got, scalar, "{name} simd {br}x{ti}");
            }
            for tile in [1usize, 3, 8] {
                assert_eq!(prepared.logits_batch(&inputs, batch, tile), scalar, "{name} fused");
            }
            for ring in [1usize, 4] {
                got.fill(0);
                prepared.logits_batch_pipelined(&inputs, batch, &mut got, ring);
                assert_eq!(got, scalar, "{name} pipelined ring={ring}");
            }
        }
    }

    #[test]
    fn conv_fused_walk_leaves_the_i32_tile_empty() {
        // The fused path must stay word-only even with a conv front: the
        // tiled walk's i32 tile is never grown.
        use crate::bnn::conv::random_conv_model;
        let model = random_conv_model((1, 10, 10), &[(6, 3, 1, 1)], &[32, 10], 41);
        let prepared = PreparedModel::new(&model).unwrap();
        let mut rng = Xoshiro256::new(42);
        let inputs = conv_inputs(&model, 4, &mut rng);
        let mut scratch = Scratch::default();
        let mut out = vec![0i32; 4 * model.n_classes()];
        prepared.logits_batch_into(&inputs, 4, &mut scratch, &mut out, 2);
        assert!(scratch.zt.is_empty(), "fused conv walk must not touch the i32 tile");
        assert_eq!(out, model.logits_batch(&inputs, 4));
    }

    #[test]
    fn conv_scratch_reuse_is_deterministic() {
        use crate::bnn::conv::random_conv_model;
        let model = random_conv_model((2, 7, 7), &[(9, 3, 2, 1)], &[20, 10], 43);
        let mut rng = Xoshiro256::new(44);
        let x = conv_inputs(&model, 1, &mut rng);
        let mut scratch = Scratch::default();
        let mut out1 = vec![0i32; model.n_classes()];
        let mut out2 = vec![0i32; model.n_classes()];
        model.logits_into(&x, &mut scratch, &mut out1);
        model.logits_into(&x, &mut scratch, &mut out2); // warm conv arenas
        assert_eq!(out1, out2);
        assert_eq!(out1, model.logits(&x));
        assert_eq!(model.predict_into(&x, &mut scratch, &mut out1), model.predict(&x));
    }

    #[test]
    fn conv_model_validation_catches_mismatched_stacks() {
        use crate::bnn::conv::random_conv_model;
        // chain break: second conv's input channels disagree with the
        // first conv's output channels
        let mut m = random_conv_model((3, 9, 9), &[(5, 3, 1, 1), (7, 3, 2, 0)], &[33, 10], 51);
        assert!(m.validate().is_ok());
        m.conv[1].in_ch += 1;
        assert!(m.validate().is_err(), "chain mismatch must be rejected");
        // junction break: conv output bits disagree with the dense stack
        let mut m = random_conv_model((1, 8, 8), &[(4, 3, 1, 1)], &[16, 10], 52);
        m.conv[0].in_h += 2;
        assert!(m.validate().is_err(), "junction mismatch must be rejected");
    }

    #[test]
    fn conv_geometry_accessors_are_image_level() {
        use crate::bnn::conv::random_conv_model;
        let model = random_conv_model((1, 28, 28), &[(8, 3, 1, 1)], &[64, 10], 53);
        assert_eq!(model.n_in(), 784, "first conv layer sets the image width");
        assert_eq!(model.input_geometry(), Some((1, 28, 28)));
        assert_eq!(model.dense_n_in(), 8 * 28 * 28);
        assert_eq!(model.n_layers(), 3);
        let prepared = PreparedModel::new(&model).unwrap();
        assert_eq!(prepared.n_in(), 784);
        assert_eq!(prepared.dense_input_words(), packing::words_u64(8 * 28 * 28));
        assert_eq!(prepared.conv_layers().len(), 1);
        let dense = random_model(&[784, 128, 64, 10], 54);
        assert_eq!(dense.input_geometry(), None);
        assert_eq!(dense.dense_n_in(), 784);
        assert_eq!(dense.n_layers(), 3);
    }
}
