//! Streaming layer-pipelined dataflow engine — `Kernel::Pipelined`.
//!
//! The paper's Verilog datapath earns its throughput from *layer-parallel
//! streaming*: every layer is live hardware and images flow through the
//! chain, one result draining while the next is still being computed.
//! FINN (Umuroglu et al.) and Fraser et al. make the same dataflow
//! argument for scaling binarized networks.  This module is the software
//! analogue: one stage worker thread per hidden layer, connected by
//! fixed-capacity SPSC rings whose currency is the packed `u64`
//! activation words the fused tier already emits
//! ([`packing::xnor_threshold_pack_simd`] produces exactly one word per
//! 64 neurons).  The output stage runs on the calling thread and writes
//! raw `i32` logits straight into the caller's rows, so a depth-`H` model
//! keeps `H` cores busy on a *single* batch — throughput scales with
//! cores × layers, where the fused batch split only scales with
//! batch ÷ [`FUSED_PAR_MIN_CHUNK`](super::FUSED_PAR_MIN_CHUNK).
//!
//! Two stage schedulers live here so there is exactly one home for
//! thread orchestration over [`PreparedModel`] stages:
//!
//! * `run_layer_pipeline` — the dataflow pipeline (`Kernel::Pipelined`),
//!   reached through `PreparedModel::logits_batch_pipelined`.
//! * `run_batch_split` — the chunked batch split the fused tier uses
//!   for large batches (subsumed from `PreparedModel::logits_batch_into`,
//!   which now delegates here).
//!
//! Drain contract (pinned by `tests/pipeline_conformance.rs`): every
//! batch — single-image, ragged, or empty — drains with no deadlock and
//! no lost images; a no-hidden-layer model degenerates to the output
//! stage inline (zero rings, zero threads); and `std::thread::scope`
//! structurally joins every stage worker before the call returns
//! (observable via [`live_stage_threads`]).
//!
//! Ring sizing: capacity 1 already pipelines (stages run in lockstep,
//! hand-over-hand); larger capacities only absorb per-image compute
//! jitter between unevenly sized layers.  [`DEFAULT_RING_CAP`] images of
//! slack per boundary is plenty — each slot is just `words_u64(n_out)`
//! packed words — and the conformance suite sweeps {1, 2, 7, 64} to pin
//! that capacity never changes results.

use super::model::{BinaryDenseLayer, PreparedModel, PreparedPanelLayer, Scratch};
use super::packing;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Default inter-stage ring capacity (in-flight images per layer
/// boundary) — `[coordinator] ring_cap` / `--ring-cap` override it.
pub const DEFAULT_RING_CAP: usize = 8;

// ---------------------------------------------------------------------------
// Bounded SPSC ring
// ---------------------------------------------------------------------------

/// `send` failed because the consumer side was dropped; the undelivered
/// value is handed back.
#[derive(Debug)]
pub struct RingDisconnected<T>(pub T);

struct RingState<T> {
    buf: VecDeque<T>,
    tx_alive: bool,
    rx_alive: bool,
}

struct RingShared<T> {
    state: Mutex<RingState<T>>,
    /// Producer parks here when the ring is full.
    space: Condvar,
    /// Consumer parks here when the ring is empty.
    items: Condvar,
    cap: usize,
}

impl<T> RingShared<T> {
    /// Lock the ring state, recovering from poisoning (a stage panicking
    /// mid-drain must not turn neighbours' joins into double panics).
    fn lock(&self) -> MutexGuard<'_, RingState<T>> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Producer half of a bounded SPSC ring (see [`spsc_ring`]).
pub struct RingSender<T> {
    shared: Arc<RingShared<T>>,
}

/// Consumer half of a bounded SPSC ring (see [`spsc_ring`]).
pub struct RingReceiver<T> {
    shared: Arc<RingShared<T>>,
}

/// A fixed-capacity single-producer single-consumer ring: the inter-stage
/// channel of the dataflow pipeline.  Blocking with no spinning, and both
/// drop directions are wired for clean shutdown — a dropped producer
/// wakes the consumer into the `None` drain path, a dropped consumer
/// unblocks the producer with [`RingDisconnected`] instead of hanging it.
pub fn spsc_ring<T>(cap: usize) -> (RingSender<T>, RingReceiver<T>) {
    assert!(cap >= 1, "ring capacity must be ≥ 1");
    let shared = Arc::new(RingShared {
        state: Mutex::new(RingState {
            buf: VecDeque::with_capacity(cap),
            tx_alive: true,
            rx_alive: true,
        }),
        space: Condvar::new(),
        items: Condvar::new(),
        cap,
    });
    (
        RingSender {
            shared: Arc::clone(&shared),
        },
        RingReceiver { shared },
    )
}

impl<T> RingSender<T> {
    /// Enqueue `value`, blocking while the ring is full.  Errs (returning
    /// the value) once the receiver has been dropped — queued-but-unread
    /// items are abandoned, never silently re-delivered.
    pub fn send(&self, value: T) -> Result<(), RingDisconnected<T>> {
        let mut st = self.shared.lock();
        loop {
            if !st.rx_alive {
                return Err(RingDisconnected(value));
            }
            if st.buf.len() < self.shared.cap {
                st.buf.push_back(value);
                drop(st);
                self.shared.items.notify_one();
                return Ok(());
            }
            st = self
                .shared
                .space
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// The fixed capacity this ring was built with.
    pub fn capacity(&self) -> usize {
        self.shared.cap
    }
}

impl<T> Drop for RingSender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        st.tx_alive = false;
        drop(st);
        // wake a consumer blocked in `recv` so it observes the drain
        self.shared.items.notify_all();
    }
}

impl<T> RingReceiver<T> {
    /// Dequeue the next value, blocking while the ring is empty.  Returns
    /// `None` only once the ring is drained *and* the producer is gone —
    /// FIFO order is preserved to the last item.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.lock();
        loop {
            if let Some(v) = st.buf.pop_front() {
                drop(st);
                self.shared.space.notify_one();
                return Some(v);
            }
            if !st.tx_alive {
                return None;
            }
            st = self
                .shared
                .items
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// The fixed capacity this ring was built with.
    pub fn capacity(&self) -> usize {
        self.shared.cap
    }
}

impl<T> Drop for RingReceiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        st.rx_alive = false;
        st.buf.clear(); // abandoned work is dropped eagerly
        drop(st);
        // wake a producer blocked in `send` so it errors instead of hanging
        self.shared.space.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Stage-thread accounting
// ---------------------------------------------------------------------------

static LIVE_STAGE_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Stage worker threads currently alive across *all* pipelines in the
/// process (both schedulers count).  `std::thread::scope` joins every
/// worker before `run_layer_pipeline` / `run_batch_split` return, so
/// this reads 0 whenever no call is in flight — the conformance suite
/// asserts exactly that after every case to pin joined-on-drop.
pub fn live_stage_threads() -> usize {
    LIVE_STAGE_THREADS.load(Ordering::SeqCst)
}

/// RAII increment of [`live_stage_threads`] for the lifetime of one stage
/// worker (decrements even if the stage unwinds).
struct StageGuard;

impl StageGuard {
    fn enter() -> Self {
        LIVE_STAGE_THREADS.fetch_add(1, Ordering::SeqCst);
        StageGuard
    }
}

impl Drop for StageGuard {
    fn drop(&mut self) {
        LIVE_STAGE_THREADS.fetch_sub(1, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Stage kernels
// ---------------------------------------------------------------------------

/// One hidden stage on one image: threshold-pack every panel of `layer`
/// into `act` (`n_panels()` packed words — the next stage's input).
fn hidden_stage(layer: &PreparedPanelLayer, x: &[u64], act: &mut Vec<u64>) {
    let wpr = layer.words_per_row;
    act.clear();
    for p in 0..layer.n_panels() {
        act.push(packing::xnor_threshold_pack_simd(
            x,
            layer.panel(p),
            wpr,
            layer.n_in,
            layer.panel_thresholds(p),
        ));
    }
}

/// The output stage on one image: raw XNOR-popcount sums written straight
/// into the caller's logits row (no threshold — the sums *are* the
/// logits, §3.4), in the same row blocks the fused walk uses.
fn output_stage(layer: &BinaryDenseLayer, x: &[u64], out_row: &mut [i32]) {
    let wpr = layer.words_per_row;
    let nc = layer.n_out;
    let mut j = 0;
    while j < nc {
        let b = super::DEFAULT_BLOCK_ROWS.min(nc - j);
        let rows = &layer.weights[j * wpr..(j + b) * wpr];
        packing::xnor_popcount_z_simd(x, 1, rows, wpr, layer.n_in, &mut out_row[j..], nc);
        j += b;
    }
}

// ---------------------------------------------------------------------------
// Scheduler 1: the layer pipeline (`Kernel::Pipelined`)
// ---------------------------------------------------------------------------

/// Drive `batch` images through the stage graph: one worker thread per
/// hidden layer chained by `ring_cap`-deep SPSC rings, output stage on
/// the calling thread.  `inputs` is `batch × input_words` row-major and
/// `out` is `batch × n_classes` row-major, exactly like
/// [`PreparedModel::logits_batch_into`]; results are bit-identical to the
/// scalar reference at every ring capacity.
///
/// A conv prefix is lowered on the calling thread before the rings spin
/// up: the stage graph's currency is *dense-level* packed activations, so
/// the fused conv front materializes `batch × dense_input_words` words
/// once and the dense pipeline streams over those (the conv front is a
/// per-image loop and would otherwise serialize stage 0 anyway).
pub(crate) fn run_layer_pipeline(
    prepared: &PreparedModel,
    inputs: &[u64],
    batch: usize,
    out: &mut [i32],
    ring_cap: usize,
) {
    assert!(ring_cap >= 1, "ring_cap must be ≥ 1");
    let iw = packing::words_u64(prepared.n_in());
    assert_eq!(inputs.len(), batch * iw, "batch input length");
    let nc = prepared.n_classes();
    assert_eq!(out.len(), batch * nc, "batch output length");
    if batch == 0 {
        return;
    }
    let lowered: Vec<u64>;
    let (feed, fw) = if prepared.conv_layers().is_empty() {
        (inputs, iw)
    } else {
        let mut scratch = Scratch::default();
        lowered = prepared.conv_front_batch(inputs, batch, &mut scratch);
        (lowered.as_slice(), prepared.dense_input_words())
    };
    let hidden = prepared.hidden_layers();
    let output = prepared.output_layer();
    if hidden.is_empty() {
        // a no-hidden-layer model is a one-stage graph: run the output
        // stage inline — zero rings, zero threads to join
        for (x, row) in feed.chunks_exact(fw).zip(out.chunks_exact_mut(nc)) {
            output_stage(output, x, row);
        }
        return;
    }
    std::thread::scope(|s| {
        // stage 0: pack dense-level input images through the first hidden
        // layer
        let (tx0, mut rx) = spsc_ring::<Vec<u64>>(ring_cap);
        {
            let layer = &hidden[0];
            s.spawn(move || {
                let _live = StageGuard::enter();
                for x in feed.chunks_exact(fw) {
                    let mut act = Vec::with_capacity(layer.n_panels());
                    hidden_stage(layer, x, &mut act);
                    if tx0.send(act).is_err() {
                        return; // downstream died mid-drain; unwind quietly
                    }
                }
                // falling out drops tx0: the drain signal for stage 1
            });
        }
        // stages 1..H: one worker per remaining hidden layer
        for layer in &hidden[1..] {
            let (tx, rx_next) = spsc_ring::<Vec<u64>>(ring_cap);
            let rx_prev = rx;
            rx = rx_next;
            s.spawn(move || {
                let _live = StageGuard::enter();
                while let Some(x) = rx_prev.recv() {
                    let mut act = Vec::with_capacity(layer.n_panels());
                    hidden_stage(layer, &x, &mut act);
                    if tx.send(act).is_err() {
                        return;
                    }
                }
            });
        }
        // the output stage drains the final ring on the calling thread,
        // writing each image's logits row the moment it arrives — the
        // `chunks_exact_mut` bound guarantees no image is lost or extra
        for row in out.chunks_exact_mut(nc) {
            let x = rx
                .recv()
                .expect("pipeline drained early: a stage thread died");
            output_stage(output, &x, row);
        }
        // `thread::scope` joins every stage worker here (joined-on-drop)
    });
}

// ---------------------------------------------------------------------------
// Scheduler 2: the chunked batch split (fused tier, large batches)
// ---------------------------------------------------------------------------

/// Split `batch` images into per-thread chunks of at least `min_chunk`
/// and run `walk` on each in a scoped worker (fresh local [`Scratch`] per
/// worker, amortized over its chunk); small batches run `walk` serially
/// on the caller's `scratch`.  Per-image results are independent, so the
/// split is bit-identical to the serial walk for every batch size.
/// `PreparedModel::logits_batch_into` delegates its parallel split here
/// so both stage schedulers share one home (and one thread-accounting
/// path — [`live_stage_threads`] covers these workers too).
pub(crate) fn run_batch_split(
    inputs: &[u64],
    batch: usize,
    scratch: &mut Scratch,
    out: &mut [i32],
    words_per_image: usize,
    n_classes: usize,
    min_chunk: usize,
    walk: &(dyn Fn(&[u64], usize, &mut Scratch, &mut [i32]) + Sync),
) {
    assert!(min_chunk >= 1, "min_chunk must be ≥ 1");
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get());
    let chunks = (batch / min_chunk).min(threads);
    if chunks < 2 {
        walk(inputs, batch, scratch, out);
        return;
    }
    let per = batch.div_ceil(chunks);
    std::thread::scope(|s| {
        for (in_c, out_c) in inputs
            .chunks(per * words_per_image)
            .zip(out.chunks_mut(per * n_classes))
        {
            s.spawn(move || {
                let _live = StageGuard::enter();
                let mut local = Scratch::default();
                let n = out_c.len() / n_classes;
                walk(in_c, n, &mut local, out_c);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::{random_model, PreparedModel};
    use crate::bnn::packing::pack_bits_u64;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest_lite::{gens, Runner};
    use std::sync::mpsc;
    use std::time::Duration;

    // --- SPSC ring properties (ISSUE 6 satellite) ---

    #[test]
    fn ring_preserves_fifo_order_at_every_capacity() {
        Runner::new("spsc-ring-fifo").cases(32).run(
            &gens::Pair(
                gens::U64(1..=9),
                gens::VecU64 {
                    len: 0..=80,
                    elem: 0..=u64::MAX - 1,
                },
            ),
            |(cap, items)| {
                let (tx, rx) = spsc_ring::<u64>(*cap as usize);
                let sent = items.clone();
                let producer = std::thread::spawn(move || {
                    for v in sent {
                        if tx.send(v).is_err() {
                            return;
                        }
                    }
                });
                let mut got = Vec::new();
                while let Some(v) = rx.recv() {
                    got.push(v);
                }
                producer.join().unwrap();
                got == *items
            },
        );
    }

    #[test]
    fn capacity_one_ring_ping_pongs_in_lockstep() {
        let (tx, rx) = spsc_ring::<u64>(1);
        assert_eq!(tx.capacity(), 1);
        let producer = std::thread::spawn(move || {
            for v in 0..200u64 {
                tx.send(v).unwrap(); // every send waits for the matching recv
            }
        });
        for want in 0..200u64 {
            assert_eq!(rx.recv(), Some(want));
        }
        assert_eq!(rx.recv(), None, "drained ring with a dropped producer");
        producer.join().unwrap();
    }

    #[test]
    fn producer_drop_wakes_a_blocked_consumer() {
        let (tx, rx) = spsc_ring::<u64>(4);
        tx.send(7).unwrap();
        let (done_tx, done_rx) = mpsc::channel();
        std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = rx.recv() {
                got.push(v);
            }
            done_tx.send(got).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20)); // let the consumer park
        drop(tx);
        let got = done_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("consumer must wake (not hang) when the producer drops");
        assert_eq!(got, vec![7], "buffered items still drain before None");
    }

    #[test]
    fn consumer_drop_errors_a_blocked_producer() {
        let (tx, rx) = spsc_ring::<u64>(1);
        tx.send(1).unwrap(); // ring now full
        let (done_tx, done_rx) = mpsc::channel();
        std::thread::spawn(move || {
            let res = tx.send(2); // parks on the full ring
            done_tx.send(res).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20)); // let the producer park
        drop(rx);
        let res = done_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("producer must unblock (not hang) when the consumer drops");
        assert_eq!(
            res.unwrap_err().0,
            2,
            "the undelivered value rides back in the error"
        );
    }

    #[test]
    fn send_to_a_dropped_consumer_errors_immediately() {
        let (tx, rx) = spsc_ring::<u64>(4);
        drop(rx);
        assert_eq!(tx.send(9).unwrap_err().0, 9);
    }

    // --- pipeline walk spot checks (the full golden + fuzz matrix lives
    //     in tests/pipeline_conformance.rs) ---

    fn packed_batch(rng: &mut Xoshiro256, n_in: usize, batch: usize) -> Vec<u64> {
        let mut inputs = Vec::new();
        for _ in 0..batch {
            let bits: Vec<u8> = (0..n_in).map(|_| rng.bool() as u8).collect();
            inputs.extend(pack_bits_u64(&bits));
        }
        inputs
    }

    #[test]
    fn pipelined_walk_matches_scalar_on_the_paper_shape() {
        let model = random_model(&[784, 128, 64, 10], 42);
        let prepared = PreparedModel::new(&model).unwrap();
        let mut rng = Xoshiro256::new(7);
        for batch in [1usize, 2, 9] {
            let inputs = packed_batch(&mut rng, 784, batch);
            let want = model.logits_batch(&inputs, batch);
            for cap in [1usize, 3] {
                let mut got = vec![0i32; batch * 10];
                prepared.logits_batch_pipelined(&inputs, batch, &mut got, cap);
                assert_eq!(got, want, "batch {batch}, ring cap {cap}");
            }
        }
    }

    #[test]
    fn pipelined_walk_handles_a_no_hidden_layer_model_inline() {
        let model = random_model(&[65, 10], 5);
        let prepared = PreparedModel::new(&model).unwrap();
        let mut rng = Xoshiro256::new(8);
        let inputs = packed_batch(&mut rng, 65, 3);
        let want = model.logits_batch(&inputs, 3);
        let mut got = vec![0i32; 3 * 10];
        prepared.logits_batch_pipelined(&inputs, 3, &mut got, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let model = random_model(&[64, 32, 10], 3);
        let prepared = PreparedModel::new(&model).unwrap();
        prepared.logits_batch_pipelined(&[], 0, &mut [], DEFAULT_RING_CAP);
    }
}
