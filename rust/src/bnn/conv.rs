//! Binary convolution layers (model format v2), lowered onto the dense
//! XNOR-popcount machinery via **im2col-to-packed-words**.
//!
//! A [`BinaryConvLayer`] is a dense "core" ([`BinaryDenseLayer`] with
//! `n_in = k²·C_in`, `n_out = C_out`, mandatory integer thresholds)
//! plus spatial geometry.  Executing it gathers each output patch's
//! receptive field into packed u64 words — one contiguous `k·C_in`-bit
//! run per kernel row ([`packing::copy_bits`]), padding stays 0 (= −1)
//! — and then every dense kernel tier applies unchanged per patch: the
//! scalar/blocked paths via [`BinaryDenseLayer::z`], the fused tier via
//! [`packing::xnor_threshold_pack`] over 64-channel panels (see
//! `PreparedConvLayer` in [`super::model`]).  DESIGN.md §Binary
//! convolution derives the layout math.
//!
//! Bit layouts (fixed by the format, shared with the Python generator):
//!
//! * activations: bit index `(y·W + x)·C + c` — pixel-major,
//!   channel-minor, so a `1×28×28` first layer consumes the existing
//!   784-bit row-major MNIST packing unchanged;
//! * core weight rows: bit index `(ky·k + kx)·C_in + c` (the im2col
//!   patch layout);
//! * output geometry: `out = (in + 2·pad − k) / stride + 1` (floor),
//!   sign activation `z ≥ θ` packs bit `(oy·out_w + ox)·C_out + c_out`.

use anyhow::{bail, Result};

use super::model::{BinaryDenseLayer, BnnModel};
use super::packing;
use crate::util::prng::Xoshiro256;

/// Layer kind tag — the format-v2 `type` field and the introspection
/// vocabulary (`weights.json` v1 files carry no tag and default to
/// [`LayerKind::Dense`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Binary convolution ([`BinaryConvLayer`]).
    Conv,
    /// Binary dense / fully-connected ([`BinaryDenseLayer`]).
    Dense,
}

impl LayerKind {
    /// The format-v2 `type` string.
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Dense => "dense",
        }
    }

    /// Parse a format-v2 `type` string.
    pub fn parse(s: &str) -> Option<LayerKind> {
        match s {
            "conv" => Some(LayerKind::Conv),
            "dense" => Some(LayerKind::Dense),
            _ => None,
        }
    }
}

/// Output spatial extent of one axis: `(n + 2p − k)/s + 1` (floor), or
/// `None` when the kernel does not fit even once.
pub fn conv_out_dim(n: usize, k: usize, stride: usize, pad: usize) -> Option<usize> {
    if k == 0 || stride == 0 || n + 2 * pad < k {
        return None;
    }
    Some((n + 2 * pad - k) / stride + 1)
}

/// One binary convolution layer: spatial geometry around a dense core of
/// `C_out` packed weight rows × `k²·C_in` bits, with mandatory integer
/// thresholds (every conv layer emits sign activations — the raw-sum
/// output layer of a model is always dense, §3.4).
#[derive(Clone, Debug)]
pub struct BinaryConvLayer {
    pub in_ch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
    /// The conv cores as a dense layer: `n_in = k²·in_ch` (im2col patch
    /// bits), `n_out = C_out`, thresholds mandatory.
    pub core: BinaryDenseLayer,
}

impl BinaryConvLayer {
    /// Build and validate (geometry must admit ≥ 1 output position; the
    /// core must match `k²·C_in` and carry thresholds).
    pub fn new(
        in_ch: usize,
        in_h: usize,
        in_w: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
        core: BinaryDenseLayer,
    ) -> Result<Self> {
        let layer = Self {
            in_ch,
            in_h,
            in_w,
            kernel,
            stride,
            pad,
            core,
        };
        layer.validate()?;
        Ok(layer)
    }

    /// Geometry/core consistency checks (also run by
    /// [`BnnModel::validate`]).
    pub fn validate(&self) -> Result<()> {
        if self.in_ch == 0 || self.in_h == 0 || self.in_w == 0 {
            bail!(
                "conv input shape must be non-zero, got {}×{}×{}",
                self.in_ch,
                self.in_h,
                self.in_w
            );
        }
        if self.kernel == 0 {
            bail!("conv kernel must be ≥ 1");
        }
        if self.stride == 0 {
            bail!("conv stride must be ≥ 1");
        }
        if self.pad >= self.kernel {
            bail!(
                "conv pad {} must be < kernel {} (an all-padding patch is degenerate)",
                self.pad,
                self.kernel
            );
        }
        if conv_out_dim(self.in_h, self.kernel, self.stride, self.pad).is_none()
            || conv_out_dim(self.in_w, self.kernel, self.stride, self.pad).is_none()
        {
            bail!(
                "conv kernel {} does not fit {}×{} input with pad {}",
                self.kernel,
                self.in_h,
                self.in_w,
                self.pad
            );
        }
        if self.core.n_in != self.patch_bits() {
            bail!(
                "conv core has n_in {} but k²·C_in = {}",
                self.core.n_in,
                self.patch_bits()
            );
        }
        if self.core.n_out == 0 {
            bail!("conv layer needs ≥ 1 output channel");
        }
        if self.core.thresholds.is_none() {
            bail!("conv layer missing thresholds (sign activation is mandatory)");
        }
        Ok(())
    }

    /// Output channels (`C_out` = core rows).
    #[inline]
    pub fn out_ch(&self) -> usize {
        self.core.n_out
    }

    /// Output height.
    #[inline]
    pub fn out_h(&self) -> usize {
        conv_out_dim(self.in_h, self.kernel, self.stride, self.pad).expect("validated geometry")
    }

    /// Output width.
    #[inline]
    pub fn out_w(&self) -> usize {
        conv_out_dim(self.in_w, self.kernel, self.stride, self.pad).expect("validated geometry")
    }

    /// Output positions per image (`out_h × out_w`).
    #[inline]
    pub fn n_patches(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// im2col patch width in bits (`k²·C_in` = the core's `n_in`).
    #[inline]
    pub fn patch_bits(&self) -> usize {
        self.kernel * self.kernel * self.in_ch
    }

    /// Input activation bits (`C_in·H·W`).
    #[inline]
    pub fn in_bits(&self) -> usize {
        self.in_ch * self.in_h * self.in_w
    }

    /// Output activation bits (`C_out·out_h·out_w`).
    #[inline]
    pub fn out_bits(&self) -> usize {
        self.out_ch() * self.n_patches()
    }

    /// Gather output position `(oy, ox)`'s receptive field into `patch`
    /// (pre-sized to the core's `words_per_row`; bits beyond
    /// [`Self::patch_bits`] stay 0).  Each in-bounds kernel row is one
    /// contiguous `run·C_in`-bit copy; padding rows/columns are skipped
    /// and stay 0, which the XNOR-popcount treats as −1 — the binary
    /// analogue of FINN-style −1 padding.
    pub fn gather_patch(&self, x: &[u64], oy: usize, ox: usize, patch: &mut [u64]) {
        patch.fill(0);
        let (k, c) = (self.kernel, self.in_ch);
        let base_y = (oy * self.stride) as isize - self.pad as isize;
        let base_x = (ox * self.stride) as isize - self.pad as isize;
        for ky in 0..k {
            let iy = base_y + ky as isize;
            if iy < 0 || iy >= self.in_h as isize {
                continue;
            }
            let ix0 = base_x.max(0) as usize;
            let ix1 = (base_x + k as isize).min(self.in_w as isize) as usize;
            if ix0 >= ix1 {
                continue;
            }
            let src = (iy as usize * self.in_w + ix0) * c;
            let dst = (ky * k + (ix0 as isize - base_x) as usize) * c;
            packing::copy_bits(patch, dst, x, src, (ix1 - ix0) * c);
        }
    }

    /// Scalar-reference forward pass: packed input activations → packed
    /// output activations (`out` must hold `words_u64(out_bits())` words;
    /// `patch` is the reusable im2col arena).  Per patch this is exactly
    /// the dense scalar walk — [`BinaryDenseLayer::z`] per output channel,
    /// sign at the folded threshold — so every dense-tier equivalence
    /// proof transfers per patch.
    pub fn forward(&self, x: &[u64], out: &mut [u64], patch: &mut Vec<u64>) {
        debug_assert!(x.len() >= packing::words_u64(self.in_bits()));
        assert_eq!(out.len(), packing::words_u64(self.out_bits()), "conv output arena");
        out.fill(0);
        patch.clear();
        patch.resize(self.core.words_per_row, 0);
        let (oc, ow) = (self.out_ch(), self.out_w());
        let thr = self.core.thresholds.as_ref().expect("validated: conv thresholds");
        for oy in 0..self.out_h() {
            for ox in 0..ow {
                let pos = oy * ow + ox;
                self.gather_patch(x, oy, ox, patch);
                for (co, &t) in thr.iter().enumerate().take(oc) {
                    if self.core.z(patch, co) >= t {
                        let bit = pos * oc + co;
                        out[bit / 64] |= 1u64 << (bit % 64);
                    }
                }
            }
        }
    }
}

/// Deterministic random mixed conv→dense model with zero thresholds — the
/// conv counterpart of [`super::model::random_model`], mirrored
/// draw-for-draw by `python/tools/gen_golden_vectors.py`
/// (`random_conv_model`): one PRNG stream, conv layers first (row-major
/// `rng.bool()` per weight bit in `(ky·k + kx)·C_in + c` order), then the
/// dense stack on the flattened width.
pub fn random_conv_model(
    in_shape: (usize, usize, usize),
    convs: &[(usize, usize, usize, usize)], // (out_ch, kernel, stride, pad)
    dense: &[usize],
    seed: u64,
) -> BnnModel {
    assert!(!convs.is_empty(), "need at least one conv layer");
    assert!(!dense.is_empty(), "need at least the dense output layer");
    let mut rng = Xoshiro256::new(seed);
    let (mut c, mut h, mut w) = in_shape;
    let mut conv_layers = Vec::new();
    for &(out_ch, kernel, stride, pad) in convs {
        let patch = kernel * kernel * c;
        let rows_u32: Vec<Vec<u32>> = (0..out_ch)
            .map(|_| {
                let bits: Vec<u8> = (0..patch).map(|_| rng.bool() as u8).collect();
                packing::pack_bits_u32(&bits)
            })
            .collect();
        let core = BinaryDenseLayer::from_u32_rows(patch, &rows_u32, Some(vec![0i32; out_ch]))
            .expect("random conv core is well-formed");
        let layer = BinaryConvLayer::new(c, h, w, kernel, stride, pad, core)
            .expect("random conv geometry is well-formed");
        (c, h, w) = (out_ch, layer.out_h(), layer.out_w());
        conv_layers.push(layer);
    }
    let mut dims = vec![c * h * w];
    dims.extend_from_slice(dense);
    let mut dense_layers = Vec::new();
    for (li, pair) in dims.windows(2).enumerate() {
        let rows_u32: Vec<Vec<u32>> = (0..pair[1])
            .map(|_| {
                let bits: Vec<u8> = (0..pair[0]).map(|_| rng.bool() as u8).collect();
                packing::pack_bits_u32(&bits)
            })
            .collect();
        let thr = (li + 2 < dims.len()).then(|| vec![0i32; pair[1]]);
        dense_layers.push(
            BinaryDenseLayer::from_u32_rows(pair[0], &rows_u32, thr)
                .expect("random dense layer is well-formed"),
        );
    }
    let model = BnnModel::with_conv(conv_layers, dense_layers);
    model.validate().expect("random conv model is well-formed");
    model
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::packing::{pack_bits_u64, unpack_bits_u64, words_u64};

    /// Independent naive reference: nested loops over ±1 values with
    /// explicit bounds checks (padding contributes −1), no packing, no
    /// im2col — the same oracle the Python generator cross-checks.
    pub(crate) fn naive_conv_bits(layer: &BinaryConvLayer, x_bits: &[u8]) -> Vec<u8> {
        let (ci, h, w) = (layer.in_ch, layer.in_h, layer.in_w);
        let (k, s, p) = (layer.kernel, layer.stride as isize, layer.pad as isize);
        let oc = layer.out_ch();
        let thr = layer.core.thresholds.as_ref().unwrap();
        let weight_bit = |co: usize, bit: usize| -> i32 {
            let row = layer.core.row(co);
            if (row[bit / 64] >> (bit % 64)) & 1 == 1 {
                1
            } else {
                -1
            }
        };
        let mut out = Vec::new();
        for oy in 0..layer.out_h() {
            for ox in 0..layer.out_w() {
                for co in 0..oc {
                    let mut z = 0i32;
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = oy as isize * s - p + ky as isize;
                            let ix = ox as isize * s - p + kx as isize;
                            for c in 0..ci {
                                let xv = if iy >= 0
                                    && iy < h as isize
                                    && ix >= 0
                                    && ix < w as isize
                                    && x_bits[(iy as usize * w + ix as usize) * ci + c] == 1
                                {
                                    1i32
                                } else {
                                    -1
                                };
                                z += xv * weight_bit(co, (ky * k + kx) * ci + c);
                            }
                        }
                    }
                    out.push(u8::from(z >= thr[co]));
                }
            }
        }
        out
    }

    fn random_layer(
        rng: &mut Xoshiro256,
        in_ch: usize,
        in_h: usize,
        in_w: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> BinaryConvLayer {
        let patch = kernel * kernel * in_ch;
        let rows: Vec<Vec<u32>> = (0..out_ch)
            .map(|_| {
                let bits: Vec<u8> = (0..patch).map(|_| rng.bool() as u8).collect();
                packing::pack_bits_u32(&bits)
            })
            .collect();
        let thr: Vec<i32> = (0..out_ch)
            .map(|_| rng.range_i64(-(patch as i64), patch as i64) as i32)
            .collect();
        let core = BinaryDenseLayer::from_u32_rows(patch, &rows, Some(thr)).unwrap();
        BinaryConvLayer::new(in_ch, in_h, in_w, kernel, stride, pad, core).unwrap()
    }

    #[test]
    fn packed_forward_matches_naive_reference() {
        // the im2col-to-packed-words lowering vs the nested-loop ±1
        // oracle over kernel {1,3,5} × stride {1,2} × pad {0,1} ×
        // channel counts off the 64-bit word grid
        let mut rng = Xoshiro256::new(0xC04B);
        for k in [1usize, 3, 5] {
            for s in [1usize, 2] {
                for p in [0usize, 1] {
                    if p >= k {
                        continue;
                    }
                    for (ci, co) in [(1usize, 5usize), (3, 7), (2, 66)] {
                        let h = k.max(5);
                        let layer = random_layer(&mut rng, ci, h, h, co, k, s, p);
                        let x_bits: Vec<u8> =
                            (0..layer.in_bits()).map(|_| rng.bool() as u8).collect();
                        let x = pack_bits_u64(&x_bits);
                        let mut out = vec![0u64; words_u64(layer.out_bits())];
                        let mut patch = Vec::new();
                        layer.forward(&x, &mut out, &mut patch);
                        assert_eq!(
                            unpack_bits_u64(&out, layer.out_bits()),
                            naive_conv_bits(&layer, &x_bits),
                            "k={k} s={s} p={p} ci={ci} co={co}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn geometry_accessors_match_the_formula() {
        let mut rng = Xoshiro256::new(0x6E0);
        let layer = random_layer(&mut rng, 1, 28, 28, 8, 3, 1, 1);
        assert_eq!((layer.out_h(), layer.out_w()), (28, 28));
        assert_eq!(layer.patch_bits(), 9);
        assert_eq!(layer.in_bits(), 784);
        assert_eq!(layer.out_bits(), 8 * 28 * 28);
        let strided = random_layer(&mut rng, 1, 28, 28, 6, 5, 2, 0);
        assert_eq!((strided.out_h(), strided.out_w()), (12, 12));
        assert_eq!(conv_out_dim(4, 5, 1, 0), None);
        assert_eq!(conv_out_dim(5, 5, 1, 0), Some(1));
        assert_eq!(conv_out_dim(9, 3, 2, 0), Some(4));
    }

    #[test]
    fn validation_rejects_degenerate_geometry() {
        let mut rng = Xoshiro256::new(0xBAD);
        let good = random_layer(&mut rng, 2, 6, 6, 4, 3, 1, 1);
        // kernel larger than the padded input
        assert!(
            BinaryConvLayer::new(2, 2, 2, 5, 1, 1, good.core.clone()).is_err(),
            "kernel must fit"
        );
        // zero stride
        assert!(BinaryConvLayer::new(2, 6, 6, 3, 0, 1, good.core.clone()).is_err());
        // pad ≥ kernel
        assert!(BinaryConvLayer::new(2, 6, 6, 3, 1, 3, good.core.clone()).is_err());
        // core width mismatch (claims 1 input channel → patch 9 ≠ 18)
        assert!(BinaryConvLayer::new(1, 6, 6, 3, 1, 1, good.core.clone()).is_err());
        // missing thresholds
        let mut raw = good.core.clone();
        raw.thresholds = None;
        assert!(BinaryConvLayer::new(2, 6, 6, 3, 1, 1, raw).is_err());
    }

    #[test]
    fn layer_kind_round_trips() {
        for kind in [LayerKind::Conv, LayerKind::Dense] {
            assert_eq!(LayerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(LayerKind::parse("pooling"), None);
    }

    #[test]
    fn random_conv_model_is_deterministic_and_valid() {
        let a = random_conv_model((1, 28, 28), &[(8, 3, 1, 1)], &[64, 10], 42);
        let b = random_conv_model((1, 28, 28), &[(8, 3, 1, 1)], &[64, 10], 42);
        assert!(a.validate().is_ok());
        assert_eq!(a.conv.len(), 1);
        assert_eq!(a.n_in(), 784);
        assert_eq!(a.layers[0].n_in, 8 * 28 * 28);
        assert_eq!(a.conv[0].core.weights, b.conv[0].core.weights);
        let c = random_conv_model((1, 28, 28), &[(8, 3, 1, 1)], &[64, 10], 43);
        assert_ne!(a.conv[0].core.weights, c.conv[0].core.weights);
    }
}
