//! Bit packing for binary (±1) vectors — Rust mirror of
//! `python/compile/kernels/packing.py`.
//!
//! Convention (identical across Python, the `.mem` files after bit-order
//! conversion, and both Rust word widths): value +1 ↦ bit 1, −1 ↦ bit 0;
//! bit *i* of the logical vector lives at position `i % W` of word `i / W`,
//! LSB-first.  Padding bits beyond `n` are 0 in every operand, so XOR never
//! counts them.
//!
//! Two physical widths:
//! * `u32` — the interchange width (weights.json, PJRT artifact inputs);
//! * `u64` — the native hot-path width (half the words per row, one
//!   `popcnt` per 64 bits).

/// Number of u64 words for `n` bits.
pub const fn words_u64(n_bits: usize) -> usize {
    n_bits.div_ceil(64)
}

/// Number of u32 words for `n` bits.
pub const fn words_u32(n_bits: usize) -> usize {
    n_bits.div_ceil(32)
}

/// Pack a `{0,1}` bit slice into u64 words (LSB-first).
pub fn pack_bits_u64(bits: &[u8]) -> Vec<u64> {
    let mut words = vec![0u64; words_u64(bits.len())];
    for (i, &b) in bits.iter().enumerate() {
        debug_assert!(b <= 1, "bit value {b} at {i}");
        words[i / 64] |= u64::from(b & 1) << (i % 64);
    }
    words
}

/// Pack a `{0,1}` bit slice into u32 words (the Python/PJRT interchange).
pub fn pack_bits_u32(bits: &[u8]) -> Vec<u32> {
    let mut words = vec![0u32; words_u32(bits.len())];
    for (i, &b) in bits.iter().enumerate() {
        words[i / 32] |= u32::from(b & 1) << (i % 32);
    }
    words
}

/// Unpack u64 words back into `n_bits` bits.
pub fn unpack_bits_u64(words: &[u64], n_bits: usize) -> Vec<u8> {
    (0..n_bits)
        .map(|i| ((words[i / 64] >> (i % 64)) & 1) as u8)
        .collect()
}

/// Convert u32 interchange words into u64 hot-path words (same bit layout).
pub fn u32_words_to_u64(words32: &[u32], n_bits: usize) -> Vec<u64> {
    let mut out = vec![0u64; words_u64(n_bits)];
    for (i, &w) in words32.iter().enumerate() {
        out[i / 2] |= u64::from(w) << (32 * (i % 2));
    }
    out
}

/// Convert u64 hot-path words into u32 interchange words.
pub fn u64_words_to_u32(words64: &[u64], n_bits: usize) -> Vec<u32> {
    let mut out = vec![0u32; words_u32(n_bits)];
    u64_words_to_u32_into(words64, n_bits, &mut out);
    out
}

/// Allocation-free variant of [`u64_words_to_u32`]: write the first
/// `words_u32(n_bits)` interchange words of `words64` into `out` (staging
/// buffers on the serve hot path reuse one arena across batches).
///
/// Panics when `out` cannot hold them — a short destination would
/// otherwise silently truncate the vector (per-image cold path, so the
/// hard check costs nothing measurable).
pub fn u64_words_to_u32_into(words64: &[u64], n_bits: usize, out: &mut [u32]) {
    assert!(
        out.len() >= words_u32(n_bits),
        "{} u32 words needed, destination holds {}",
        words_u32(n_bits),
        out.len()
    );
    for (i, o) in out.iter_mut().enumerate().take(words_u32(n_bits)) {
        *o = (words64[i / 2] >> (32 * (i % 2))) as u32;
    }
}

/// A packed binary vector with its logical bit length.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Packed {
    pub words: Vec<u64>,
    pub n_bits: usize,
}

impl Packed {
    pub fn from_bits(bits: &[u8]) -> Self {
        Packed {
            words: pack_bits_u64(bits),
            n_bits: bits.len(),
        }
    }

    pub fn from_u32_words(words32: &[u32], n_bits: usize) -> Self {
        Packed {
            words: u32_words_to_u64(words32, n_bits),
            n_bits,
        }
    }

    pub fn to_bits(&self) -> Vec<u8> {
        unpack_bits_u64(&self.words, self.n_bits)
    }

    pub fn to_u32_words(&self) -> Vec<u32> {
        u64_words_to_u32(&self.words, self.n_bits)
    }

    /// Signed ±1 dot product with another packed vector of the same length:
    /// `z = n − 2·popcount(a ⊕ b)` (§2.1).
    pub fn dot(&self, other: &Packed) -> i32 {
        assert_eq!(self.n_bits, other.n_bits, "length mismatch in binary dot");
        xnor_popcount_z(&self.words, &other.words, self.n_bits)
    }
}

/// Core identity on raw word slices (hot path, no allocation).
///
/// Perf note (EXPERIMENTS.md §Perf iterations 1–2): two alternatives were
/// measured against this simple zip-sum — a 4-way manually unrolled
/// accumulator (+55 % slower) and a fixed-13-word specialization (+35 %
/// slower).  LLVM already auto-vectorizes this form into the AVX2
/// popcount sequence; manual restructuring defeated it.  Kept naive —
/// this is the measured practical roofline (~1.2 ns/word).
#[inline]
pub fn xnor_popcount_z(a: &[u64], b: &[u64], n_bits: usize) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut mismatches = 0u32;
    for (x, w) in a.iter().zip(b.iter()) {
        mismatches += (x ^ w).count_ones();
    }
    n_bits as i32 - 2 * mismatches as i32
}

/// Blocked multi-row kernel: pre-activation sums for `out.len()` consecutive
/// weight rows against one packed input, in a single pass over `x`.
///
/// This is the software mirror of the FPGA's parallelism parameter `P`
/// (§3.3: `P` neuron units consume each broadcast input bit at once): rows
/// are processed in register tiles of four, so every input word loaded from
/// cache is XORed against four weight rows before the next load, amortizing
/// input traffic that the scalar path ([`xnor_popcount_z`]) re-pays per
/// neuron.  `rows` is `out.len() × words_per_row` words, row-major — exactly
/// the [`super::model::BinaryDenseLayer::weights`] layout, so layers can
/// hand in weight sub-slices with no copying.
///
/// Padding-bit contract: as everywhere in this module, bits ≥ `n_bits` must
/// be 0 in *every* operand so XOR never counts them (property-tested below).
///
/// Bit-identical to the scalar path by construction — both compute
/// `z = n − 2·popcount(x ⊕ w)` exactly; see `blocked_equals_scalar_*` tests.
///
/// ```
/// use bnn_fpga::bnn::packing::{pack_bits_u64, words_u64, xnor_popcount_z_block};
/// let x = pack_bits_u64(&[1, 0, 1]);
/// let rows = [pack_bits_u64(&[1, 1, 1]), pack_bits_u64(&[0, 0, 0])].concat();
/// let mut z = [0i32; 2];
/// xnor_popcount_z_block(&x, &rows, words_u64(3), 3, &mut z);
/// assert_eq!(z, [1, -1]); // (+1·+1 −1·+1 +1·+1), (+1·−1 −1·−1 +1·−1)
/// ```
pub fn xnor_popcount_z_block(
    x: &[u64],
    rows: &[u64],
    words_per_row: usize,
    n_bits: usize,
    out: &mut [i32],
) {
    if out.is_empty() {
        return;
    }
    debug_assert!(words_per_row >= 1);
    debug_assert_eq!(x.len(), words_per_row);
    debug_assert_eq!(rows.len(), out.len() * words_per_row);
    let n = n_bits as i32;
    let mut quads = rows.chunks_exact(4 * words_per_row);
    let mut outs = out.chunks_exact_mut(4);
    for (quad, o) in (&mut quads).zip(&mut outs) {
        let (r0, rest) = quad.split_at(words_per_row);
        let (r1, rest) = rest.split_at(words_per_row);
        let (r2, r3) = rest.split_at(words_per_row);
        let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
        for ((((xw, w0), w1), w2), w3) in x.iter().zip(r0).zip(r1).zip(r2).zip(r3) {
            c0 += (xw ^ w0).count_ones();
            c1 += (xw ^ w1).count_ones();
            c2 += (xw ^ w2).count_ones();
            c3 += (xw ^ w3).count_ones();
        }
        o[0] = n - 2 * c0 as i32;
        o[1] = n - 2 * c1 as i32;
        o[2] = n - 2 * c2 as i32;
        o[3] = n - 2 * c3 as i32;
    }
    for (row, o) in quads
        .remainder()
        .chunks_exact(words_per_row)
        .zip(outs.into_remainder())
    {
        *o = xnor_popcount_z(x, row, n_bits);
    }
}

/// Weight-stationary batch-tile kernel: pre-activation sums for every
/// (image, weight-row) pair of an `n_imgs × n_rows` tile, with each weight
/// row walked **once per image pair** instead of once per image.
///
/// This is the software mirror of the FPGA datapath's weight reuse (§3.3:
/// each ROM row is read once and broadcast while the image stream flows
/// past it) and of FINN-style matrix–vector folding across a batch
/// (PAPERS.md, Umuroglu et al. / Fraser et al.): the per-image blocked
/// kernel ([`xnor_popcount_z_block`]) re-traverses the packed weight
/// matrix for every image, while this kernel holds a 4-row weight quad in
/// registers and streams two images through it — 8 independent popcount
/// chains per inner iteration, 6 loads per 8 XNOR-popcounts instead of 5
/// per 4.
///
/// Layout contracts (all row-major, no copies needed by callers):
/// * `imgs` — `n_imgs × words_per_row` packed input words (the flat
///   activation arena of [`super::model::Scratch`]);
/// * `rows` — `n_rows × words_per_row` packed weight rows, exactly the
///   [`super::model::BinaryDenseLayer::weights`] sub-slice layout;
/// * `out[i * out_stride + j] = z(img_i, row_j)` with `out_stride ≥ n_rows`
///   (a stride larger than `n_rows` lets layers write row blocks straight
///   into a `batch × n_classes` logits buffer).
///
/// Padding-bit contract: as everywhere in this module, bits ≥ `n_bits`
/// must be 0 in *every* operand.  Bit-identical to [`xnor_popcount_z`] by
/// construction — both compute `z = n − 2·popcount(x ⊕ w)` exactly; the
/// remainder rows/images fall back to the blocked/scalar kernels
/// (property-tested below).
///
/// ```
/// use bnn_fpga::bnn::packing::{pack_bits_u64, words_u64, xnor_popcount_z_tile};
/// let imgs = [pack_bits_u64(&[1, 0, 1]), pack_bits_u64(&[0, 0, 0])].concat();
/// let rows = [pack_bits_u64(&[1, 1, 1]), pack_bits_u64(&[0, 0, 0])].concat();
/// let mut z = [0i32; 4];
/// xnor_popcount_z_tile(&imgs, 2, &rows, words_u64(3), 3, &mut z, 2);
/// assert_eq!(z, [1, -1, -3, 3]); // [img0·row0, img0·row1, img1·row0, img1·row1]
/// ```
#[allow(clippy::too_many_arguments)]
pub fn xnor_popcount_z_tile(
    imgs: &[u64],
    n_imgs: usize,
    rows: &[u64],
    words_per_row: usize,
    n_bits: usize,
    out: &mut [i32],
    out_stride: usize,
) {
    debug_assert!(words_per_row >= 1);
    debug_assert_eq!(imgs.len(), n_imgs * words_per_row);
    debug_assert_eq!(rows.len() % words_per_row, 0);
    let n_rows = rows.len() / words_per_row;
    if n_rows == 0 || n_imgs == 0 {
        return;
    }
    debug_assert!(out_stride >= n_rows);
    debug_assert!(out.len() >= (n_imgs - 1) * out_stride + n_rows);
    let n = n_bits as i32;

    // 4-row × 2-image register tiles; each weight quad stays resident
    // while the tile's images stream through it.
    let mut q = 0;
    while q + 4 <= n_rows {
        let r0 = &rows[q * words_per_row..(q + 1) * words_per_row];
        let r1 = &rows[(q + 1) * words_per_row..(q + 2) * words_per_row];
        let r2 = &rows[(q + 2) * words_per_row..(q + 3) * words_per_row];
        let r3 = &rows[(q + 3) * words_per_row..(q + 4) * words_per_row];
        let mut i = 0;
        while i + 2 <= n_imgs {
            let xa = &imgs[i * words_per_row..(i + 1) * words_per_row];
            let xb = &imgs[(i + 1) * words_per_row..(i + 2) * words_per_row];
            let (mut a0, mut a1, mut a2, mut a3) = (0u32, 0u32, 0u32, 0u32);
            let (mut b0, mut b1, mut b2, mut b3) = (0u32, 0u32, 0u32, 0u32);
            for (((((x0, x1), w0), w1), w2), w3) in
                xa.iter().zip(xb).zip(r0).zip(r1).zip(r2).zip(r3)
            {
                a0 += (x0 ^ w0).count_ones();
                a1 += (x0 ^ w1).count_ones();
                a2 += (x0 ^ w2).count_ones();
                a3 += (x0 ^ w3).count_ones();
                b0 += (x1 ^ w0).count_ones();
                b1 += (x1 ^ w1).count_ones();
                b2 += (x1 ^ w2).count_ones();
                b3 += (x1 ^ w3).count_ones();
            }
            let oa = i * out_stride + q;
            out[oa] = n - 2 * a0 as i32;
            out[oa + 1] = n - 2 * a1 as i32;
            out[oa + 2] = n - 2 * a2 as i32;
            out[oa + 3] = n - 2 * a3 as i32;
            let ob = (i + 1) * out_stride + q;
            out[ob] = n - 2 * b0 as i32;
            out[ob + 1] = n - 2 * b1 as i32;
            out[ob + 2] = n - 2 * b2 as i32;
            out[ob + 3] = n - 2 * b3 as i32;
            i += 2;
        }
        if i < n_imgs {
            // odd trailing image: one blocked pass over the same quad
            let x = &imgs[i * words_per_row..(i + 1) * words_per_row];
            let quad = &rows[q * words_per_row..(q + 4) * words_per_row];
            let o = i * out_stride + q;
            xnor_popcount_z_block(x, quad, words_per_row, n_bits, &mut out[o..o + 4]);
        }
        q += 4;
    }
    // remaining rows (< 4): scalar per (image, row)
    for r in q..n_rows {
        let row = &rows[r * words_per_row..(r + 1) * words_per_row];
        for i in 0..n_imgs {
            let x = &imgs[i * words_per_row..(i + 1) * words_per_row];
            out[i * out_stride + r] = xnor_popcount_z(x, row, n_bits);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest_lite::{gens, Runner};

    #[test]
    fn pack_known_patterns() {
        assert_eq!(pack_bits_u64(&[1]), vec![1]);
        let mut bits = vec![0u8; 65];
        bits[64] = 1;
        assert_eq!(pack_bits_u64(&bits), vec![0, 1]);
        assert_eq!(pack_bits_u32(&[0, 1]), vec![2]);
    }

    #[test]
    fn roundtrip_property() {
        Runner::new("u64-pack-roundtrip").run(&gens::BitVec(1..=800), |bits| {
            unpack_bits_u64(&pack_bits_u64(bits), bits.len()) == *bits
        });
    }

    #[test]
    fn u32_u64_conversion_property() {
        Runner::new("u32<->u64-words").run(&gens::BitVec(1..=800), |bits| {
            let w32 = pack_bits_u32(bits);
            let w64 = pack_bits_u64(bits);
            u32_words_to_u64(&w32, bits.len()) == w64
                && u64_words_to_u32(&w64, bits.len()) == w32
        });
    }

    #[test]
    fn dot_identity_vs_naive() {
        // z = Σ ±1·±1 must equal n − 2·popcount(xor) for random vectors.
        let mut rng = Xoshiro256::new(99);
        for _ in 0..50 {
            let n = 1 + rng.below(790) as usize;
            let a_bits: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
            let b_bits: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
            let naive: i32 = a_bits
                .iter()
                .zip(&b_bits)
                .map(|(&a, &b)| if a == b { 1 } else { -1 })
                .sum();
            let a = Packed::from_bits(&a_bits);
            let b = Packed::from_bits(&b_bits);
            assert_eq!(a.dot(&b), naive);
            // parity + bound invariants
            assert_eq!((a.dot(&b) - n as i32) % 2, 0);
            assert!(a.dot(&b).abs() <= n as i32);
        }
    }

    #[test]
    fn dot_extremes() {
        let ones = Packed::from_bits(&vec![1u8; 784]);
        let zeros = Packed::from_bits(&vec![0u8; 784]);
        assert_eq!(ones.dot(&ones), 784);
        assert_eq!(ones.dot(&zeros), -784);
        assert_eq!(zeros.dot(&zeros), 784);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dot_length_checked() {
        let a = Packed::from_bits(&[1, 0]);
        let b = Packed::from_bits(&[1]);
        let _ = a.dot(&b);
    }

    #[test]
    fn padding_bits_never_count() {
        // 65 bits: padding in word 1 must not affect the dot product.
        let a = Packed::from_bits(&vec![1u8; 65]);
        let b = Packed::from_bits(&vec![0u8; 65]);
        assert_eq!(a.dot(&b), -65);
    }

    /// The widths the stack actually meets (layer widths 784/128/64/10) plus
    /// the word-boundary edge cases (1, 63, 65) for both physical widths.
    const EDGE_WIDTHS: [usize; 5] = [784, 10, 1, 63, 65];

    fn random_bits(rng: &mut Xoshiro256, n: usize) -> Vec<u8> {
        (0..n).map(|_| rng.bool() as u8).collect()
    }

    #[test]
    fn roundtrip_u32_u64_at_edge_widths() {
        let mut rng = Xoshiro256::new(2026);
        for &n in &EDGE_WIDTHS {
            for _ in 0..10 {
                let bits = random_bits(&mut rng, n);
                let w32 = pack_bits_u32(&bits);
                let w64 = pack_bits_u64(&bits);
                assert_eq!(w32.len(), words_u32(n));
                assert_eq!(w64.len(), words_u64(n));
                // bits → u32 → u64 → u32 → bits is the identity at every width
                assert_eq!(u32_words_to_u64(&w32, n), w64, "width {n}");
                assert_eq!(u64_words_to_u32(&w64, n), w32, "width {n}");
                assert_eq!(unpack_bits_u64(&w64, n), bits, "width {n}");
                let back = Packed::from_u32_words(&w32, n);
                assert_eq!(back.to_bits(), bits, "width {n}");
                assert_eq!(back.to_u32_words(), w32, "width {n}");
            }
        }
    }

    #[test]
    fn padding_bits_are_zero_at_edge_widths() {
        // The invariant the blocked kernel leans on: every packer leaves
        // bits ≥ n zero, in both word widths.
        let mut rng = Xoshiro256::new(2027);
        for &n in &EDGE_WIDTHS {
            let bits = vec![1u8; n]; // worst case: all ones up to the boundary
            let w64 = pack_bits_u64(&bits);
            let w32 = pack_bits_u32(&bits);
            let pad64 = words_u64(n) * 64 - n;
            let pad32 = words_u32(n) * 32 - n;
            if pad64 > 0 {
                assert_eq!(w64.last().unwrap() >> (64 - pad64), 0, "u64 padding, width {n}");
            }
            if pad32 > 0 {
                assert_eq!(w32.last().unwrap() >> (32 - pad32), 0, "u32 padding, width {n}");
            }
            // and the u32→u64 conversion cannot invent padding bits either
            let conv = u32_words_to_u64(&w32, n);
            if pad64 > 0 {
                assert_eq!(conv.last().unwrap() >> (64 - pad64), 0, "converted padding, width {n}");
            }
            // total popcount is preserved exactly (no bit lost, none invented)
            let pop: u32 = w64.iter().map(|w| w.count_ones()).sum();
            assert_eq!(pop as usize, n);
            let _ = random_bits(&mut rng, n); // keep the stream moving per width
        }
    }

    #[test]
    fn blocked_equals_scalar_at_edge_widths() {
        // The blocked kernel must be bit-identical to the scalar path for
        // every row count around its 4-row register tile (0..=9 rows) and
        // every edge width, including the sub-word and straddling ones.
        let mut rng = Xoshiro256::new(2028);
        for &n in &EDGE_WIDTHS {
            let wpr = words_u64(n);
            for n_rows in 0..=9usize {
                let x = pack_bits_u64(&random_bits(&mut rng, n));
                let mut rows = Vec::with_capacity(n_rows * wpr);
                for _ in 0..n_rows {
                    rows.extend(pack_bits_u64(&random_bits(&mut rng, n)));
                }
                let mut blocked = vec![0i32; n_rows];
                xnor_popcount_z_block(&x, &rows, wpr, n, &mut blocked);
                let scalar: Vec<i32> = (0..n_rows)
                    .map(|r| xnor_popcount_z(&x, &rows[r * wpr..(r + 1) * wpr], n))
                    .collect();
                assert_eq!(blocked, scalar, "width {n}, {n_rows} rows");
            }
        }
    }

    #[test]
    fn tile_equals_scalar_at_edge_widths() {
        // The tile kernel must be bit-identical to the scalar path for
        // every (image count, row count) around its 2-image × 4-row
        // register tile, at every edge width.
        let mut rng = Xoshiro256::new(2029);
        for &n in &EDGE_WIDTHS {
            let wpr = words_u64(n);
            for n_imgs in 0..=5usize {
                for n_rows in 0..=9usize {
                    let mut imgs = Vec::with_capacity(n_imgs * wpr);
                    for _ in 0..n_imgs {
                        imgs.extend(pack_bits_u64(&random_bits(&mut rng, n)));
                    }
                    let mut rows = Vec::with_capacity(n_rows * wpr);
                    for _ in 0..n_rows {
                        rows.extend(pack_bits_u64(&random_bits(&mut rng, n)));
                    }
                    let mut tiled = vec![0i32; n_imgs * n_rows.max(1)];
                    xnor_popcount_z_tile(&imgs, n_imgs, &rows, wpr, n, &mut tiled, n_rows.max(1));
                    for i in 0..n_imgs {
                        for r in 0..n_rows {
                            let want = xnor_popcount_z(
                                &imgs[i * wpr..(i + 1) * wpr],
                                &rows[r * wpr..(r + 1) * wpr],
                                n,
                            );
                            assert_eq!(
                                tiled[i * n_rows.max(1) + r],
                                want,
                                "width {n}, {n_imgs} imgs, {n_rows} rows, ({i},{r})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn tile_respects_wide_out_stride() {
        // out_stride > n_rows writes a row block into a wider logits
        // buffer without touching the columns beyond the block.
        let mut rng = Xoshiro256::new(2030);
        let n = 65;
        let wpr = words_u64(n);
        let (n_imgs, n_rows, stride) = (3usize, 5usize, 9usize);
        let mut imgs = Vec::new();
        for _ in 0..n_imgs {
            imgs.extend(pack_bits_u64(&random_bits(&mut rng, n)));
        }
        let mut rows = Vec::new();
        for _ in 0..n_rows {
            rows.extend(pack_bits_u64(&random_bits(&mut rng, n)));
        }
        let mut out = vec![i32::MIN; n_imgs * stride];
        xnor_popcount_z_tile(&imgs, n_imgs, &rows, wpr, n, &mut out, stride);
        for i in 0..n_imgs {
            for c in 0..stride {
                let got = out[i * stride + c];
                if c < n_rows {
                    let want = xnor_popcount_z(
                        &imgs[i * wpr..(i + 1) * wpr],
                        &rows[c * wpr..(c + 1) * wpr],
                        n,
                    );
                    assert_eq!(got, want, "img {i} row {c}");
                } else {
                    assert_eq!(got, i32::MIN, "img {i} col {c} clobbered");
                }
            }
        }
    }

    #[test]
    fn tile_kernel_matches_naive_property() {
        // Property: for random widths, image counts and row counts, the
        // tile kernel equals the ±1 definition (so padding never leaks).
        Runner::new("tile-vs-naive").cases(32).run(
            &gens::Pair(gens::BitVec(1..=200), gens::Pair(gens::U64(1..=5), gens::U64(1..=10))),
            |(bits, (n_imgs, n_rows))| {
                let n = bits.len();
                let wpr = words_u64(n);
                let (n_imgs, n_rows) = (*n_imgs as usize, *n_rows as usize);
                let mut rng = Xoshiro256::new(n as u64 * 37 + n_imgs as u64 * 7 + n_rows as u64);
                let mut img_bits = vec![bits.clone()];
                for _ in 1..n_imgs {
                    img_bits.push((0..n).map(|_| rng.bool() as u8).collect());
                }
                let mut row_bits = Vec::new();
                for _ in 0..n_rows {
                    row_bits.push((0..n).map(|_| rng.bool() as u8).collect::<Vec<u8>>());
                }
                let imgs: Vec<u64> = img_bits.iter().flat_map(|b| pack_bits_u64(b)).collect();
                let rows: Vec<u64> = row_bits.iter().flat_map(|b| pack_bits_u64(b)).collect();
                let mut tiled = vec![0i32; n_imgs * n_rows];
                xnor_popcount_z_tile(&imgs, n_imgs, &rows, wpr, n, &mut tiled, n_rows);
                img_bits.iter().enumerate().all(|(i, xb)| {
                    row_bits.iter().enumerate().all(|(r, wb)| {
                        let naive: i32 = xb
                            .iter()
                            .zip(wb)
                            .map(|(&a, &b)| if a == b { 1i32 } else { -1 })
                            .sum();
                        tiled[i * n_rows + r] == naive
                    })
                })
            },
        );
    }

    #[test]
    fn blocked_kernel_ignores_padding_property() {
        // Property: for random widths and row counts, blocked == scalar ==
        // the ±1 definition, so padding can never leak into any row's sum.
        Runner::new("blocked-vs-naive").cases(48).run(
            &gens::Pair(gens::BitVec(1..=200), gens::U64(1..=12)),
            |(bits, n_rows)| {
                let n = bits.len();
                let wpr = words_u64(n);
                let n_rows = *n_rows as usize;
                let mut rng = Xoshiro256::new(n as u64 * 131 + n_rows as u64);
                let x = pack_bits_u64(bits);
                let mut rows = Vec::new();
                let mut naive = Vec::new();
                for _ in 0..n_rows {
                    let w: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
                    naive.push(
                        w.iter()
                            .zip(bits)
                            .map(|(&a, &b)| if a == b { 1i32 } else { -1 })
                            .sum::<i32>(),
                    );
                    rows.extend(pack_bits_u64(&w));
                }
                let mut blocked = vec![0i32; n_rows];
                xnor_popcount_z_block(&x, &rows, wpr, n, &mut blocked);
                blocked == naive
            },
        );
    }
}
