//! Bit packing for binary (±1) vectors — Rust mirror of
//! `python/compile/kernels/packing.py`.
//!
//! Convention (identical across Python, the `.mem` files after bit-order
//! conversion, and both Rust word widths): value +1 ↦ bit 1, −1 ↦ bit 0;
//! bit *i* of the logical vector lives at position `i % W` of word `i / W`,
//! LSB-first.  Padding bits beyond `n` are 0 in every operand, so XOR never
//! counts them.
//!
//! Two physical widths:
//! * `u32` — the interchange width (weights.json, PJRT artifact inputs);
//! * `u64` — the native hot-path width (half the words per row, one
//!   `popcnt` per 64 bits).

/// Number of u64 words for `n` bits.
pub const fn words_u64(n_bits: usize) -> usize {
    n_bits.div_ceil(64)
}

/// Number of u32 words for `n` bits.
pub const fn words_u32(n_bits: usize) -> usize {
    n_bits.div_ceil(32)
}

/// Pack a `{0,1}` bit slice into u64 words (LSB-first).
pub fn pack_bits_u64(bits: &[u8]) -> Vec<u64> {
    let mut words = vec![0u64; words_u64(bits.len())];
    for (i, &b) in bits.iter().enumerate() {
        debug_assert!(b <= 1, "bit value {b} at {i}");
        words[i / 64] |= u64::from(b & 1) << (i % 64);
    }
    words
}

/// Pack a `{0,1}` bit slice into u32 words (the Python/PJRT interchange).
pub fn pack_bits_u32(bits: &[u8]) -> Vec<u32> {
    let mut words = vec![0u32; words_u32(bits.len())];
    for (i, &b) in bits.iter().enumerate() {
        words[i / 32] |= u32::from(b & 1) << (i % 32);
    }
    words
}

/// Unpack u64 words back into `n_bits` bits.
pub fn unpack_bits_u64(words: &[u64], n_bits: usize) -> Vec<u8> {
    (0..n_bits)
        .map(|i| ((words[i / 64] >> (i % 64)) & 1) as u8)
        .collect()
}

/// Convert u32 interchange words into u64 hot-path words (same bit layout).
pub fn u32_words_to_u64(words32: &[u32], n_bits: usize) -> Vec<u64> {
    let mut out = vec![0u64; words_u64(n_bits)];
    for (i, &w) in words32.iter().enumerate() {
        out[i / 2] |= u64::from(w) << (32 * (i % 2));
    }
    out
}

/// Convert u64 hot-path words into u32 interchange words.
pub fn u64_words_to_u32(words64: &[u64], n_bits: usize) -> Vec<u32> {
    (0..words_u32(n_bits))
        .map(|i| (words64[i / 2] >> (32 * (i % 2))) as u32)
        .collect()
}

/// A packed binary vector with its logical bit length.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Packed {
    pub words: Vec<u64>,
    pub n_bits: usize,
}

impl Packed {
    pub fn from_bits(bits: &[u8]) -> Self {
        Packed {
            words: pack_bits_u64(bits),
            n_bits: bits.len(),
        }
    }

    pub fn from_u32_words(words32: &[u32], n_bits: usize) -> Self {
        Packed {
            words: u32_words_to_u64(words32, n_bits),
            n_bits,
        }
    }

    pub fn to_bits(&self) -> Vec<u8> {
        unpack_bits_u64(&self.words, self.n_bits)
    }

    pub fn to_u32_words(&self) -> Vec<u32> {
        u64_words_to_u32(&self.words, self.n_bits)
    }

    /// Signed ±1 dot product with another packed vector of the same length:
    /// `z = n − 2·popcount(a ⊕ b)` (§2.1).
    pub fn dot(&self, other: &Packed) -> i32 {
        assert_eq!(self.n_bits, other.n_bits, "length mismatch in binary dot");
        xnor_popcount_z(&self.words, &other.words, self.n_bits)
    }
}

/// Core identity on raw word slices (hot path, no allocation).
///
/// Perf note (EXPERIMENTS.md §Perf iterations 1–2): two alternatives were
/// measured against this simple zip-sum — a 4-way manually unrolled
/// accumulator (+55 % slower) and a fixed-13-word specialization (+35 %
/// slower).  LLVM already auto-vectorizes this form into the AVX2
/// popcount sequence; manual restructuring defeated it.  Kept naive —
/// this is the measured practical roofline (~1.2 ns/word).
#[inline]
pub fn xnor_popcount_z(a: &[u64], b: &[u64], n_bits: usize) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut mismatches = 0u32;
    for (x, w) in a.iter().zip(b.iter()) {
        mismatches += (x ^ w).count_ones();
    }
    n_bits as i32 - 2 * mismatches as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest_lite::{gens, Runner};

    #[test]
    fn pack_known_patterns() {
        assert_eq!(pack_bits_u64(&[1]), vec![1]);
        let mut bits = vec![0u8; 65];
        bits[64] = 1;
        assert_eq!(pack_bits_u64(&bits), vec![0, 1]);
        assert_eq!(pack_bits_u32(&[0, 1]), vec![2]);
    }

    #[test]
    fn roundtrip_property() {
        Runner::new("u64-pack-roundtrip").run(&gens::BitVec(1..=800), |bits| {
            unpack_bits_u64(&pack_bits_u64(bits), bits.len()) == *bits
        });
    }

    #[test]
    fn u32_u64_conversion_property() {
        Runner::new("u32<->u64-words").run(&gens::BitVec(1..=800), |bits| {
            let w32 = pack_bits_u32(bits);
            let w64 = pack_bits_u64(bits);
            u32_words_to_u64(&w32, bits.len()) == w64
                && u64_words_to_u32(&w64, bits.len()) == w32
        });
    }

    #[test]
    fn dot_identity_vs_naive() {
        // z = Σ ±1·±1 must equal n − 2·popcount(xor) for random vectors.
        let mut rng = Xoshiro256::new(99);
        for _ in 0..50 {
            let n = 1 + rng.below(790) as usize;
            let a_bits: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
            let b_bits: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
            let naive: i32 = a_bits
                .iter()
                .zip(&b_bits)
                .map(|(&a, &b)| if a == b { 1 } else { -1 })
                .sum();
            let a = Packed::from_bits(&a_bits);
            let b = Packed::from_bits(&b_bits);
            assert_eq!(a.dot(&b), naive);
            // parity + bound invariants
            assert_eq!((a.dot(&b) - n as i32) % 2, 0);
            assert!(a.dot(&b).abs() <= n as i32);
        }
    }

    #[test]
    fn dot_extremes() {
        let ones = Packed::from_bits(&vec![1u8; 784]);
        let zeros = Packed::from_bits(&vec![0u8; 784]);
        assert_eq!(ones.dot(&ones), 784);
        assert_eq!(ones.dot(&zeros), -784);
        assert_eq!(zeros.dot(&zeros), 784);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dot_length_checked() {
        let a = Packed::from_bits(&[1, 0]);
        let b = Packed::from_bits(&[1]);
        let _ = a.dot(&b);
    }

    #[test]
    fn padding_bits_never_count() {
        // 65 bits: padding in word 1 must not affect the dot product.
        let a = Packed::from_bits(&vec![1u8; 65]);
        let b = Packed::from_bits(&vec![0u8; 65]);
        assert_eq!(a.dot(&b), -65);
    }
}
