//! Bit packing for binary (±1) vectors — Rust mirror of
//! `python/compile/kernels/packing.py`.
//!
//! Convention (identical across Python, the `.mem` files after bit-order
//! conversion, and both Rust word widths): value +1 ↦ bit 1, −1 ↦ bit 0;
//! bit *i* of the logical vector lives at position `i % W` of word `i / W`,
//! LSB-first.  Padding bits beyond `n` are 0 in every operand, so XOR never
//! counts them.
//!
//! Two physical widths:
//! * `u32` — the interchange width (weights.json, PJRT artifact inputs);
//! * `u64` — the native hot-path width (half the words per row, one
//!   `popcnt` per 64 bits).

/// Number of u64 words for `n` bits.
pub const fn words_u64(n_bits: usize) -> usize {
    n_bits.div_ceil(64)
}

/// Number of u32 words for `n` bits.
pub const fn words_u32(n_bits: usize) -> usize {
    n_bits.div_ceil(32)
}

/// Pack a `{0,1}` bit slice into u64 words (LSB-first).
pub fn pack_bits_u64(bits: &[u8]) -> Vec<u64> {
    let mut words = vec![0u64; words_u64(bits.len())];
    for (i, &b) in bits.iter().enumerate() {
        debug_assert!(b <= 1, "bit value {b} at {i}");
        words[i / 64] |= u64::from(b & 1) << (i % 64);
    }
    words
}

/// Pack a `{0,1}` bit slice into u32 words (the Python/PJRT interchange).
pub fn pack_bits_u32(bits: &[u8]) -> Vec<u32> {
    let mut words = vec![0u32; words_u32(bits.len())];
    for (i, &b) in bits.iter().enumerate() {
        words[i / 32] |= u32::from(b & 1) << (i % 32);
    }
    words
}

/// Unpack u64 words back into `n_bits` bits.
pub fn unpack_bits_u64(words: &[u64], n_bits: usize) -> Vec<u8> {
    (0..n_bits)
        .map(|i| ((words[i / 64] >> (i % 64)) & 1) as u8)
        .collect()
}

/// Read `n ≤ 64` bits of `src` starting at bit `off` into a u64 (LSB
/// first).  `src` must cover `off + n` bits.
#[inline]
pub fn read_bits(src: &[u64], off: usize, n: usize) -> u64 {
    debug_assert!(n >= 1 && n <= 64);
    let (w, s) = (off / 64, off % 64);
    let mut v = src[w] >> s;
    if s != 0 && s + n > 64 {
        v |= src[w + 1] << (64 - s);
    }
    if n < 64 {
        v &= (1u64 << n) - 1;
    }
    v
}

/// OR `len ≤ 64` bits of `word` (LSB first) into `dst` starting at bit
/// `off`.  The target bits must currently be 0 (the packed-arena zero-fill
/// contract) — the splice ORs, it does not clear.
#[inline]
pub fn splice_bits(dst: &mut [u64], off: usize, word: u64, len: usize) {
    debug_assert!(len >= 1 && len <= 64);
    let masked = if len == 64 { word } else { word & ((1u64 << len) - 1) };
    let (w, s) = (off / 64, off % 64);
    dst[w] |= masked << s;
    if s != 0 && s + len > 64 {
        dst[w + 1] |= masked >> (64 - s);
    }
}

/// Copy a contiguous run of `len` bits from `src` (starting at `src_off`)
/// into `dst` (starting at `dst_off`), neither necessarily word-aligned.
/// This is the im2col gather primitive (`bnn::conv`): each kernel row of a
/// receptive field is one contiguous `k·C_in`-bit run in the pixel-major
/// activation layout, so a whole patch assembles from ≤ `k` of these
/// copies instead of `k²·C_in` single-bit probes.  Target bits must
/// currently be 0 (OR semantics, as [`splice_bits`]).
#[inline]
pub fn copy_bits(dst: &mut [u64], dst_off: usize, src: &[u64], src_off: usize, len: usize) {
    let mut done = 0;
    while done < len {
        let n = (len - done).min(64);
        let w = read_bits(src, src_off + done, n);
        splice_bits(dst, dst_off + done, w, n);
        done += n;
    }
}

/// Convert u32 interchange words into u64 hot-path words (same bit layout).
pub fn u32_words_to_u64(words32: &[u32], n_bits: usize) -> Vec<u64> {
    let mut out = vec![0u64; words_u64(n_bits)];
    for (i, &w) in words32.iter().enumerate() {
        out[i / 2] |= u64::from(w) << (32 * (i % 2));
    }
    out
}

/// Convert u64 hot-path words into u32 interchange words.
pub fn u64_words_to_u32(words64: &[u64], n_bits: usize) -> Vec<u32> {
    let mut out = vec![0u32; words_u32(n_bits)];
    u64_words_to_u32_into(words64, n_bits, &mut out);
    out
}

/// Allocation-free variant of [`u64_words_to_u32`]: write the first
/// `words_u32(n_bits)` interchange words of `words64` into `out` (staging
/// buffers on the serve hot path reuse one arena across batches).
///
/// Panics when `out` cannot hold them — a short destination would
/// otherwise silently truncate the vector (per-image cold path, so the
/// hard check costs nothing measurable).
pub fn u64_words_to_u32_into(words64: &[u64], n_bits: usize, out: &mut [u32]) {
    assert!(
        out.len() >= words_u32(n_bits),
        "{} u32 words needed, destination holds {}",
        words_u32(n_bits),
        out.len()
    );
    for (i, o) in out.iter_mut().enumerate().take(words_u32(n_bits)) {
        *o = (words64[i / 2] >> (32 * (i % 2))) as u32;
    }
}

/// A packed binary vector with its logical bit length.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Packed {
    pub words: Vec<u64>,
    pub n_bits: usize,
}

impl Packed {
    pub fn from_bits(bits: &[u8]) -> Self {
        Packed {
            words: pack_bits_u64(bits),
            n_bits: bits.len(),
        }
    }

    pub fn from_u32_words(words32: &[u32], n_bits: usize) -> Self {
        Packed {
            words: u32_words_to_u64(words32, n_bits),
            n_bits,
        }
    }

    pub fn to_bits(&self) -> Vec<u8> {
        unpack_bits_u64(&self.words, self.n_bits)
    }

    pub fn to_u32_words(&self) -> Vec<u32> {
        u64_words_to_u32(&self.words, self.n_bits)
    }

    /// Signed ±1 dot product with another packed vector of the same length:
    /// `z = n − 2·popcount(a ⊕ b)` (§2.1).
    pub fn dot(&self, other: &Packed) -> i32 {
        assert_eq!(self.n_bits, other.n_bits, "length mismatch in binary dot");
        xnor_popcount_z(&self.words, &other.words, self.n_bits)
    }
}

/// Core identity on raw word slices (hot path, no allocation).
///
/// Perf note (EXPERIMENTS.md §Perf iterations 1–2): two alternatives were
/// measured against this simple zip-sum — a 4-way manually unrolled
/// accumulator (+55 % slower) and a fixed-13-word specialization (+35 %
/// slower).  LLVM already auto-vectorizes this form into the AVX2
/// popcount sequence; manual restructuring defeated it.  Kept naive —
/// this is the measured practical roofline (~1.2 ns/word).
#[inline]
pub fn xnor_popcount_z(a: &[u64], b: &[u64], n_bits: usize) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut mismatches = 0u32;
    for (x, w) in a.iter().zip(b.iter()) {
        mismatches += (x ^ w).count_ones();
    }
    n_bits as i32 - 2 * mismatches as i32
}

/// Blocked multi-row kernel: pre-activation sums for `out.len()` consecutive
/// weight rows against one packed input, in a single pass over `x`.
///
/// This is the software mirror of the FPGA's parallelism parameter `P`
/// (§3.3: `P` neuron units consume each broadcast input bit at once): rows
/// are processed in register tiles of four, so every input word loaded from
/// cache is XORed against four weight rows before the next load, amortizing
/// input traffic that the scalar path ([`xnor_popcount_z`]) re-pays per
/// neuron.  `rows` is `out.len() × words_per_row` words, row-major — exactly
/// the [`super::model::BinaryDenseLayer::weights`] layout, so layers can
/// hand in weight sub-slices with no copying.
///
/// Padding-bit contract: as everywhere in this module, bits ≥ `n_bits` must
/// be 0 in *every* operand so XOR never counts them (property-tested below).
///
/// Bit-identical to the scalar path by construction — both compute
/// `z = n − 2·popcount(x ⊕ w)` exactly; see `blocked_equals_scalar_*` tests.
///
/// ```
/// use bnn_fpga::bnn::packing::{pack_bits_u64, words_u64, xnor_popcount_z_block};
/// let x = pack_bits_u64(&[1, 0, 1]);
/// let rows = [pack_bits_u64(&[1, 1, 1]), pack_bits_u64(&[0, 0, 0])].concat();
/// let mut z = [0i32; 2];
/// xnor_popcount_z_block(&x, &rows, words_u64(3), 3, &mut z);
/// assert_eq!(z, [1, -1]); // (+1·+1 −1·+1 +1·+1), (+1·−1 −1·−1 +1·−1)
/// ```
pub fn xnor_popcount_z_block(
    x: &[u64],
    rows: &[u64],
    words_per_row: usize,
    n_bits: usize,
    out: &mut [i32],
) {
    if out.is_empty() {
        return;
    }
    debug_assert!(words_per_row >= 1);
    debug_assert_eq!(x.len(), words_per_row);
    debug_assert_eq!(rows.len(), out.len() * words_per_row);
    let n = n_bits as i32;
    let mut quads = rows.chunks_exact(4 * words_per_row);
    let mut outs = out.chunks_exact_mut(4);
    for (quad, o) in (&mut quads).zip(&mut outs) {
        let (r0, rest) = quad.split_at(words_per_row);
        let (r1, rest) = rest.split_at(words_per_row);
        let (r2, r3) = rest.split_at(words_per_row);
        let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
        for ((((xw, w0), w1), w2), w3) in x.iter().zip(r0).zip(r1).zip(r2).zip(r3) {
            c0 += (xw ^ w0).count_ones();
            c1 += (xw ^ w1).count_ones();
            c2 += (xw ^ w2).count_ones();
            c3 += (xw ^ w3).count_ones();
        }
        o[0] = n - 2 * c0 as i32;
        o[1] = n - 2 * c1 as i32;
        o[2] = n - 2 * c2 as i32;
        o[3] = n - 2 * c3 as i32;
    }
    for (row, o) in quads
        .remainder()
        .chunks_exact(words_per_row)
        .zip(outs.into_remainder())
    {
        *o = xnor_popcount_z(x, row, n_bits);
    }
}

/// Weight-stationary batch-tile kernel: pre-activation sums for every
/// (image, weight-row) pair of an `n_imgs × n_rows` tile, with each weight
/// row walked **once per image pair** instead of once per image.
///
/// This is the software mirror of the FPGA datapath's weight reuse (§3.3:
/// each ROM row is read once and broadcast while the image stream flows
/// past it) and of FINN-style matrix–vector folding across a batch
/// (PAPERS.md, Umuroglu et al. / Fraser et al.): the per-image blocked
/// kernel ([`xnor_popcount_z_block`]) re-traverses the packed weight
/// matrix for every image, while this kernel holds a 4-row weight quad in
/// registers and streams two images through it — 8 independent popcount
/// chains per inner iteration, 6 loads per 8 XNOR-popcounts instead of 5
/// per 4.
///
/// Layout contracts (all row-major, no copies needed by callers):
/// * `imgs` — `n_imgs × words_per_row` packed input words (the flat
///   activation arena of [`super::model::Scratch`]);
/// * `rows` — `n_rows × words_per_row` packed weight rows, exactly the
///   [`super::model::BinaryDenseLayer::weights`] sub-slice layout;
/// * `out[i * out_stride + j] = z(img_i, row_j)` with `out_stride ≥ n_rows`
///   (a stride larger than `n_rows` lets layers write row blocks straight
///   into a `batch × n_classes` logits buffer).
///
/// Padding-bit contract: as everywhere in this module, bits ≥ `n_bits`
/// must be 0 in *every* operand.  Bit-identical to [`xnor_popcount_z`] by
/// construction — both compute `z = n − 2·popcount(x ⊕ w)` exactly; the
/// remainder rows/images fall back to the blocked/scalar kernels
/// (property-tested below).
///
/// ```
/// use bnn_fpga::bnn::packing::{pack_bits_u64, words_u64, xnor_popcount_z_tile};
/// let imgs = [pack_bits_u64(&[1, 0, 1]), pack_bits_u64(&[0, 0, 0])].concat();
/// let rows = [pack_bits_u64(&[1, 1, 1]), pack_bits_u64(&[0, 0, 0])].concat();
/// let mut z = [0i32; 4];
/// xnor_popcount_z_tile(&imgs, 2, &rows, words_u64(3), 3, &mut z, 2);
/// assert_eq!(z, [1, -1, -3, 3]); // [img0·row0, img0·row1, img1·row0, img1·row1]
/// ```
#[allow(clippy::too_many_arguments)]
pub fn xnor_popcount_z_tile(
    imgs: &[u64],
    n_imgs: usize,
    rows: &[u64],
    words_per_row: usize,
    n_bits: usize,
    out: &mut [i32],
    out_stride: usize,
) {
    debug_assert!(words_per_row >= 1);
    debug_assert_eq!(imgs.len(), n_imgs * words_per_row);
    debug_assert_eq!(rows.len() % words_per_row, 0);
    let n_rows = rows.len() / words_per_row;
    if n_rows == 0 || n_imgs == 0 {
        return;
    }
    debug_assert!(out_stride >= n_rows);
    debug_assert!(out.len() >= (n_imgs - 1) * out_stride + n_rows);
    let n = n_bits as i32;

    // 4-row × 2-image register tiles; each weight quad stays resident
    // while the tile's images stream through it.
    let mut q = 0;
    while q + 4 <= n_rows {
        let r0 = &rows[q * words_per_row..(q + 1) * words_per_row];
        let r1 = &rows[(q + 1) * words_per_row..(q + 2) * words_per_row];
        let r2 = &rows[(q + 2) * words_per_row..(q + 3) * words_per_row];
        let r3 = &rows[(q + 3) * words_per_row..(q + 4) * words_per_row];
        let mut i = 0;
        while i + 2 <= n_imgs {
            let xa = &imgs[i * words_per_row..(i + 1) * words_per_row];
            let xb = &imgs[(i + 1) * words_per_row..(i + 2) * words_per_row];
            let (mut a0, mut a1, mut a2, mut a3) = (0u32, 0u32, 0u32, 0u32);
            let (mut b0, mut b1, mut b2, mut b3) = (0u32, 0u32, 0u32, 0u32);
            for (((((x0, x1), w0), w1), w2), w3) in
                xa.iter().zip(xb).zip(r0).zip(r1).zip(r2).zip(r3)
            {
                a0 += (x0 ^ w0).count_ones();
                a1 += (x0 ^ w1).count_ones();
                a2 += (x0 ^ w2).count_ones();
                a3 += (x0 ^ w3).count_ones();
                b0 += (x1 ^ w0).count_ones();
                b1 += (x1 ^ w1).count_ones();
                b2 += (x1 ^ w2).count_ones();
                b3 += (x1 ^ w3).count_ones();
            }
            let oa = i * out_stride + q;
            out[oa] = n - 2 * a0 as i32;
            out[oa + 1] = n - 2 * a1 as i32;
            out[oa + 2] = n - 2 * a2 as i32;
            out[oa + 3] = n - 2 * a3 as i32;
            let ob = (i + 1) * out_stride + q;
            out[ob] = n - 2 * b0 as i32;
            out[ob + 1] = n - 2 * b1 as i32;
            out[ob + 2] = n - 2 * b2 as i32;
            out[ob + 3] = n - 2 * b3 as i32;
            i += 2;
        }
        if i < n_imgs {
            // odd trailing image: one blocked pass over the same quad
            let x = &imgs[i * words_per_row..(i + 1) * words_per_row];
            let quad = &rows[q * words_per_row..(q + 4) * words_per_row];
            let o = i * out_stride + q;
            xnor_popcount_z_block(x, quad, words_per_row, n_bits, &mut out[o..o + 4]);
        }
        q += 4;
    }
    // remaining rows (< 4): scalar per (image, row)
    for r in q..n_rows {
        let row = &rows[r * words_per_row..(r + 1) * words_per_row];
        for i in 0..n_imgs {
            let x = &imgs[i * words_per_row..(i + 1) * words_per_row];
            out[i * out_stride + r] = xnor_popcount_z(x, row, n_bits);
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD kernel tier (runtime-dispatched explicit vectorization)

/// Which vector path the SIMD kernel tier ([`xnor_popcount_z_simd`])
/// resolves to at runtime.
///
/// Dispatch is decided once per process ([`simd_level`]): AVX2 on x86_64
/// hosts that report it, NEON on aarch64, and the guaranteed-portable
/// fallback (the tiled kernel, [`xnor_popcount_z_tile`]) everywhere else —
/// or anywhere when `BNN_FORCE_SCALAR=1` is set, which pins the tier to
/// the fallback so the non-SIMD path stays exercisable on SIMD hosts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// 256-bit AVX2 path (x86_64, runtime-detected).
    Avx2,
    /// 128-bit NEON path (aarch64, runtime-detected).
    Neon,
    /// Portable fallback: delegates to [`xnor_popcount_z_tile`].
    Portable,
}

impl SimdLevel {
    /// Short human-readable name (metrics/tables/logs).
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
            SimdLevel::Portable => "portable",
        }
    }

    /// Every level, most-vectorized first.  Conformance suites iterate
    /// this so each path is pinned bit-identical on whatever host runs
    /// them: a level the host cannot execute degrades safely to
    /// [`SimdLevel::Portable`] inside [`xnor_popcount_z_simd_at`].
    pub const ALL: [SimdLevel; 3] = [SimdLevel::Avx2, SimdLevel::Neon, SimdLevel::Portable];
}

/// `BNN_FORCE_SCALAR=1` (any value other than empty or `0`) pins the SIMD
/// tier to the portable fallback.  Read once per process — the CI matrix
/// leg sets it for the whole test binary.
fn force_portable() -> bool {
    static FORCE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FORCE.get_or_init(|| {
        std::env::var("BNN_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// The vector level [`xnor_popcount_z_simd`] dispatches to on this host:
/// runtime feature detection gated by `BNN_FORCE_SCALAR` (see
/// [`SimdLevel`]).
pub fn simd_level() -> SimdLevel {
    if force_portable() {
        return SimdLevel::Portable;
    }
    detected_level()
}

fn detected_level() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdLevel::Neon;
        }
    }
    SimdLevel::Portable
}

/// Explicitly vectorized XNOR-popcount tile kernel — the `Kernel::Simd`
/// tier.  Same contract and layout as [`xnor_popcount_z_tile`] (row-major
/// `imgs`/`rows`, strided `out`, padding bits zero in every operand), but
/// the inner popcount runs on 256-bit AVX2 or 128-bit NEON vectors when
/// the host supports them ([`simd_level`]), falling back to the tiled
/// kernel otherwise.  Bit-identical to [`xnor_popcount_z`] on every path —
/// all of them compute `z = n − 2·popcount(x ⊕ w)` exactly over the same
/// words (pinned by the golden-vector and differential conformance suites
/// in `rust/tests/kernel_conformance.rs`).
///
/// ```
/// use bnn_fpga::bnn::packing::{pack_bits_u64, words_u64, xnor_popcount_z_simd};
/// let imgs = [pack_bits_u64(&[1, 0, 1]), pack_bits_u64(&[0, 0, 0])].concat();
/// let rows = [pack_bits_u64(&[1, 1, 1]), pack_bits_u64(&[0, 0, 0])].concat();
/// let mut z = [0i32; 4];
/// xnor_popcount_z_simd(&imgs, 2, &rows, words_u64(3), 3, &mut z, 2);
/// assert_eq!(z, [1, -1, -3, 3]); // identical to the tiled/scalar kernels
/// ```
#[allow(clippy::too_many_arguments)]
pub fn xnor_popcount_z_simd(
    imgs: &[u64],
    n_imgs: usize,
    rows: &[u64],
    words_per_row: usize,
    n_bits: usize,
    out: &mut [i32],
    out_stride: usize,
) {
    xnor_popcount_z_simd_at(
        simd_level(),
        imgs,
        n_imgs,
        rows,
        words_per_row,
        n_bits,
        out,
        out_stride,
    )
}

/// [`xnor_popcount_z_simd`] pinned to an explicit [`SimdLevel`] — the
/// conformance suites exercise every level deterministically regardless of
/// environment.  A level this host cannot execute (wrong architecture or
/// missing CPU feature) degrades to the portable fallback, so the function
/// is safe to call with any level anywhere.
#[allow(clippy::too_many_arguments)]
pub fn xnor_popcount_z_simd_at(
    level: SimdLevel,
    imgs: &[u64],
    n_imgs: usize,
    rows: &[u64],
    words_per_row: usize,
    n_bits: usize,
    out: &mut [i32],
    out_stride: usize,
) {
    debug_assert!(words_per_row >= 1);
    debug_assert_eq!(imgs.len(), n_imgs * words_per_row);
    debug_assert_eq!(rows.len() % words_per_row, 0);
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 if std::arch::is_x86_feature_detected!("avx2") => unsafe {
            avx2::tile(imgs, n_imgs, rows, words_per_row, n_bits, out, out_stride)
        },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon if std::arch::is_aarch64_feature_detected!("neon") => unsafe {
            neon::tile(imgs, n_imgs, rows, words_per_row, n_bits, out, out_stride)
        },
        _ => xnor_popcount_z_tile(imgs, n_imgs, rows, words_per_row, n_bits, out, out_stride),
    }
}

// ---------------------------------------------------------------------------
// Fused threshold-pack kernel tier (popcount → compare → bit-pack in registers)

/// Rows per fused weight panel: one panel's thresholded activations fill
/// exactly one packed `u64` word, so the fused kernel
/// ([`xnor_threshold_pack`]) emits a hidden layer's output **one word per
/// (image, panel)** with the integer pre-activations never touching memory.
pub const PANEL_ROWS: usize = 64;

/// Fused popcount → threshold-compare → activation-pack panel kernel — the
/// software mirror of the paper's Verilog datapath, where the popcount
/// tree, the threshold comparator and the next layer's activation register
/// are one combinational path (§3.3–3.4; the BatchNorm-as-threshold fusion
/// FINN identifies as the key to BNN throughput).  Where the tiled/simd
/// tiers materialize a `tile_imgs × block_rows` `i32` tile and re-pack it
/// in a second pass, this kernel keeps every sum in a register and returns
/// the packed activation word directly.
///
/// Layout contract (the [`super::model::PreparedPanelLayer`] layout): the
/// panel holds `thr.len() ≤ 64` weight rows **quad-interleaved** — word
/// `k` of row `4q + lane` lives at `panel[(q * words_per_row + k) * 4 +
/// lane]` — so the walk streams the panel strictly linearly (and the AVX2
/// path turns each quad step into a single 256-bit load).  Rows padding
/// the last quad (when `thr.len() % 4 != 0`) must be present (zeroed);
/// their sums are computed and discarded, never packed.
///
/// Bit `j` of the returned word is `z_j ≥ thr[j]` with
/// `z_j = n − 2·popcount(x ⊕ w_j)`; bits `≥ thr.len()` are 0 — exactly
/// the padding contract every other kernel in this module relies on.
///
/// ```
/// use bnn_fpga::bnn::packing::{pack_bits_u64, words_u64, xnor_threshold_pack};
/// let x = pack_bits_u64(&[1, 0, 1]);
/// // one 2-row panel (quad-padded): rows [1,1,1] (z=1) and [0,0,0] (z=-1)
/// let (r0, r1) = (pack_bits_u64(&[1, 1, 1]), pack_bits_u64(&[0, 0, 0]));
/// let panel = vec![r0[0], r1[0], 0, 0]; // word 0 of rows 0..4, interleaved
/// let word = xnor_threshold_pack(&x, &panel, words_u64(3), 3, &[0, 0]);
/// assert_eq!(word, 0b01); // z0=1 ≥ 0 fires, z1=−1 < 0 does not
/// ```
pub fn xnor_threshold_pack(
    x: &[u64],
    panel: &[u64],
    words_per_row: usize,
    n_bits: usize,
    thr: &[i32],
) -> u64 {
    let n_rows = thr.len();
    debug_assert!(n_rows <= PANEL_ROWS);
    debug_assert!(words_per_row >= 1);
    debug_assert_eq!(x.len(), words_per_row);
    let n_quads = n_rows.div_ceil(4);
    debug_assert_eq!(panel.len(), n_quads * 4 * words_per_row);
    let n = n_bits as i32;
    let mut word = 0u64;
    for q in 0..n_quads {
        let quad = &panel[q * 4 * words_per_row..(q + 1) * 4 * words_per_row];
        let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
        for (k, xw) in x.iter().enumerate() {
            c0 += (xw ^ quad[4 * k]).count_ones();
            c1 += (xw ^ quad[4 * k + 1]).count_ones();
            c2 += (xw ^ quad[4 * k + 2]).count_ones();
            c3 += (xw ^ quad[4 * k + 3]).count_ones();
        }
        for (lane, c) in [c0, c1, c2, c3].into_iter().enumerate() {
            let j = 4 * q + lane;
            if j < n_rows {
                word |= u64::from(n - 2 * c as i32 >= thr[j]) << j;
            }
        }
    }
    word
}

/// [`xnor_threshold_pack`] behind the same once-per-process runtime
/// dispatch as the SIMD tile tier ([`simd_level`]): AVX2 on x86_64, NEON
/// on aarch64, the portable fused kernel elsewhere or under
/// `BNN_FORCE_SCALAR=1`.  Bit-identical on every path — the level only
/// changes how the popcounts are computed (pinned by the golden-vector and
/// differential suites through `Kernel::Fused`).
pub fn xnor_threshold_pack_simd(
    x: &[u64],
    panel: &[u64],
    words_per_row: usize,
    n_bits: usize,
    thr: &[i32],
) -> u64 {
    xnor_threshold_pack_simd_at(simd_level(), x, panel, words_per_row, n_bits, thr)
}

/// [`xnor_threshold_pack_simd`] pinned to an explicit [`SimdLevel`] (the
/// conformance suites force every path deterministically).  A level this
/// host cannot execute degrades to the portable fused kernel, so the
/// function is safe to call with any level anywhere.
pub fn xnor_threshold_pack_simd_at(
    level: SimdLevel,
    x: &[u64],
    panel: &[u64],
    words_per_row: usize,
    n_bits: usize,
    thr: &[i32],
) -> u64 {
    debug_assert!(thr.len() <= PANEL_ROWS);
    debug_assert_eq!(panel.len(), thr.len().div_ceil(4) * 4 * words_per_row);
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 if std::arch::is_x86_feature_detected!("avx2") => unsafe {
            avx2::threshold_pack(x, panel, words_per_row, n_bits, thr)
        },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon if std::arch::is_aarch64_feature_detected!("neon") => unsafe {
            neon::threshold_pack(x, panel, words_per_row, n_bits, thr)
        },
        _ => xnor_threshold_pack(x, panel, words_per_row, n_bits, thr),
    }
}

/// AVX2 path: 4 u64 words per 256-bit XOR, popcount via the nibble-LUT
/// (`vpshufb`) + byte-sum (`vpsadbw`) sequence (Muła et al., "Faster
/// Population Counts Using AVX2 Instructions" — the same shape FINN-style
/// wide PE lanes compute in hardware).  Two weight rows share every loaded
/// image vector, halving image-side loads relative to a row-at-a-time
/// walk.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Per-(image, row-pair) tile walk, same contract as
    /// [`super::xnor_popcount_z_tile`].
    ///
    /// # Safety
    /// Caller must ensure the `avx2` target feature is available.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn tile(
        imgs: &[u64],
        n_imgs: usize,
        rows: &[u64],
        words_per_row: usize,
        n_bits: usize,
        out: &mut [i32],
        out_stride: usize,
    ) {
        let n_rows = rows.len() / words_per_row;
        if n_rows == 0 || n_imgs == 0 {
            return;
        }
        debug_assert!(out_stride >= n_rows);
        debug_assert!(out.len() >= (n_imgs - 1) * out_stride + n_rows);
        let n = n_bits as i32;
        let mut r = 0;
        while r + 2 <= n_rows {
            let w0 = &rows[r * words_per_row..(r + 1) * words_per_row];
            let w1 = &rows[(r + 1) * words_per_row..(r + 2) * words_per_row];
            for i in 0..n_imgs {
                let x = &imgs[i * words_per_row..(i + 1) * words_per_row];
                let (c0, c1) = xor_popcount_2(x, w0, w1);
                let o = i * out_stride + r;
                out[o] = n - 2 * c0 as i32;
                out[o + 1] = n - 2 * c1 as i32;
            }
            r += 2;
        }
        if r < n_rows {
            let w = &rows[r * words_per_row..(r + 1) * words_per_row];
            for i in 0..n_imgs {
                let x = &imgs[i * words_per_row..(i + 1) * words_per_row];
                out[i * out_stride + r] = n - 2 * xor_popcount_1(x, w) as i32;
            }
        }
    }

    /// `popcount(i & 0xF)` per byte position, duplicated across both lanes
    /// for `vpshufb`.
    ///
    /// # Safety
    /// Requires `avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn nibble_lut() -> __m256i {
        _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, // lane 0
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, // lane 1
        )
    }

    /// Per-64-bit-lane sums of the byte popcounts of `v`.
    ///
    /// # Safety
    /// Requires `avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn popcount_lanes(v: __m256i, lut: __m256i, mask: __m256i, zero: __m256i) -> __m256i {
        let lo = _mm256_and_si256(v, mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), mask);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, zero)
    }

    /// Sum of the four u64 lanes of an accumulator.
    ///
    /// # Safety
    /// Requires `avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256i) -> u32 {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
        (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32
    }

    /// `(popcount(x ⊕ w0), popcount(x ⊕ w1))` in one pass: each 256-bit
    /// image load feeds two XOR-popcount chains.  Remainder words (< 4)
    /// use the scalar `popcnt`.
    ///
    /// # Safety
    /// Requires `avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn xor_popcount_2(x: &[u64], w0: &[u64], w1: &[u64]) -> (u32, u32) {
        debug_assert_eq!(x.len(), w0.len());
        debug_assert_eq!(x.len(), w1.len());
        let lut = nibble_lut();
        let mask = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let mut a0 = zero;
        let mut a1 = zero;
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            let xv = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
            let v0 =
                _mm256_xor_si256(xv, _mm256_loadu_si256(w0.as_ptr().add(i) as *const __m256i));
            let v1 =
                _mm256_xor_si256(xv, _mm256_loadu_si256(w1.as_ptr().add(i) as *const __m256i));
            a0 = _mm256_add_epi64(a0, popcount_lanes(v0, lut, mask, zero));
            a1 = _mm256_add_epi64(a1, popcount_lanes(v1, lut, mask, zero));
            i += 4;
        }
        let mut c0 = hsum(a0);
        let mut c1 = hsum(a1);
        while i < n {
            c0 += (x[i] ^ w0[i]).count_ones();
            c1 += (x[i] ^ w1[i]).count_ones();
            i += 1;
        }
        (c0, c1)
    }

    /// `popcount(x ⊕ w)` for the odd trailing row.
    ///
    /// # Safety
    /// Requires `avx2`.
    #[target_feature(enable = "avx2")]
    unsafe fn xor_popcount_1(x: &[u64], w: &[u64]) -> u32 {
        debug_assert_eq!(x.len(), w.len());
        let lut = nibble_lut();
        let mask = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let mut acc = zero;
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            let xv = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
            let v = _mm256_xor_si256(xv, _mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i));
            acc = _mm256_add_epi64(acc, popcount_lanes(v, lut, mask, zero));
            i += 4;
        }
        let mut c = hsum(acc);
        while i < n {
            c += (x[i] ^ w[i]).count_ones();
            i += 1;
        }
        c
    }

    /// Fused threshold-pack over one quad-interleaved panel (same contract
    /// as [`super::xnor_threshold_pack`]): each 256-bit load brings word
    /// `k` of all four rows of a quad, XORs it against the broadcast image
    /// word, and `vpsadbw` accumulates the four per-row popcounts in one
    /// vector accumulator — the panel streams strictly linearly with no
    /// per-row pointer hopping.
    ///
    /// # Safety
    /// Caller must ensure the `avx2` target feature is available.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn threshold_pack(
        x: &[u64],
        panel: &[u64],
        words_per_row: usize,
        n_bits: usize,
        thr: &[i32],
    ) -> u64 {
        let n_rows = thr.len();
        let n_quads = n_rows.div_ceil(4);
        debug_assert_eq!(x.len(), words_per_row);
        debug_assert_eq!(panel.len(), n_quads * 4 * words_per_row);
        let lut = nibble_lut();
        let mask = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let n = n_bits as i32;
        let mut word = 0u64;
        for q in 0..n_quads {
            let quad = &panel[q * 4 * words_per_row..(q + 1) * 4 * words_per_row];
            let mut acc = zero;
            for (k, &xw) in x.iter().enumerate() {
                let xv = _mm256_set1_epi64x(xw as i64);
                let wv = _mm256_loadu_si256(quad.as_ptr().add(4 * k) as *const __m256i);
                acc = _mm256_add_epi64(
                    acc,
                    popcount_lanes(_mm256_xor_si256(xv, wv), lut, mask, zero),
                );
            }
            let mut lanes = [0u64; 4];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            for (lane, &c) in lanes.iter().enumerate() {
                let j = 4 * q + lane;
                if j < n_rows {
                    word |= u64::from(n - 2 * c as i32 >= thr[j]) << j;
                }
            }
        }
        word
    }
}

/// NEON path: 2 u64 words per 128-bit XOR, hardware byte popcount
/// (`vcntq_u8`) + horizontal add (`vaddvq_u8` — 16 bytes × 8 bits ≤ 255,
/// no overflow).  Same row-pair image-load sharing as the AVX2 path.
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Per-(image, row-pair) tile walk, same contract as
    /// [`super::xnor_popcount_z_tile`].
    ///
    /// # Safety
    /// Caller must ensure the `neon` target feature is available.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn tile(
        imgs: &[u64],
        n_imgs: usize,
        rows: &[u64],
        words_per_row: usize,
        n_bits: usize,
        out: &mut [i32],
        out_stride: usize,
    ) {
        let n_rows = rows.len() / words_per_row;
        if n_rows == 0 || n_imgs == 0 {
            return;
        }
        debug_assert!(out_stride >= n_rows);
        debug_assert!(out.len() >= (n_imgs - 1) * out_stride + n_rows);
        let n = n_bits as i32;
        let mut r = 0;
        while r + 2 <= n_rows {
            let w0 = &rows[r * words_per_row..(r + 1) * words_per_row];
            let w1 = &rows[(r + 1) * words_per_row..(r + 2) * words_per_row];
            for i in 0..n_imgs {
                let x = &imgs[i * words_per_row..(i + 1) * words_per_row];
                let (c0, c1) = xor_popcount_2(x, w0, w1);
                let o = i * out_stride + r;
                out[o] = n - 2 * c0 as i32;
                out[o + 1] = n - 2 * c1 as i32;
            }
            r += 2;
        }
        if r < n_rows {
            let w = &rows[r * words_per_row..(r + 1) * words_per_row];
            for i in 0..n_imgs {
                let x = &imgs[i * words_per_row..(i + 1) * words_per_row];
                out[i * out_stride + r] = n - 2 * xor_popcount_1(x, w) as i32;
            }
        }
    }

    /// `(popcount(x ⊕ w0), popcount(x ⊕ w1))`, sharing each image load.
    ///
    /// # Safety
    /// Requires `neon`.
    #[target_feature(enable = "neon")]
    unsafe fn xor_popcount_2(x: &[u64], w0: &[u64], w1: &[u64]) -> (u32, u32) {
        debug_assert_eq!(x.len(), w0.len());
        debug_assert_eq!(x.len(), w1.len());
        let n = x.len();
        let mut c0 = 0u32;
        let mut c1 = 0u32;
        let mut i = 0;
        while i + 2 <= n {
            let xv = vld1q_u64(x.as_ptr().add(i));
            let v0 = veorq_u64(xv, vld1q_u64(w0.as_ptr().add(i)));
            let v1 = veorq_u64(xv, vld1q_u64(w1.as_ptr().add(i)));
            c0 += vaddvq_u8(vcntq_u8(vreinterpretq_u8_u64(v0))) as u32;
            c1 += vaddvq_u8(vcntq_u8(vreinterpretq_u8_u64(v1))) as u32;
            i += 2;
        }
        while i < n {
            c0 += (x[i] ^ w0[i]).count_ones();
            c1 += (x[i] ^ w1[i]).count_ones();
            i += 1;
        }
        (c0, c1)
    }

    /// `popcount(x ⊕ w)` for the odd trailing row.
    ///
    /// # Safety
    /// Requires `neon`.
    #[target_feature(enable = "neon")]
    unsafe fn xor_popcount_1(x: &[u64], w: &[u64]) -> u32 {
        debug_assert_eq!(x.len(), w.len());
        let n = x.len();
        let mut c = 0u32;
        let mut i = 0;
        while i + 2 <= n {
            let xv = vld1q_u64(x.as_ptr().add(i));
            let v = veorq_u64(xv, vld1q_u64(w.as_ptr().add(i)));
            c += vaddvq_u8(vcntq_u8(vreinterpretq_u8_u64(v))) as u32;
            i += 2;
        }
        while i < n {
            c += (x[i] ^ w[i]).count_ones();
            i += 1;
        }
        c
    }

    /// Fused threshold-pack over one quad-interleaved panel (same contract
    /// as [`super::xnor_threshold_pack`]): two 128-bit loads per quad step
    /// (rows 0–1 and 2–3 of word `k`), XORed against the broadcast image
    /// word, with per-64-bit-lane popcounts accumulated through the
    /// `vcnt` + pairwise-widening-add chain.
    ///
    /// # Safety
    /// Caller must ensure the `neon` target feature is available.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn threshold_pack(
        x: &[u64],
        panel: &[u64],
        words_per_row: usize,
        n_bits: usize,
        thr: &[i32],
    ) -> u64 {
        let n_rows = thr.len();
        let n_quads = n_rows.div_ceil(4);
        debug_assert_eq!(x.len(), words_per_row);
        debug_assert_eq!(panel.len(), n_quads * 4 * words_per_row);
        let n = n_bits as i32;
        let mut word = 0u64;
        for q in 0..n_quads {
            let quad = &panel[q * 4 * words_per_row..(q + 1) * 4 * words_per_row];
            let mut acc01 = vdupq_n_u64(0);
            let mut acc23 = vdupq_n_u64(0);
            for (k, &xw) in x.iter().enumerate() {
                let xv = vdupq_n_u64(xw);
                let v01 = veorq_u64(xv, vld1q_u64(quad.as_ptr().add(4 * k)));
                let v23 = veorq_u64(xv, vld1q_u64(quad.as_ptr().add(4 * k + 2)));
                acc01 = vaddq_u64(
                    acc01,
                    vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(v01))))),
                );
                acc23 = vaddq_u64(
                    acc23,
                    vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(v23))))),
                );
            }
            let counts = [
                vgetq_lane_u64(acc01, 0),
                vgetq_lane_u64(acc01, 1),
                vgetq_lane_u64(acc23, 0),
                vgetq_lane_u64(acc23, 1),
            ];
            for (lane, &c) in counts.iter().enumerate() {
                let j = 4 * q + lane;
                if j < n_rows {
                    word |= u64::from(n - 2 * c as i32 >= thr[j]) << j;
                }
            }
        }
        word
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest_lite::{gens, Runner};

    #[test]
    fn pack_known_patterns() {
        assert_eq!(pack_bits_u64(&[1]), vec![1]);
        let mut bits = vec![0u8; 65];
        bits[64] = 1;
        assert_eq!(pack_bits_u64(&bits), vec![0, 1]);
        assert_eq!(pack_bits_u32(&[0, 1]), vec![2]);
    }

    #[test]
    fn roundtrip_property() {
        Runner::new("u64-pack-roundtrip").run(&gens::BitVec(1..=800), |bits| {
            unpack_bits_u64(&pack_bits_u64(bits), bits.len()) == *bits
        });
    }

    #[test]
    fn u32_u64_conversion_property() {
        Runner::new("u32<->u64-words").run(&gens::BitVec(1..=800), |bits| {
            let w32 = pack_bits_u32(bits);
            let w64 = pack_bits_u64(bits);
            u32_words_to_u64(&w32, bits.len()) == w64
                && u64_words_to_u32(&w64, bits.len()) == w32
        });
    }

    #[test]
    fn dot_identity_vs_naive() {
        // z = Σ ±1·±1 must equal n − 2·popcount(xor) for random vectors.
        let mut rng = Xoshiro256::new(99);
        for _ in 0..50 {
            let n = 1 + rng.below(790) as usize;
            let a_bits: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
            let b_bits: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
            let naive: i32 = a_bits
                .iter()
                .zip(&b_bits)
                .map(|(&a, &b)| if a == b { 1 } else { -1 })
                .sum();
            let a = Packed::from_bits(&a_bits);
            let b = Packed::from_bits(&b_bits);
            assert_eq!(a.dot(&b), naive);
            // parity + bound invariants
            assert_eq!((a.dot(&b) - n as i32) % 2, 0);
            assert!(a.dot(&b).abs() <= n as i32);
        }
    }

    #[test]
    fn dot_extremes() {
        let ones = Packed::from_bits(&vec![1u8; 784]);
        let zeros = Packed::from_bits(&vec![0u8; 784]);
        assert_eq!(ones.dot(&ones), 784);
        assert_eq!(ones.dot(&zeros), -784);
        assert_eq!(zeros.dot(&zeros), 784);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dot_length_checked() {
        let a = Packed::from_bits(&[1, 0]);
        let b = Packed::from_bits(&[1]);
        let _ = a.dot(&b);
    }

    #[test]
    fn padding_bits_never_count() {
        // 65 bits: padding in word 1 must not affect the dot product.
        let a = Packed::from_bits(&vec![1u8; 65]);
        let b = Packed::from_bits(&vec![0u8; 65]);
        assert_eq!(a.dot(&b), -65);
    }

    /// The widths the stack actually meets (layer widths 784/128/64/10) plus
    /// the word-boundary edge cases (1, 63, 65) for both physical widths.
    const EDGE_WIDTHS: [usize; 5] = [784, 10, 1, 63, 65];

    fn random_bits(rng: &mut Xoshiro256, n: usize) -> Vec<u8> {
        (0..n).map(|_| rng.bool() as u8).collect()
    }

    #[test]
    fn roundtrip_u32_u64_at_edge_widths() {
        let mut rng = Xoshiro256::new(2026);
        for &n in &EDGE_WIDTHS {
            for _ in 0..10 {
                let bits = random_bits(&mut rng, n);
                let w32 = pack_bits_u32(&bits);
                let w64 = pack_bits_u64(&bits);
                assert_eq!(w32.len(), words_u32(n));
                assert_eq!(w64.len(), words_u64(n));
                // bits → u32 → u64 → u32 → bits is the identity at every width
                assert_eq!(u32_words_to_u64(&w32, n), w64, "width {n}");
                assert_eq!(u64_words_to_u32(&w64, n), w32, "width {n}");
                assert_eq!(unpack_bits_u64(&w64, n), bits, "width {n}");
                let back = Packed::from_u32_words(&w32, n);
                assert_eq!(back.to_bits(), bits, "width {n}");
                assert_eq!(back.to_u32_words(), w32, "width {n}");
            }
        }
    }

    #[test]
    fn padding_bits_are_zero_at_edge_widths() {
        // The invariant the blocked kernel leans on: every packer leaves
        // bits ≥ n zero, in both word widths.
        let mut rng = Xoshiro256::new(2027);
        for &n in &EDGE_WIDTHS {
            let bits = vec![1u8; n]; // worst case: all ones up to the boundary
            let w64 = pack_bits_u64(&bits);
            let w32 = pack_bits_u32(&bits);
            let pad64 = words_u64(n) * 64 - n;
            let pad32 = words_u32(n) * 32 - n;
            if pad64 > 0 {
                assert_eq!(w64.last().unwrap() >> (64 - pad64), 0, "u64 padding, width {n}");
            }
            if pad32 > 0 {
                assert_eq!(w32.last().unwrap() >> (32 - pad32), 0, "u32 padding, width {n}");
            }
            // and the u32→u64 conversion cannot invent padding bits either
            let conv = u32_words_to_u64(&w32, n);
            if pad64 > 0 {
                assert_eq!(conv.last().unwrap() >> (64 - pad64), 0, "converted padding, width {n}");
            }
            // total popcount is preserved exactly (no bit lost, none invented)
            let pop: u32 = w64.iter().map(|w| w.count_ones()).sum();
            assert_eq!(pop as usize, n);
            let _ = random_bits(&mut rng, n); // keep the stream moving per width
        }
    }

    #[test]
    fn blocked_equals_scalar_at_edge_widths() {
        // The blocked kernel must be bit-identical to the scalar path for
        // every row count around its 4-row register tile (0..=9 rows) and
        // every edge width, including the sub-word and straddling ones.
        let mut rng = Xoshiro256::new(2028);
        for &n in &EDGE_WIDTHS {
            let wpr = words_u64(n);
            for n_rows in 0..=9usize {
                let x = pack_bits_u64(&random_bits(&mut rng, n));
                let mut rows = Vec::with_capacity(n_rows * wpr);
                for _ in 0..n_rows {
                    rows.extend(pack_bits_u64(&random_bits(&mut rng, n)));
                }
                let mut blocked = vec![0i32; n_rows];
                xnor_popcount_z_block(&x, &rows, wpr, n, &mut blocked);
                let scalar: Vec<i32> = (0..n_rows)
                    .map(|r| xnor_popcount_z(&x, &rows[r * wpr..(r + 1) * wpr], n))
                    .collect();
                assert_eq!(blocked, scalar, "width {n}, {n_rows} rows");
            }
        }
    }

    #[test]
    fn tile_equals_scalar_at_edge_widths() {
        // The tile kernel must be bit-identical to the scalar path for
        // every (image count, row count) around its 2-image × 4-row
        // register tile, at every edge width.
        let mut rng = Xoshiro256::new(2029);
        for &n in &EDGE_WIDTHS {
            let wpr = words_u64(n);
            for n_imgs in 0..=5usize {
                for n_rows in 0..=9usize {
                    let mut imgs = Vec::with_capacity(n_imgs * wpr);
                    for _ in 0..n_imgs {
                        imgs.extend(pack_bits_u64(&random_bits(&mut rng, n)));
                    }
                    let mut rows = Vec::with_capacity(n_rows * wpr);
                    for _ in 0..n_rows {
                        rows.extend(pack_bits_u64(&random_bits(&mut rng, n)));
                    }
                    let mut tiled = vec![0i32; n_imgs * n_rows.max(1)];
                    xnor_popcount_z_tile(&imgs, n_imgs, &rows, wpr, n, &mut tiled, n_rows.max(1));
                    for i in 0..n_imgs {
                        for r in 0..n_rows {
                            let want = xnor_popcount_z(
                                &imgs[i * wpr..(i + 1) * wpr],
                                &rows[r * wpr..(r + 1) * wpr],
                                n,
                            );
                            assert_eq!(
                                tiled[i * n_rows.max(1) + r],
                                want,
                                "width {n}, {n_imgs} imgs, {n_rows} rows, ({i},{r})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn tile_respects_wide_out_stride() {
        // out_stride > n_rows writes a row block into a wider logits
        // buffer without touching the columns beyond the block.
        let mut rng = Xoshiro256::new(2030);
        let n = 65;
        let wpr = words_u64(n);
        let (n_imgs, n_rows, stride) = (3usize, 5usize, 9usize);
        let mut imgs = Vec::new();
        for _ in 0..n_imgs {
            imgs.extend(pack_bits_u64(&random_bits(&mut rng, n)));
        }
        let mut rows = Vec::new();
        for _ in 0..n_rows {
            rows.extend(pack_bits_u64(&random_bits(&mut rng, n)));
        }
        let mut out = vec![i32::MIN; n_imgs * stride];
        xnor_popcount_z_tile(&imgs, n_imgs, &rows, wpr, n, &mut out, stride);
        for i in 0..n_imgs {
            for c in 0..stride {
                let got = out[i * stride + c];
                if c < n_rows {
                    let want = xnor_popcount_z(
                        &imgs[i * wpr..(i + 1) * wpr],
                        &rows[c * wpr..(c + 1) * wpr],
                        n,
                    );
                    assert_eq!(got, want, "img {i} row {c}");
                } else {
                    assert_eq!(got, i32::MIN, "img {i} col {c} clobbered");
                }
            }
        }
    }

    #[test]
    fn tile_kernel_matches_naive_property() {
        // Property: for random widths, image counts and row counts, the
        // tile kernel equals the ±1 definition (so padding never leaks).
        Runner::new("tile-vs-naive").cases(32).run(
            &gens::Pair(gens::BitVec(1..=200), gens::Pair(gens::U64(1..=5), gens::U64(1..=10))),
            |(bits, (n_imgs, n_rows))| {
                let n = bits.len();
                let wpr = words_u64(n);
                let (n_imgs, n_rows) = (*n_imgs as usize, *n_rows as usize);
                let mut rng = Xoshiro256::new(n as u64 * 37 + n_imgs as u64 * 7 + n_rows as u64);
                let mut img_bits = vec![bits.clone()];
                for _ in 1..n_imgs {
                    img_bits.push((0..n).map(|_| rng.bool() as u8).collect());
                }
                let mut row_bits = Vec::new();
                for _ in 0..n_rows {
                    row_bits.push((0..n).map(|_| rng.bool() as u8).collect::<Vec<u8>>());
                }
                let imgs: Vec<u64> = img_bits.iter().flat_map(|b| pack_bits_u64(b)).collect();
                let rows: Vec<u64> = row_bits.iter().flat_map(|b| pack_bits_u64(b)).collect();
                let mut tiled = vec![0i32; n_imgs * n_rows];
                xnor_popcount_z_tile(&imgs, n_imgs, &rows, wpr, n, &mut tiled, n_rows);
                img_bits.iter().enumerate().all(|(i, xb)| {
                    row_bits.iter().enumerate().all(|(r, wb)| {
                        let naive: i32 = xb
                            .iter()
                            .zip(wb)
                            .map(|(&a, &b)| if a == b { 1i32 } else { -1 })
                            .sum();
                        tiled[i * n_rows + r] == naive
                    })
                })
            },
        );
    }

    #[test]
    fn simd_equals_scalar_at_edge_widths_for_every_level() {
        // Every SIMD level — including levels this host degrades to the
        // portable fallback — must be bit-identical to the scalar path
        // around the row-pair tile, at word-straddling widths.
        let mut rng = Xoshiro256::new(2031);
        for level in SimdLevel::ALL {
            for &n in &[784usize, 10, 1, 37, 63, 64, 65, 128, 129] {
                let wpr = words_u64(n);
                for n_imgs in 0..=4usize {
                    for n_rows in 0..=5usize {
                        let mut imgs = Vec::with_capacity(n_imgs * wpr);
                        for _ in 0..n_imgs {
                            imgs.extend(pack_bits_u64(&random_bits(&mut rng, n)));
                        }
                        let mut rows = Vec::with_capacity(n_rows * wpr);
                        for _ in 0..n_rows {
                            rows.extend(pack_bits_u64(&random_bits(&mut rng, n)));
                        }
                        let stride = n_rows.max(1);
                        let mut got = vec![0i32; n_imgs * stride];
                        xnor_popcount_z_simd_at(
                            level, &imgs, n_imgs, &rows, wpr, n, &mut got, stride,
                        );
                        for i in 0..n_imgs {
                            for r in 0..n_rows {
                                let want = xnor_popcount_z(
                                    &imgs[i * wpr..(i + 1) * wpr],
                                    &rows[r * wpr..(r + 1) * wpr],
                                    n,
                                );
                                assert_eq!(
                                    got[i * stride + r],
                                    want,
                                    "{level:?} width {n}, {n_imgs} imgs, {n_rows} rows, ({i},{r})"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn simd_respects_wide_out_stride() {
        // out_stride > n_rows writes a row block into a wider logits
        // buffer without touching the columns beyond the block — for
        // every level, including the vectorized ones.
        let mut rng = Xoshiro256::new(2032);
        let n = 129; // two full words + one straddling bit
        let wpr = words_u64(n);
        let (n_imgs, n_rows, stride) = (3usize, 5usize, 9usize);
        let mut imgs = Vec::new();
        for _ in 0..n_imgs {
            imgs.extend(pack_bits_u64(&random_bits(&mut rng, n)));
        }
        let mut rows = Vec::new();
        for _ in 0..n_rows {
            rows.extend(pack_bits_u64(&random_bits(&mut rng, n)));
        }
        for level in SimdLevel::ALL {
            let mut out = vec![i32::MIN; n_imgs * stride];
            xnor_popcount_z_simd_at(level, &imgs, n_imgs, &rows, wpr, n, &mut out, stride);
            for i in 0..n_imgs {
                for c in 0..stride {
                    let got = out[i * stride + c];
                    if c < n_rows {
                        let want = xnor_popcount_z(
                            &imgs[i * wpr..(i + 1) * wpr],
                            &rows[c * wpr..(c + 1) * wpr],
                            n,
                        );
                        assert_eq!(got, want, "{level:?} img {i} row {c}");
                    } else {
                        assert_eq!(got, i32::MIN, "{level:?} img {i} col {c} clobbered");
                    }
                }
            }
        }
    }

    #[test]
    fn simd_kernel_matches_naive_property() {
        // Property: at random widths/images/rows, the dispatched SIMD
        // kernel (whatever level this host resolves to) equals the ±1
        // definition — so neither padding nor the vector remainder loop
        // can leak.
        Runner::new("simd-vs-naive").cases(32).run(
            &gens::Pair(gens::BitVec(1..=300), gens::Pair(gens::U64(1..=5), gens::U64(1..=10))),
            |(bits, (n_imgs, n_rows))| {
                let n = bits.len();
                let wpr = words_u64(n);
                let (n_imgs, n_rows) = (*n_imgs as usize, *n_rows as usize);
                let mut rng = Xoshiro256::new(n as u64 * 41 + n_imgs as u64 * 11 + n_rows as u64);
                let mut img_bits = vec![bits.clone()];
                for _ in 1..n_imgs {
                    img_bits.push((0..n).map(|_| rng.bool() as u8).collect());
                }
                let mut row_bits = Vec::new();
                for _ in 0..n_rows {
                    row_bits.push((0..n).map(|_| rng.bool() as u8).collect::<Vec<u8>>());
                }
                let imgs: Vec<u64> = img_bits.iter().flat_map(|b| pack_bits_u64(b)).collect();
                let rows: Vec<u64> = row_bits.iter().flat_map(|b| pack_bits_u64(b)).collect();
                let mut got = vec![0i32; n_imgs * n_rows];
                xnor_popcount_z_simd(&imgs, n_imgs, &rows, wpr, n, &mut got, n_rows);
                img_bits.iter().enumerate().all(|(i, xb)| {
                    row_bits.iter().enumerate().all(|(r, wb)| {
                        let naive: i32 = xb
                            .iter()
                            .zip(wb)
                            .map(|(&a, &b)| if a == b { 1i32 } else { -1 })
                            .sum();
                        got[i * n_rows + r] == naive
                    })
                })
            },
        );
    }

    #[test]
    fn simd_level_is_stable_and_named() {
        // The per-process dispatch decision must be deterministic, and
        // every level must carry a distinct display name.
        assert_eq!(simd_level(), simd_level());
        let names: Vec<&str> = SimdLevel::ALL.iter().map(|l| l.name()).collect();
        assert_eq!(names.len(), 3);
        assert!(names.contains(&"portable"));
        for (i, a) in names.iter().enumerate() {
            for b in &names[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn blocked_kernel_ignores_padding_property() {
        // Property: for random widths and row counts, blocked == scalar ==
        // the ±1 definition, so padding can never leak into any row's sum.
        Runner::new("blocked-vs-naive").cases(48).run(
            &gens::Pair(gens::BitVec(1..=200), gens::U64(1..=12)),
            |(bits, n_rows)| {
                let n = bits.len();
                let wpr = words_u64(n);
                let n_rows = *n_rows as usize;
                let mut rng = Xoshiro256::new(n as u64 * 131 + n_rows as u64);
                let x = pack_bits_u64(bits);
                let mut rows = Vec::new();
                let mut naive = Vec::new();
                for _ in 0..n_rows {
                    let w: Vec<u8> = (0..n).map(|_| rng.bool() as u8).collect();
                    naive.push(
                        w.iter()
                            .zip(bits)
                            .map(|(&a, &b)| if a == b { 1i32 } else { -1 })
                            .sum::<i32>(),
                    );
                    rows.extend(pack_bits_u64(&w));
                }
                let mut blocked = vec![0i32; n_rows];
                xnor_popcount_z_block(&x, &rows, wpr, n, &mut blocked);
                blocked == naive
            },
        );
    }

    /// Quad-interleave `rows` into the fused panel layout (the test mirror
    /// of `model::PreparedPanelLayer`): word `k` of row `4q + lane` at
    /// `panel[(q * wpr + k) * 4 + lane]`, zero rows padding the last quad.
    fn interleave_panel(rows: &[Vec<u64>], wpr: usize) -> Vec<u64> {
        let n_quads = rows.len().div_ceil(4);
        let mut panel = vec![0u64; n_quads * 4 * wpr];
        for (j, row) in rows.iter().enumerate() {
            let (q, lane) = (j / 4, j % 4);
            for (k, &w) in row.iter().enumerate() {
                panel[(q * wpr + k) * 4 + lane] = w;
            }
        }
        panel
    }

    #[test]
    fn threshold_pack_equals_scalar_at_edge_widths_for_every_level() {
        // The fused kernel — on every SIMD level, including levels this
        // host degrades to the portable path — must pack exactly the bits
        // the scalar z ≥ thr comparison produces, at word-straddling
        // widths and every row count around the 4-row quad.
        let mut rng = Xoshiro256::new(2033);
        for level in SimdLevel::ALL {
            for &n in &[784usize, 10, 1, 37, 63, 64, 65, 128, 129] {
                let wpr = words_u64(n);
                for n_rows in [0usize, 1, 3, 4, 5, 8, 63, 64] {
                    let x = pack_bits_u64(&random_bits(&mut rng, n));
                    let rows: Vec<Vec<u64>> = (0..n_rows)
                        .map(|_| pack_bits_u64(&random_bits(&mut rng, n)))
                        .collect();
                    let thr: Vec<i32> = (0..n_rows)
                        .map(|_| rng.range_i64(-(n as i64), n as i64) as i32)
                        .collect();
                    let panel = interleave_panel(&rows, wpr);
                    let word = xnor_threshold_pack_simd_at(level, &x, &panel, wpr, n, &thr);
                    for (j, row) in rows.iter().enumerate() {
                        let z = xnor_popcount_z(&x, row, n);
                        assert_eq!(
                            (word >> j) & 1,
                            u64::from(z >= thr[j]),
                            "{level:?} width {n}, {n_rows} rows, row {j}"
                        );
                    }
                    // bits beyond the panel's rows stay zero — the padding
                    // contract the next layer's XOR relies on
                    if n_rows < 64 {
                        assert_eq!(word >> n_rows, 0, "{level:?} width {n}, {n_rows} rows");
                    }
                }
            }
        }
    }

    #[test]
    fn threshold_pack_matches_naive_property() {
        // Property: at random widths, row counts and thresholds, the fused
        // kernel's packed bits equal the ±1 definition thresholded — so
        // neither padding, the quad remainder, nor the compare can leak —
        // and the runtime-dispatched entry agrees with the portable one.
        Runner::new("threshold-pack-vs-naive").cases(32).run(
            &gens::Pair(gens::BitVec(1..=300), gens::U64(1..=64)),
            |(bits, n_rows)| {
                let n = bits.len();
                let wpr = words_u64(n);
                let n_rows = *n_rows as usize;
                let mut rng = Xoshiro256::new(n as u64 * 53 + n_rows as u64 * 17);
                let x = pack_bits_u64(bits);
                let row_bits: Vec<Vec<u8>> = (0..n_rows)
                    .map(|_| (0..n).map(|_| rng.bool() as u8).collect())
                    .collect();
                let rows: Vec<Vec<u64>> = row_bits.iter().map(|b| pack_bits_u64(b)).collect();
                let thr: Vec<i32> = (0..n_rows)
                    .map(|_| rng.range_i64(-(n as i64), n as i64) as i32)
                    .collect();
                let panel = interleave_panel(&rows, wpr);
                let word = xnor_threshold_pack(&x, &panel, wpr, n, &thr);
                let dispatched = xnor_threshold_pack_simd(&x, &panel, wpr, n, &thr);
                word == dispatched
                    && row_bits.iter().enumerate().all(|(j, wb)| {
                        let naive: i32 = wb
                            .iter()
                            .zip(bits)
                            .map(|(&a, &b)| if a == b { 1i32 } else { -1 })
                            .sum();
                        (word >> j) & 1 == u64::from(naive >= thr[j])
                    })
            },
        );
    }

    #[test]
    fn copy_bits_matches_bitwise_copy() {
        // the im2col gather primitive vs a per-bit reference, across
        // unaligned offsets, word-straddling runs and multi-word runs
        let mut rng = Xoshiro256::new(0xC0B1);
        for trial in 0..200 {
            let src_bits: Vec<u8> = (0..300).map(|_| rng.bool() as u8).collect();
            let src = pack_bits_u64(&src_bits);
            let len = 1 + (rng.next_u64() % 180) as usize;
            let src_off = (rng.next_u64() % (300 - len as u64 + 1)) as usize;
            let dst_off = (rng.next_u64() % 100) as usize;
            let dst_bits_len = dst_off + len;
            let mut dst = vec![0u64; words_u64(dst_bits_len)];
            copy_bits(&mut dst, dst_off, &src, src_off, len);
            let got = unpack_bits_u64(&dst, dst_bits_len);
            for i in 0..dst_bits_len {
                let want = if i >= dst_off { src_bits[src_off + i - dst_off] } else { 0 };
                assert_eq!(got[i], want, "trial {trial} bit {i}");
            }
        }
    }

    #[test]
    fn splice_and_read_round_trip() {
        let mut rng = Xoshiro256::new(0x5B11);
        for _ in 0..200 {
            let word = rng.next_u64();
            let len = 1 + (rng.next_u64() % 64) as usize;
            let off = (rng.next_u64() % 130) as usize;
            let mut dst = vec![0u64; words_u64(off + len)];
            splice_bits(&mut dst, off, word, len);
            let back = read_bits(&dst, off, len);
            let mask = if len == 64 { u64::MAX } else { (1u64 << len) - 1 };
            assert_eq!(back, word & mask, "off {off} len {len}");
            // bits outside [off, off+len) stay zero
            let total = dst.len() * 64;
            for (i, b) in unpack_bits_u64(&dst, total).iter().enumerate() {
                if !(off..off + len).contains(&i) {
                    assert_eq!(*b, 0, "stray bit {i}");
                }
            }
        }
    }
}
