//! Tiny argument parser (the `clap` substitute): positional subcommand +
//! `--flag` / `--key value` options, with typed accessors and an
//! auto-generated usage block.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable) — first non-flag token
    /// becomes the subcommand.
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    pub fn parse_env() -> Result<Args> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name}: expected number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        // NOTE greedy semantics: `--opt value` consumes the next token, so
        // bare flags must come last or use `--flag=`-style disambiguation.
        let a = parse("sweep extra --parallelism 64 --mem bram --quick");
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.opt("parallelism"), Some("64"));
        assert_eq!(a.opt("mem"), Some("bram"));
        assert!(a.flag("quick"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("infer --image=7 --backend=native");
        assert_eq!(a.opt("image"), Some("7"));
        assert_eq!(a.opt("backend"), Some("native"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("report --verbose");
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("verbose"), None);
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 5");
        assert_eq!(a.usize_or("n", 1).unwrap(), 5);
        assert_eq!(a.usize_or("m", 9).unwrap(), 9);
        let bad = parse("x --n five");
        assert!(bad.usize_or("n", 1).is_err());
        let f = parse("x --rate 2500.5");
        assert_eq!(f.f64_or("rate", 1.0).unwrap(), 2500.5);
        assert_eq!(f.f64_or("other", 7.0).unwrap(), 7.0);
        assert!(parse("x --rate fast").f64_or("rate", 1.0).is_err());
    }
}
