//! `bnn-fpga` command line — the leader entrypoint.
//!
//! Subcommands:
//! * `info`        — artifacts, model and platform summary
//! * `infer`       — classify test images via any backend
//! * `verify`      — the paper's §4.1 correctness run (100-image subset)
//! * `sweep`       — Table 1/2/3 rows for one or all configurations
//! * `report`      — full §3.6-style implementation report for one config
//! * `serve-demo`  — run the coordinator under synthetic load, print metrics
//! * `classify`    — one-shot: load a weights file, classify one image file
//!
//! Benches (`cargo bench`) regenerate the paper's tables/figures; examples
//! show the library API.  This binary is the operational tool.

pub mod args;

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::{BatcherConfig, Engine, NativeBackend, PjrtBackend, SimBackend};
use crate::data::Dataset;
use crate::estimate::{power, resources, timing};
use crate::sim::{analytic_steps, Accelerator, MemStyle, SimConfig};
use crate::util::table::{Align, Table};
use crate::{artifacts_dir, mem, BNN_DIMS};
use args::Args;

const USAGE: &str = "\
bnn-fpga — BNN FPGA accelerator reproduction (see README.md)

USAGE: bnn-fpga <subcommand> [options]

SUBCOMMANDS
  info                      artifact/model/platform summary
  infer      --backend native|pjrt|fpga-sim [--count N] [--parallelism P] [--mem bram|lut]
  verify     [--parallelism P] [--mem bram|lut]        §4.1 100-image check
  sweep      [--strict-clock]                          Table 1 sweep
  report     --parallelism P [--mem bram|lut]          §3.6-style report
  serve-demo [--backend ...] [--requests N] [--workers W]
             [--kernel scalar|blocked|tiled|simd|fused|pipelined]
             [--block-rows B] [--tile-imgs T] [--ring-cap R]
             [--max-batch B] [--queue-cap N] [--config FILE]
  serve      [--addr HOST:PORT] [--backend ...] [--workers W]
             [--kernel scalar|blocked|tiled|simd|fused|pipelined]
             [--block-rows B] [--tile-imgs T] [--ring-cap R]
             [--queue-cap N] [--config FILE]
             [--serve-async] [--max-conns N] [--idle-timeout-ms MS]
             a config with [models.NAME] sections serves a multi-model
             registry; wire-v2 clients route by model name
  classify   <weights.json> <image> [--index N] [--width W] [--height H]
             [--threshold T] [--invert] [--labels FILE]
             one-shot local inference; <image> is raw grayscale bytes
             (W×H, inferred from the model when square or 28×28) or an
             idx3 file (--index picks the image)
  loadgen    --addr HOST:PORT [--rate R] [--connections C]
             [--duration-ms MS] [--mix-v1 PCT] [--seed S] [--model NAME]
             [--chaos-rate PCT] [--chaos-seed S] [--workers W]
             open-loop load against a running serve instance; --model
             names a registry model in the v2 frames (implies v2-only
             unless --mix-v1 is given).  --chaos-rate/--chaos-seed run
             the self-contained chaos soak instead: an in-process async
             server over a fault-injecting engine (no --addr needed),
             reporting restarts and typed-error latency separately
  trace      [--image N] [--parallelism P] [--out trace.vcd]  VCD waveform

Set BNN_FPGA_ARTIFACTS to override the artifacts directory (default ./artifacts).
";

fn mem_style(args: &Args) -> Result<MemStyle> {
    match args.opt_or("mem", "bram").as_str() {
        "bram" => Ok(MemStyle::Bram),
        "lut" => Ok(MemStyle::Lut),
        other => bail!("--mem must be bram|lut, got '{other}'"),
    }
}

fn block_rows_arg(args: &Args, default: usize) -> Result<usize> {
    let b = args.usize_or("block-rows", default)?;
    if b < 1 {
        bail!("--block-rows must be ≥ 1");
    }
    Ok(b)
}

fn tile_imgs_arg(args: &Args, default: usize) -> Result<usize> {
    let t = args.usize_or("tile-imgs", default)?;
    if t < 1 {
        bail!("--tile-imgs must be ≥ 1");
    }
    Ok(t)
}

fn ring_cap_arg(args: &Args, default: usize) -> Result<usize> {
    let r = args.usize_or("ring-cap", default)?;
    if r < 1 {
        bail!("--ring-cap must be ≥ 1");
    }
    Ok(r)
}

/// `--kernel scalar|blocked|tiled|simd|fused|pipelined` overrides the
/// config file's typed kernel; without the flag the file kernel is kept
/// but re-shaped by the (possibly flag-overridden) `--block-rows` /
/// `--tile-imgs` / `--ring-cap`.  `simd` and `fused` runtime-dispatch to
/// AVX2/NEON and fall back to their portable kernels on hosts without
/// them; `fused` and `pipelined` additionally prepare the panel weight
/// layout once at engine build.
fn kernel_arg(
    args: &Args,
    file_kernel: crate::coordinator::Kernel,
    block_rows: usize,
    tile_imgs: usize,
    ring_cap: usize,
) -> Result<crate::coordinator::Kernel> {
    let kernel = match args.opt("kernel") {
        Some(name) => crate::coordinator::Kernel::parse(name, block_rows, tile_imgs)?,
        None => file_kernel.with_shape(block_rows, tile_imgs),
    };
    Ok(kernel.with_ring_cap(ring_cap))
}

/// `--queue-cap N` (default from `[coordinator] queue_cap`): the engine's
/// backpressure bound.
fn queue_cap_arg(args: &Args, default: usize) -> Result<usize> {
    let c = args.usize_or("queue-cap", default)?;
    if c < 1 {
        bail!("--queue-cap must be ≥ 1");
    }
    Ok(c)
}

/// `--config FILE` → [`crate::config::ServeConfig`]; defaults otherwise.
/// CLI flags override whatever the file says.
fn serve_config(args: &Args) -> Result<crate::config::ServeConfig> {
    match args.opt("config") {
        Some(p) => crate::config::ServeConfig::load(std::path::Path::new(p)),
        None => Ok(crate::config::ServeConfig::default()),
    }
}

/// Entry point used by `main.rs`; prints errors and sets the exit code.
pub fn run() {
    let code = match Args::parse_env().and_then(dispatch) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("infer") => cmd_infer(&args),
        Some("verify") => cmd_verify(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("report") => cmd_report(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        Some("serve") => cmd_serve(&args),
        Some("classify") => cmd_classify(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("trace") => cmd_trace(&args),
        Some(other) => bail!("unknown subcommand '{other}'\n\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn load_model() -> Result<crate::bnn::BnnModel> {
    mem::load_model(&artifacts_dir().join("weights.json"))
}

fn cmd_info() -> Result<()> {
    let dir = artifacts_dir();
    println!("artifacts dir : {}", dir.display());
    let model = load_model()?;
    let mut shape: Vec<String> = Vec::new();
    match model.input_geometry() {
        Some((c, h, w)) => shape.push(format!("{c}x{h}x{w}")),
        None => shape.push(model.n_in().to_string()),
    }
    for cl in &model.conv {
        shape.push(format!("conv{}@{1}x{1}", cl.out_ch(), cl.kernel));
    }
    shape.extend(model.layers.iter().map(|l| l.n_out.to_string()));
    println!(
        "model         : {} ({} layers, {} packed weight words)",
        shape.join("-"),
        model.n_layers(),
        model.conv.iter().map(|c| c.core.weights.len()).sum::<usize>()
            + model.layers.iter().map(|l| l.weights.len()).sum::<usize>()
    );
    match crate::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts     : {}", m.artifacts.len());
            println!("bnn ladder    : {:?}", m.batch_ladder("bnn"));
            println!("cnn ladder    : {:?}", m.batch_ladder("cnn"));
        }
        Err(e) => println!("artifacts     : unavailable ({e})"),
    }
    let ds = Dataset::load_mem_subset(&dir.join("mem"))?;
    println!("mem subset    : {} images", ds.len());
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let model = load_model()?;
    let dir = artifacts_dir();
    let ds = Dataset::load_mem_subset(&dir.join("mem"))?;
    let count = args.usize_or("count", 10)?.min(ds.len());
    let backend: Arc<dyn crate::coordinator::InferBackend> =
        match args.opt_or("backend", "native").as_str() {
            "native" => Arc::new(NativeBackend::new(model)),
            "pjrt" => {
                let engine = Arc::new(crate::runtime::Engine::load(&dir)?);
                Arc::new(PjrtBackend::new(engine)?)
            }
            "fpga-sim" => {
                let cfg = SimConfig::new(args.usize_or("parallelism", 64)?, mem_style(args)?);
                Arc::new(SimBackend::new(&model, cfg)?)
            }
            other => bail!("unknown backend '{other}'"),
        };
    // one arena pair for the whole loop: after the first image the
    // per-prediction path allocates nothing (InferBackend::predict_into)
    let mut scratch = crate::coordinator::InferScratch::default();
    let mut logits = crate::coordinator::LogitsBuf::new();
    let mut correct = 0;
    for i in 0..count {
        let t = std::time::Instant::now();
        let digit = backend.predict_into(&ds.images[i], &mut scratch, &mut logits)?;
        let us = t.elapsed().as_micros();
        let ok = digit == ds.labels[i];
        correct += ok as usize;
        println!(
            "image {i:3}  label {}  predicted {digit}  {}  ({us} µs)",
            ds.labels[i],
            if ok { "✓" } else { "✗" }
        );
    }
    println!("accuracy: {correct}/{count}");
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let model = load_model()?;
    let ds = Dataset::load_mem_subset(&artifacts_dir().join("mem"))?;
    let cfg = SimConfig::new(args.usize_or("parallelism", 64)?, mem_style(args)?);
    let mut acc = Accelerator::new(&model, cfg)?;
    let mut correct = 0;
    let mut per_digit = [[0u32; 2]; 10];
    for (img, &label) in ds.images.iter().zip(&ds.labels) {
        let r = acc.run_image(img);
        let ok = r.digit == label;
        correct += ok as usize;
        per_digit[label as usize][ok as usize] += 1;
    }
    println!(
        "§4.1 correctness: {}/{} correct on the exported subset (paper: 84/100)",
        correct,
        ds.len()
    );
    for (d, [wrong, right]) in per_digit.iter().enumerate() {
        println!("  digit {d}: {right}/{}", wrong + right);
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let model = load_model()?;
    let ds = Dataset::load_mem_subset(&artifacts_dir().join("mem"))?;
    let img = &ds.images[0];
    let mut table = Table::new(&[
        "Parallelism", "Latency (ns)", "Speedup", "LUTs (%)", "FFs (%)", "BRAMs (%)",
        "Power (W)", "Dyn/Static (%)", "Memory",
    ])
    .align(8, Align::Left);
    let base: f64 = {
        let steps = analytic_steps(&BNN_DIMS, 1, MemStyle::Bram) as f64;
        steps * 10.0
    };
    for mut cfg in SimConfig::table1_rows() {
        if args.flag("strict-clock") {
            cfg = cfg.strict_80mhz();
        }
        let mut acc = Accelerator::new(&model, cfg)?;
        let r = acc.run_image(img);
        let res = resources::best(&BNN_DIMS, cfg.parallelism, cfg.mem_style);
        let pow = power::estimate(&BNN_DIMS, &cfg);
        table.row(vec![
            cfg.parallelism.to_string(),
            crate::util::table::fmt_thousands(r.latency_ns as u64),
            format!("{:.2}", base / r.latency_ns),
            format!("{:.2}", res.lut_pct()),
            format!("{:.2}", res.ff_pct()),
            format!("{:.2}", res.bram_pct()),
            format!("{:.3}", pow.total_w),
            format!("{:.0}/{:.0}", pow.dynamic_pct(), pow.static_pct()),
            cfg.mem_style.name().to_string(),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let p = args.usize_or("parallelism", 64)?;
    let style = mem_style(args)?;
    let cfg = SimConfig::new(p, style);
    let model = load_model()?;
    let ds = Dataset::load_mem_subset(&artifacts_dir().join("mem"))?;
    let mut acc = Accelerator::new(&model, cfg)?;
    let r = acc.run_image(&ds.images[0]);
    let res = resources::best(&BNN_DIMS, p, style);
    let pow = power::estimate(&BNN_DIMS, &cfg);
    let tim = timing::best(p, style);
    println!("=== implementation report: P={p}, {} memory ===", style.name());
    println!("latency       : {} ns ({} cycles @ {} ns)", r.latency_ns, r.cycles, cfg.step_ns);
    println!(
        "cycles        : load={} prologue={} group_load={} compute={} writeback={} argmax={} done={}",
        r.breakdown.load, r.breakdown.prologue, r.breakdown.group_load,
        r.breakdown.compute, r.breakdown.writeback, r.breakdown.argmax, r.breakdown.done
    );
    println!(
        "resources     : LUT {:.2}%  FF {:.2}%  BRAM {:.2}% ({} blocks){}",
        res.lut_pct(), res.ff_pct(), res.bram_pct(), res.bram_blocks,
        if res.bram_overflow { "  [LUT fallback active]" } else { "" }
    );
    println!(
        "power         : {:.3} W total ({:.0}% dynamic / {:.0}% static), BRAM fraction {:.0}%",
        pow.total_w, pow.dynamic_pct(), pow.static_pct(), pow.bram_fraction * 100.0
    );
    println!("thermal       : {:.1} °C junction", pow.junction_c);
    println!(
        "timing        : WNS {:.3} ns, WHS {:.3} ns — {}",
        tim.wns_ns, tim.whs_ns,
        if tim.meets_80mhz { "meets 80 MHz" } else { "VIOLATES timing" }
    );
    println!(
        "energy        : {:.1} µJ/inference (paper §4.7.1: ≈11.0 µJ at 64x BRAM)",
        pow.uj_per_inference(r.latency_ns)
    );
    println!(
        "memory traffic: {} BRAM row reads, {} bits",
        r.activity.bram_row_reads, r.activity.bram_bits_read
    );
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    let (model, ds, trained) = crate::load_model_or_synth(100);
    if !trained {
        println!("(artifacts missing — untrained synthetic model, accuracy ≈ chance)");
    }
    let dir = artifacts_dir();
    let file_cfg = serve_config(args)?;
    let n = args.usize_or("requests", 1000)?;
    let workers = args.usize_or("workers", file_cfg.workers)?;
    let block_rows = block_rows_arg(args, file_cfg.block_rows)?;
    let tile_imgs = tile_imgs_arg(args, file_cfg.tile_imgs)?;
    let ring_cap = ring_cap_arg(args, file_cfg.ring_cap)?;
    let kernel = kernel_arg(args, file_cfg.kernel, block_rows, tile_imgs, ring_cap)?;
    let queue_cap = queue_cap_arg(args, file_cfg.queue_cap)?;
    let cfg = BatcherConfig {
        max_batch: args.usize_or("max-batch", file_cfg.batcher.max_batch)?,
        max_wait: std::time::Duration::from_micros(
            args.u64_or("max-wait-us", file_cfg.batcher.max_wait.as_micros() as u64)?,
        ),
    };

    let images: Vec<_> = (0..n).map(|i| ds.images[i % ds.len()].clone()).collect();
    let labels: Vec<_> = (0..n).map(|i| ds.labels[i % ds.len()]).collect();

    // One construction path for every topology: native and fpga-sim scale
    // via per-worker replicas (the sharded core); pjrt shares one backend
    // behind a single queue — the PJRT engine serializes dispatch and
    // PJRT-CPU parallelizes internally.
    let engine = match args.opt_or("backend", "native").as_str() {
        "native" => Engine::builder()
            .native(&model)
            .kernel(kernel)
            .workers(workers)
            .batcher(cfg)
            .queue_cap(queue_cap)
            .build()?,
        "fpga-sim" => {
            let sim_cfg = SimConfig::new(args.usize_or("parallelism", 64)?, mem_style(args)?);
            Engine::builder()
                .fpga_sim(&model, sim_cfg)
                .workers(workers)
                .batcher(cfg)
                .queue_cap(queue_cap)
                .build()?
        }
        "pjrt" => Engine::builder()
            .shared(Arc::new(PjrtBackend::new(Arc::new(crate::runtime::Engine::load(&dir)?))?))
            .workers(workers)
            .batcher(cfg)
            .queue_cap(queue_cap)
            .build()?,
        other => bail!("unknown backend '{other}'"),
    };

    // Only the serving window is timed: construction and shutdown stay
    // outside t0..wall.
    let t0 = std::time::Instant::now();
    let responses = engine.infer_many(images)?;
    let wall = t0.elapsed();
    let summary = engine.summary_line();
    let per_worker = engine.per_worker_report();
    engine.shutdown();

    let correct = responses
        .iter()
        .zip(&labels)
        .filter(|(r, &l)| r.digit == u16::from(l))
        .count();
    println!("served {n} requests in {:.1} ms", wall.as_secs_f64() * 1e3);
    println!("throughput : {:.0} req/s", n as f64 / wall.as_secs_f64());
    println!("accuracy   : {:.1}%", correct as f64 / n as f64 * 100.0);
    println!("metrics    : {summary}");
    if let Some(pw) = per_worker {
        print!("{pw}");
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let model = load_model()?;
    let ds = Dataset::load_mem_subset(&artifacts_dir().join("mem"))?;
    let idx = args.usize_or("image", 0)?.min(ds.len() - 1);
    let cfg = SimConfig::new(args.usize_or("parallelism", 64)?, mem_style(args)?);
    let mut acc = Accelerator::new(&model, cfg)?;
    let (r, trace) = acc.run_image_traced(&ds.images[idx]);
    let out = args.opt_or("out", "trace.vcd");
    std::fs::write(&out, trace.render())?;
    println!(
        "traced image {idx} (label {}, predicted {}): {} cycles -> {out}",
        ds.labels[idx], r.digit, trace.cycles()
    );
    println!("open with GTKWave; signals: fsm_stage, layer, group, bit_index, active_units, argmax_best, sevenseg_n");
    Ok(())
}

/// `--max-conns` / `--idle-timeout-ms` override the `[server]` section; the
/// resulting policy applies to whichever server (`--serve-async` or the
/// file's `async` key picks the readiness-polled one).
fn wire_server_cfg(
    args: &Args,
    file_cfg: &crate::config::ServeConfig,
) -> Result<crate::coordinator::WireServerConfig> {
    let max_conns = args.usize_or("max-conns", file_cfg.server.max_conns)?;
    if max_conns < 1 {
        bail!("--max-conns must be ≥ 1");
    }
    let idle_ms = args.u64_or(
        "idle-timeout-ms",
        file_cfg.server.idle_timeout.as_millis() as u64,
    )?;
    if idle_ms < 1 {
        bail!("--idle-timeout-ms must be ≥ 1");
    }
    Ok(crate::coordinator::WireServerConfig {
        max_conns,
        idle_timeout: std::time::Duration::from_millis(idle_ms),
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::coordinator::wire::WireServer;
    use crate::coordinator::AsyncWireServer;
    let (model, _, trained) = crate::load_model_or_synth(1);
    if !trained {
        println!("(artifacts missing — serving an untrained synthetic model)");
    }
    let file_cfg = serve_config(args)?;
    let addr = args.opt_or("addr", "127.0.0.1:7840");
    let workers = args.usize_or("workers", file_cfg.workers)?;
    let block_rows = block_rows_arg(args, file_cfg.block_rows)?;
    let tile_imgs = tile_imgs_arg(args, file_cfg.tile_imgs)?;
    let ring_cap = ring_cap_arg(args, file_cfg.ring_cap)?;
    let kernel = kernel_arg(args, file_cfg.kernel, block_rows, tile_imgs, ring_cap)?;
    let queue_cap = queue_cap_arg(args, file_cfg.queue_cap)?;
    let server_cfg = wire_server_cfg(args, &file_cfg)?;
    let use_async = args.flag("serve-async") || file_cfg.async_serve;
    let banner = |listen: std::net::SocketAddr| {
        println!("v1 frame: 0xB1 len16 payload[98] -> 0xB2 digit status latency_us32");
        println!("v2 frame: 0xC1 features top_k id64 n_images16 n_bits32 payloads -> 0xC2 … (batched, echoes ids)");
        println!(
            "policy: max {} connections, {} ms idle timeout (listening on {listen}, Ctrl-C to stop)",
            server_cfg.max_conns,
            server_cfg.idle_timeout.as_millis()
        );
    };

    // `[models.*]` sections switch the serve path to the multi-model
    // registry: one native engine per named model, wire-v2 requests route
    // by name, nameless (and all v1) traffic hits the default model.
    if !file_cfg.models.is_empty() {
        let registry = Arc::new(crate::coordinator::ModelRegistry::new());
        for mc in &file_cfg.models {
            let m = match &mc.weights {
                Some(p) => mem::load_model(p)
                    .with_context(|| format!("loading weights for model '{}'", mc.name))?,
                None => model.clone(),
            };
            let engine = Engine::builder()
                .native(&m)
                .kernel(kernel)
                .workers(workers)
                .batcher(file_cfg.batcher)
                .queue_cap(queue_cap)
                .build()?;
            registry.register_with_quota(&mc.name, engine, mc.quota);
            if mc.default {
                registry.set_default(&mc.name)?;
            }
        }
        println!(
            "models: {} (default: {})",
            registry.names().join(", "),
            registry.default_model().unwrap_or_default()
        );
        let status = |served: &std::sync::atomic::AtomicU64| {
            println!(
                "served: {}\n{}",
                served.load(std::sync::atomic::Ordering::Relaxed),
                registry.metrics_report()
            );
        };
        if use_async {
            let server =
                AsyncWireServer::start_registry_with(&addr, registry.clone(), server_cfg)?;
            println!(
                "async wire server on {} ({} readiness backend), multi-model",
                server.addr, server.poll_backend
            );
            banner(server.addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(5));
                status(&server.served);
            }
        } else {
            let server = WireServer::start_registry_with(&addr, registry.clone(), server_cfg)?;
            println!(
                "wire-protocol server (thread-per-connection) on {}, multi-model",
                server.addr
            );
            banner(server.addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(5));
                status(&server.served);
            }
        }
    }

    let backend_default = file_cfg
        .backends
        .first()
        .cloned()
        .unwrap_or_else(|| "native".to_string());
    let engine = match args.opt_or("backend", &backend_default).as_str() {
        "native" => Engine::builder()
            .native(&model)
            .kernel(kernel)
            .workers(workers)
            .batcher(file_cfg.batcher)
            .queue_cap(queue_cap)
            .build()?,
        "fpga-sim" => {
            let sim_cfg =
                SimConfig::new(args.usize_or("parallelism", file_cfg.parallelism)?, mem_style(args)?);
            // the simulated hardware is single-image; the builder clamps
            // max_batch to the replica's limit of 1 automatically
            Engine::builder()
                .fpga_sim(&model, sim_cfg)
                .workers(workers)
                .batcher(file_cfg.batcher)
                .queue_cap(queue_cap)
                .build()?
        }
        "pjrt" => Engine::builder()
            .shared(Arc::new(PjrtBackend::new(Arc::new(crate::runtime::Engine::load(
                &artifacts_dir(),
            )?))?))
            .workers(workers)
            .batcher(file_cfg.batcher)
            .queue_cap(queue_cap)
            .build()?,
        other => bail!("unknown backend '{other}'"),
    };
    if use_async {
        let server = AsyncWireServer::start_with(&addr, Arc::new(engine), server_cfg)?;
        println!(
            "async wire server on {} ({} readiness backend)",
            server.addr, server.poll_backend
        );
        banner(server.addr);
        loop {
            std::thread::sleep(std::time::Duration::from_secs(5));
            println!(
                "served: {}  open connections: {}",
                server.served.load(std::sync::atomic::Ordering::Relaxed),
                server.metrics().conn_open.load(std::sync::atomic::Ordering::SeqCst)
            );
        }
    } else {
        let server = WireServer::start_with(&addr, Arc::new(engine), server_cfg)?;
        println!("wire-protocol server (thread-per-connection) on {}", server.addr);
        banner(server.addr);
        loop {
            std::thread::sleep(std::time::Duration::from_secs(5));
            println!(
                "served: {}  open connections: {}",
                server.served.load(std::sync::atomic::Ordering::Relaxed),
                server.metrics().conn_open.load(std::sync::atomic::Ordering::SeqCst)
            );
        }
    }
}

/// `bnn-fpga classify <weights.json> <image>` — one-shot local inference
/// with no server and no artifacts directory: load the weights, read the
/// image, binarize → bit-pack, predict, print the class and top logits.
///
/// The image file is either idx3 (magic 0x00000803; `--index` picks one
/// image) or raw grayscale bytes.  For raw files the geometry is inferred:
/// `--width`/`--height` when given, else a conv first layer's spatial
/// shape (the layer pins H×W×C exactly), else the model's input size
/// (square root when it is a perfect square, e.g. 784 → 28×28).  Pixels
/// binarize
/// as `p >= --threshold` (default 128, the MNIST convention); `--invert`
/// flips polarity for black-on-white scans.  `--labels FILE` maps class
/// indices to names (one per line).
fn cmd_classify(args: &Args) -> Result<()> {
    let [weights_path, image_path] = args.positionals.as_slice() else {
        bail!("classify needs exactly two positionals: <weights.json> <image>\n\n{USAGE}");
    };
    let model = mem::load_model(std::path::Path::new(weights_path))?;
    let n_in = model.n_in();

    let bytes = std::fs::read(image_path).with_context(|| format!("reading image {image_path}"))?;
    let idx3 = bytes.len() >= 4 && bytes[..4] == [0, 0, 8, 3];
    let (pixels, geom) = if idx3 {
        let (imgs, rows, cols) = mem::read_idx_images(std::path::Path::new(image_path))?;
        let i = args.usize_or("index", 0)?;
        if i >= imgs.len() {
            bail!("--index {i} out of range: idx3 file holds {} images", imgs.len());
        }
        (imgs.into_iter().nth(i).unwrap(), format!("{rows}×{cols} (idx3 image {i})"))
    } else {
        let width = args.usize_or("width", 0)?;
        let height = args.usize_or("height", 0)?;
        // a conv first layer pins the image geometry exactly; dense-only
        // models accept any factorization of n_in
        let geometry = model.input_geometry();
        let ch = geometry.map_or(1, |(c, _, _)| c);
        let (w, h) = match (width, height) {
            (0, 0) => {
                if let Some((_, gh, gw)) = geometry {
                    (gw, gh)
                } else {
                    // no geometry given: trust the model's input size, shown
                    // square when it is one (28×28 for the paper's 784)
                    let side = (n_in as f64).sqrt() as usize;
                    if side * side == n_in {
                        (side, side)
                    } else {
                        (n_in, 1)
                    }
                }
            }
            (w, 0) if w > 0 && n_in % (w * ch) == 0 => (w, n_in / (w * ch)),
            (0, h) if h > 0 && n_in % (h * ch) == 0 => (n_in / (h * ch), h),
            (w, h) if w > 0 && h > 0 => (w, h),
            _ => bail!(
                "--width/--height must divide the model input size {n_in}\
                 {}",
                if ch > 1 { format!(" ({ch} channels)") } else { String::new() }
            ),
        };
        if let Some((gc, gh, gw)) = geometry {
            if (w, h) != (gw, gh) {
                bail!(
                    "--width/--height {w}×{h} conflicts with the model's conv \
                     first layer, which takes {gw}×{gh}×{gc} inputs"
                );
            }
        }
        if ch * w * h != n_in {
            bail!(
                "{w}×{h}×{ch} = {} pixels, but the model takes {n_in} inputs",
                ch * w * h
            );
        }
        if bytes.len() != n_in {
            bail!(
                "raw image is {} bytes, expected {n_in} ({w}×{h} grayscale); \
                 for idx3 files the header was not recognized",
                bytes.len()
            );
        }
        let geom = if ch > 1 {
            format!("{w}×{h}×{ch} (raw)")
        } else {
            format!("{w}×{h} (raw)")
        };
        (bytes, geom)
    };
    if pixels.len() != n_in {
        bail!("image has {} pixels, model takes {n_in}", pixels.len());
    }

    let threshold = args.usize_or("threshold", 128)?;
    if threshold > 255 {
        bail!("--threshold must be in 0..=255");
    }
    let invert = args.flag("invert");
    let bits: Vec<u8> = pixels
        .iter()
        .map(|&p| u8::from((usize::from(p) >= threshold) != invert))
        .collect();
    let img = crate::bnn::packing::Packed::from_bits(&bits);

    let labels: Option<Vec<String>> = match args.opt("labels") {
        Some(p) => Some(
            std::fs::read_to_string(p)
                .with_context(|| format!("reading labels {p}"))?
                .lines()
                .map(str::to_string)
                .collect(),
        ),
        None => None,
    };
    let name_of = |c: usize| -> String {
        match &labels {
            Some(ls) if c < ls.len() => format!("{c} ({})", ls[c]),
            _ => c.to_string(),
        }
    };

    let t = std::time::Instant::now();
    let logits = model.logits(&img.words);
    let us = t.elapsed().as_micros();
    let best = logits
        .iter()
        .enumerate()
        .max_by_key(|&(i, &v)| (v, std::cmp::Reverse(i)))
        .map(|(i, _)| i)
        .unwrap();
    println!("image  : {geom}, threshold {threshold}{}", if invert { ", inverted" } else { "" });
    println!("model  : {} inputs, {} classes, {} layers", n_in, logits.len(), model.n_layers());
    println!("class  : {}  ({us} µs)", name_of(best));
    let mut ranked: Vec<(usize, i32)> = logits.iter().copied().enumerate().collect();
    ranked.sort_by_key(|&(i, v)| (std::cmp::Reverse(v), i));
    for &(c, v) in ranked.iter().take(5) {
        println!("  logit[{}] = {v}", name_of(c));
    }
    Ok(())
}

/// Open-loop load against a running `serve` instance (see
/// `coordinator/loadgen.rs` on why the loop is open): prints the achieved
/// throughput and the scheduled-send latency percentiles (success-only,
/// with a separate error-latency line).
///
/// `--chaos-rate`/`--chaos-seed` switch to the self-contained chaos soak:
/// an in-process async server over a [`crate::coordinator::ChaosBackend`]
/// -wrapped engine is stood up, the open loop is aimed at it, and the
/// engine's fault ledger (restarts, rejected, deadline sheds) is printed
/// at the end — no `--addr` needed.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use crate::coordinator::{
        run_open_loop, AsyncWireServer, ChaosConfig, InferOptions, LoadConfig, ModelRegistry,
        RetryPolicy, WireClient,
    };
    use std::net::ToSocketAddrs;

    let chaos_rate = args.f64_or("chaos-rate", 0.0)?;
    if !(0.0..=100.0).contains(&chaos_rate) {
        bail!("--chaos-rate must be a percentage in 0..=100");
    }
    let chaos = chaos_rate > 0.0 || args.opt("chaos-seed").is_some();

    let model = args.opt("model").map(str::to_string);
    // v1 frames cannot carry a model name, so naming a model defaults the
    // mix to v2-only; an explicit --mix-v1 still wins (the v1 share just
    // hits the default model)
    let mix_default = if model.is_some() { 0.0 } else { 50.0 };
    let mix_v1 = args.f64_or("mix-v1", mix_default)?;
    if !(0.0..=100.0).contains(&mix_v1) {
        bail!("--mix-v1 must be a percentage in 0..=100");
    }

    // the image pool: trained artifacts when present, synthetic otherwise —
    // load generation only needs well-formed 784-bit frames
    let (bnn_model, ds, trained) = crate::load_model_or_synth(256);
    if !trained {
        println!("(artifacts missing — load uses synthetic images)");
    }

    let (soak, addr) = if chaos {
        let seed = args.u64_or("chaos-seed", 0xC4A05)?;
        let rate = if chaos_rate > 0.0 { chaos_rate } else { 5.0 };
        let engine = Engine::builder()
            .native(&bnn_model)
            .workers(args.usize_or("workers", 2)?)
            .chaos(ChaosConfig::new(seed, rate / 100.0))
            .build()?;
        let registry = Arc::new(ModelRegistry::new());
        registry.register(model.as_deref().unwrap_or("default"), engine);
        let server = AsyncWireServer::start_registry("127.0.0.1:0", registry.clone())?;
        println!(
            "chaos soak : in-process async server on {} ({} backend), seed {seed:#x}, \
             fault rate {rate:.1}%",
            server.addr, server.poll_backend
        );
        let a = server.addr;
        (Some((server, registry)), a)
    } else {
        let addr_s = args
            .opt("addr")
            .ok_or_else(|| anyhow::anyhow!("loadgen needs --addr HOST:PORT (or --chaos-rate)"))?;
        let addr = addr_s
            .to_socket_addrs()
            .with_context(|| format!("resolving '{addr_s}'"))?
            .next()
            .ok_or_else(|| anyhow::anyhow!("'{addr_s}' resolved to no address"))?;
        (None, addr)
    };

    let cfg = LoadConfig {
        addr,
        connections: args.usize_or("connections", 16)?,
        rate: args.f64_or("rate", 10_000.0)?,
        duration: std::time::Duration::from_millis(args.u64_or("duration-ms", 2_000)?),
        v1_fraction: mix_v1 / 100.0,
        seed: args.u64_or("seed", 0xB14D)?,
        model,
    };
    println!(
        "offering {:.0} images/sec for {} ms over {} connections ({:.0}% v1{}) at {addr}",
        cfg.rate,
        cfg.duration.as_millis(),
        cfg.connections,
        mix_v1,
        cfg.model.as_deref().map(|m| format!(", model '{m}'")).unwrap_or_default()
    );
    let r = run_open_loop(&ds.images, &cfg)?;
    println!("sent       : {}", r.sent);
    println!("completed  : {} ({} typed errors)", r.completed, r.errors);
    println!("achieved   : {:.0} images/sec (offered {:.0})", r.achieved_ips, r.offered_ips);
    println!(
        "latency    : p50 {:.0} µs  p99 {:.0} µs  p999 {:.0} µs  max {:.0} µs (success only)",
        r.p50_us, r.p99_us, r.p999_us, r.max_us
    );
    if r.errors > 0 {
        println!(
            "err-latency: p50 {:.0} µs  p99 {:.0} µs  max {:.0} µs ({} typed errors)",
            r.err_p50_us, r.err_p99_us, r.err_max_us, r.errors
        );
    }
    println!("wall       : {:.1} ms", r.wall.as_secs_f64() * 1e3);
    if let Some((server, registry)) = soak {
        // a retrying probe before teardown: exercise the client backoff
        // path against the faulting server, then fold the attempt count
        // into the engine books so `retries=` in the summary line is live
        let mut probe = WireClient::connect(server.addr)?.with_retry(RetryPolicy::default());
        let probes = ds.images.len().min(32);
        let mut served = 0usize;
        for img in ds.images.iter().take(probes) {
            if probe.classify_v2(img, InferOptions::default()).is_ok() {
                served += 1;
            }
        }
        let retries = probe.retries_attempted();
        drop(probe);
        server.shutdown();
        if let Ok(engine) = registry.engine(cfg.model.as_deref().unwrap_or("default")) {
            engine
                .metrics()
                .retries_attempted
                .fetch_add(retries, std::sync::atomic::Ordering::Relaxed);
        }
        println!("probes     : {served}/{probes} served through the retrying client ({retries} retries)");
        print!("engine     : {}", registry.metrics_report());
    }
    Ok(())
}
