//! Micro-benchmark harness (criterion-substitute).
//!
//! `cargo bench` targets in `rust/benches/` use `harness = false` and drive
//! this: warmup, adaptive iteration count targeting a wall-clock budget,
//! outlier-robust summary, and paper-style table output via [`super::table`].

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use super::stats::Summary;

/// Result of one benchmark: per-iteration timings in nanoseconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.summary.mean / 1e3
    }
}

/// Benchmark runner with a fixed measurement budget per target.
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 100_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            min_iters: 5,
            max_iters: 10_000,
        }
    }

    /// Run `f` repeatedly; returns per-iteration wall-clock stats.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup + single-shot estimate.
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup || warm_iters == 0 {
            bb(f());
            warm_iters += 1;
            if warm_iters > self.max_iters {
                break;
            }
        }
        let est_ns = (w0.elapsed().as_nanos() as f64 / warm_iters as f64).max(1.0);
        let iters = ((self.budget.as_nanos() as f64 / est_ns) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            bb(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters,
        }
    }

    /// Run `f` exactly `n` times, returning each iteration's wall-clock ns —
    /// used for run-by-run series like the paper's Fig. 1.
    pub fn run_series<T>(&self, n: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
        for _ in 0..3 {
            bb(f()); // fixed small warmup
        }
        (0..n)
            .map(|_| {
                let t = Instant::now();
                bb(f());
                t.elapsed().as_nanos() as f64
            })
            .collect()
    }
}

/// `--quick` support for bench binaries: scale budgets down under CI.
pub fn from_args() -> Bench {
    if std::env::args().any(|a| a == "--quick") || std::env::var_os("BNN_BENCH_QUICK").is_some() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 1000,
        };
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.iters >= 5);
    }

    #[test]
    fn series_has_requested_length() {
        let b = Bench::quick();
        let s = b.run_series(17, || 1 + 1);
        assert_eq!(s.len(), 17);
    }
}
