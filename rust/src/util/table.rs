//! ASCII/markdown table printer for bench output (paper-style rows).

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder producing github-markdown-ish output.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Right; headers.len()],
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let pad = widths[i] - cells[i].chars().count();
                match self.aligns[i] {
                    Align::Left => line.push_str(&format!(" {}{} |", cells[i], " ".repeat(pad))),
                    Align::Right => line.push_str(&format!(" {}{} |", " ".repeat(pad), cells[i])),
                }
            }
            line
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers used across benches.
pub fn fmt_f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

pub fn fmt_thousands(x: u64) -> String {
    let s = x.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Name", "Val"]).align(0, Align::Left);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "12345".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Name"));
        assert!(lines[3].contains("| long-name | 12345 |"));
        // all lines equal width
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        Table::new(&["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn thousands() {
        assert_eq!(fmt_thousands(1_096_045), "1,096,045");
        assert_eq!(fmt_thousands(45), "45");
        assert_eq!(fmt_thousands(1000), "1,000");
    }
}
