//! First-party infrastructure substrates.
//!
//! The offline build environment ships only the crates vendored for
//! `xla 0.1.6`, so the usual ecosystem pieces are implemented here:
//! [`prng`] (rand), [`json`] (serde_json), [`stats`]/[`bench`] (criterion),
//! [`proptest_lite`] (proptest), [`table`] (comfy-table) and [`plot`]
//! (textplots).  Each is small, documented and unit-tested.

pub mod bench;
pub mod json;
pub mod plot;
pub mod prng;
pub mod proptest_lite;
pub mod stats;
pub mod table;
