//! Property-based testing runner with shrinking (the `proptest` substitute).
//!
//! Usage:
//! ```ignore
//! let mut runner = Runner::new("packing-roundtrip");
//! runner.run(&gens::vec_u8(1..=800), |bits| {
//!     let packed = pack(bits);
//!     unpack(&packed, bits.len()) == *bits
//! });
//! ```
//! On failure the input is shrunk (halving/simplification) and the minimal
//! counterexample plus the reproducing seed is reported in the panic
//! message.  Coordinator invariants (routing, batching, state) and the
//! packing/popcount identities use this.

use super::prng::Xoshiro256;

/// A generator: produces a random value and enumerates shrink candidates.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Xoshiro256) -> Self::Value;
    /// Candidate simplifications of `v`, in decreasing aggressiveness.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value>;
}

/// Property runner.
pub struct Runner {
    pub name: String,
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Runner {
    pub fn new(name: &str) -> Self {
        let seed = std::env::var("BNN_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_2025);
        Self {
            name: name.to_string(),
            cases: 64,
            seed,
            max_shrink_steps: 200,
        }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Check `prop` over `cases` generated inputs; panics with the minimal
    /// shrunk counterexample on failure.
    pub fn run<G: Gen>(&self, gen: &G, prop: impl Fn(&G::Value) -> bool) {
        let mut rng = Xoshiro256::new(self.seed);
        for case in 0..self.cases {
            let input = gen.generate(&mut rng);
            if !prop(&input) {
                let minimal = self.shrink_failure(gen, input, &prop);
                panic!(
                    "property '{}' failed (case {case}, seed {:#x}).\nminimal counterexample: {:?}",
                    self.name, self.seed, minimal
                );
            }
        }
    }

    fn shrink_failure<G: Gen>(
        &self,
        gen: &G,
        mut failing: G::Value,
        prop: &impl Fn(&G::Value) -> bool,
    ) -> G::Value {
        let mut steps = 0;
        'outer: while steps < self.max_shrink_steps {
            for cand in gen.shrink(&failing) {
                steps += 1;
                if !prop(&cand) {
                    failing = cand;
                    continue 'outer;
                }
                if steps >= self.max_shrink_steps {
                    break;
                }
            }
            break;
        }
        failing
    }
}

/// Built-in generators.
pub mod gens {
    use super::*;
    use std::ops::RangeInclusive;

    /// Uniform u64 in range, shrinking toward the low bound.
    pub struct U64(pub RangeInclusive<u64>);

    impl Gen for U64 {
        type Value = u64;
        fn generate(&self, rng: &mut Xoshiro256) -> u64 {
            let (lo, hi) = (*self.0.start(), *self.0.end());
            lo + rng.below(hi - lo + 1)
        }
        fn shrink(&self, v: &u64) -> Vec<u64> {
            let lo = *self.0.start();
            let mut out = Vec::new();
            if *v > lo {
                out.push(lo);
                out.push(lo + (*v - lo) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        }
    }

    /// Vec of random bits {0,1}, length drawn from range; shrinks by halving
    /// length then zeroing elements.
    pub struct BitVec(pub RangeInclusive<usize>);

    impl Gen for BitVec {
        type Value = Vec<u8>;
        fn generate(&self, rng: &mut Xoshiro256) -> Vec<u8> {
            let (lo, hi) = (*self.0.start(), *self.0.end());
            let n = lo + rng.below((hi - lo + 1) as u64) as usize;
            (0..n).map(|_| (rng.next_u64() & 1) as u8).collect()
        }
        fn shrink(&self, v: &Vec<u8>) -> Vec<Vec<u8>> {
            let lo = *self.0.start();
            let mut out = Vec::new();
            if v.len() > lo {
                out.push(v[..lo.max(v.len() / 2)].to_vec());
                out.push(v[..v.len() - 1].to_vec());
            }
            if v.iter().any(|&b| b != 0) {
                out.push(vec![0; v.len()]);
            }
            out
        }
    }

    /// Pair of independent generators.
    pub struct Pair<A, B>(pub A, pub B);

    impl<A: Gen, B: Gen> Gen for Pair<A, B> {
        type Value = (A::Value, B::Value);
        fn generate(&self, rng: &mut Xoshiro256) -> Self::Value {
            (self.0.generate(rng), self.1.generate(rng))
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let mut out: Vec<Self::Value> = self
                .0
                .shrink(&v.0)
                .into_iter()
                .map(|a| (a, v.1.clone()))
                .collect();
            out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
            out
        }
    }

    /// Vec of u64 drawn from an element range; shrinks length then values.
    pub struct VecU64 {
        pub len: RangeInclusive<usize>,
        pub elem: RangeInclusive<u64>,
    }

    impl Gen for VecU64 {
        type Value = Vec<u64>;
        fn generate(&self, rng: &mut Xoshiro256) -> Vec<u64> {
            let n = *self.len.start()
                + rng.below((*self.len.end() - *self.len.start() + 1) as u64) as usize;
            let (lo, hi) = (*self.elem.start(), *self.elem.end());
            (0..n).map(|_| lo + rng.below(hi - lo + 1)).collect()
        }
        fn shrink(&self, v: &Vec<u64>) -> Vec<Vec<u64>> {
            let lo_len = *self.len.start();
            let lo = *self.elem.start();
            let mut out = Vec::new();
            if v.len() > lo_len {
                out.push(v[..lo_len.max(v.len() / 2)].to_vec());
            }
            if v.iter().any(|&x| x != lo) {
                out.push(v.iter().map(|_| lo).collect());
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::gens::*;
    use super::*;

    #[test]
    fn passing_property_passes() {
        Runner::new("trivial").run(&U64(0..=100), |&v| v <= 100);
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            Runner::new("gt-10-fails").run(&U64(0..=1000), |&v| v <= 10);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The minimal failing value for `v <= 10` over shrink-toward-0 is 11.
        assert!(msg.contains("11"), "expected minimal 11 in: {msg}");
    }

    #[test]
    fn bitvec_respects_length_range() {
        let g = BitVec(3..=17);
        let mut rng = Xoshiro256::new(1);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!((3..=17).contains(&v.len()));
            assert!(v.iter().all(|&b| b <= 1));
        }
    }

    #[test]
    fn pair_shrinks_both_sides() {
        let g = Pair(U64(0..=10), U64(0..=10));
        let shrunk = g.shrink(&(5, 7));
        assert!(shrunk.iter().any(|&(a, b)| a < 5 && b == 7));
        assert!(shrunk.iter().any(|&(a, b)| a == 5 && b < 7));
    }
}
