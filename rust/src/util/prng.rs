//! Deterministic PRNGs (the `rand` substitute).
//!
//! [`SplitMix64`] for seeding, [`Xoshiro256`] (xoshiro256**) as the
//! general-purpose generator.  Both are the reference algorithms from
//! Blackman & Vigna; outputs are reproducible across platforms, which the
//! benches and property tests rely on.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference outputs for seed 1234567 (computed from the canonical
        // algorithm; stable across platforms).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::new(43);
        assert_ne!(r1.next_u64(), r3.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
