//! ASCII line plots — used to render the paper's Fig. 1 (run-by-run latency
//! series) directly in bench output, plus CSV dumping for external plotting.

/// Render one or more named series as an ASCII chart of the given size.
/// Each series is drawn with its own glyph; the y-axis is shared.
pub fn ascii_plot(series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    assert!(!series.is_empty());
    let glyphs = ['*', 'o', '+', 'x', '#'];
    let max_len = series.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    if max_len == 0 {
        return String::from("(empty series)\n");
    }
    let ymin = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .fold(f64::INFINITY, f64::min);
    let ymax = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .fold(f64::NEG_INFINITY, f64::max);
    let span = (ymax - ymin).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        let glyph = glyphs[si % glyphs.len()];
        for (i, &v) in s.iter().enumerate() {
            let x = if max_len == 1 {
                0
            } else {
                i * (width - 1) / (max_len - 1)
            };
            let yf = (v - ymin) / span;
            let y = ((1.0 - yf) * (height - 1) as f64).round() as usize;
            grid[y.min(height - 1)][x.min(width - 1)] = glyph;
        }
    }

    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>10.3} |")
        } else if i == height - 1 {
            format!("{ymin:>10.3} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>11}+{}\n", "", "-".repeat(width)));
    let mut legend = format!("{:>12}", "");
    for (si, (name, _)) in series.iter().enumerate() {
        legend.push_str(&format!("{} = {}   ", glyphs[si % glyphs.len()], name));
    }
    out.push_str(&legend);
    out.push('\n');
    out
}

/// Write series as CSV: `index,<name1>,<name2>,...` (ragged series padded
/// with empty cells).
pub fn to_csv(series: &[(&str, &[f64])]) -> String {
    let max_len = series.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    let mut out = String::from("index");
    for (name, _) in series {
        out.push(',');
        out.push_str(name);
    }
    out.push('\n');
    for i in 0..max_len {
        out.push_str(&i.to_string());
        for (_, s) in series {
            out.push(',');
            if let Some(v) = s.get(i) {
                out.push_str(&format!("{v}"));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_has_expected_shape() {
        let a = [1.0, 2.0, 3.0, 2.0, 1.0];
        let b = [3.0, 3.0, 3.0, 3.0, 3.0];
        let s = ascii_plot(&[("a", &a), ("b", &b)], 20, 8);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 8 + 2); // grid + axis + legend
        assert!(s.contains("a"));
        assert!(s.contains("b"));
        assert!(s.contains('*') && s.contains('o'));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let a = [1.0, 2.0];
        let b = [5.0];
        let csv = to_csv(&[("x", &a), ("y", &b)]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "index,x,y");
        assert_eq!(lines[1], "0,1,5");
        assert_eq!(lines[2], "1,2,");
    }

    #[test]
    fn constant_series_no_panic() {
        let a = [2.0, 2.0, 2.0];
        let s = ascii_plot(&[("c", &a)], 10, 4);
        assert!(!s.is_empty());
    }
}
