//! Minimal JSON parser + writer (the `serde_json` substitute).
//!
//! Full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bool/null); numbers are kept as `f64` with an `i64` fast path so packed
//! `u32` weight words (≤ 2³²−1 < 2⁵³) round-trip exactly.  Used to read
//! `artifacts/weights.json`, `manifest.json` and `train_log.json`, and to
//! emit bench results.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Object keys are ordered (BTreeMap) for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // --- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > 2f64.powi(53) {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as u64)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n.abs() > 2f64.powi(53) {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    // --- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if &self.b[self.i..self.i + 2] != b"\\u" {
                                    bail!("unpaired surrogate");
                                }
                                self.i += 2;
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("invalid codepoint"))?);
                        }
                        c => bail!("invalid escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: find the char boundary and copy it
                    let start = self.i - 1;
                    let text = std::str::from_utf8(&self.b[start..])?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn u32_words_roundtrip_exactly() {
        for w in [0u32, 1, 0xFFFF_FFFF, 0x8000_0000, 123_456_789] {
            let j = Json::parse(&format!("{w}")).unwrap();
            assert_eq!(j.as_u64().unwrap() as u32, w);
        }
    }

    #[test]
    fn writer_roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"n":null,"s":"a\"b","t":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // raw multibyte passthrough
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn errors_are_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn missing_key_reports_name() {
        let v = Json::parse("{}").unwrap();
        let err = v.get("weights").unwrap_err().to_string();
        assert!(err.contains("weights"));
    }
}
