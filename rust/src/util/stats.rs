//! Descriptive statistics + latency histograms (criterion-substitute core).

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted.  Panics on empty input.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64; // sample variance (ddof=1), 0 for n=1
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Fixed-bucket log-scale latency histogram (nanoseconds), constant-size,
/// allocation-free on the record path — used by coordinator metrics.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// buckets\[i\] counts samples in [2^i, 2^(i+1)) ns; 64 buckets cover all u64.
    buckets: [u64; 64],
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: [0; 64],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    pub fn record(&mut self, ns: u64) {
        let idx = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate percentile: bucket upper bound at the target rank.
    pub fn percentile_ns(&self, pct: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (pct / 100.0 * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return 1u64 << (i + 1).min(63);
            }
        }
        self.max_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn histogram_counts_and_bounds() {
        let mut h = LatencyHistogram::new();
        for ns in [100, 200, 400, 800, 100_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min_ns(), 100);
        assert_eq!(h.max_ns(), 100_000);
        assert!((h.mean_ns() - 20_300.0).abs() < 1.0);
        // p50 should be near the low cluster, p99 near the outlier
        assert!(h.percentile_ns(50.0) <= 1024);
        assert!(h.percentile_ns(99.9) >= 65_536);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min_ns(), 10);
        assert_eq!(a.max_ns(), 1000);
    }

    #[test]
    fn histogram_zero_ns_safe() {
        let mut h = LatencyHistogram::new();
        h.record(0);
        assert_eq!(h.count(), 1);
    }
}
