//! Open-loop load generator for the wire servers.
//!
//! Closed-loop clients (send, wait, send) measure a system that is never
//! actually saturated: each stalled response slows the *offered* load too,
//! hiding queueing delay — the coordinated-omission trap.  This generator is
//! **open-loop**: every request has a scheduled send time on a fixed arrival
//! grid (`k / rate` seconds after start), writer threads pace the schedule
//! without ever waiting for responses, and latency is measured from the
//! *scheduled* send time, so a server that falls behind pays for the delay
//! in the histogram instead of silently shedding offered load.
//!
//! Traffic is a deterministic (seeded) mix of v1 and single-image v2 frames
//! striped round-robin across `connections` sockets; responses are read by
//! one reader thread per connection (in order — both servers answer one
//! connection's frames in order).  Typed error frames count as `errors`
//! (e.g. [`super::WireStatus::Overloaded`] under queue-cap shedding), not
//! latency samples.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::request::InferOptions;
use super::wire::{
    encode_request, encode_request_v2_for, read_response_v2, WireStatus, IMAGE_BITS, MAGIC_ERR,
    MAGIC_RESP,
};
use crate::bnn::packing::Packed;
use crate::util::prng::Xoshiro256;
use crate::util::stats::percentile_sorted;

/// Open-loop run parameters.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    pub addr: SocketAddr,
    /// Concurrent connections the offered load is striped across.
    pub connections: usize,
    /// Offered arrival rate, images per second (fixed grid, not Poisson —
    /// deterministic schedules make runs comparable).
    pub rate: f64,
    /// How long to offer load for.
    pub duration: Duration,
    /// Fraction of requests sent as v1 frames (the rest are single-image
    /// v2, digits-only).  v1 requires 784-bit images.
    pub v1_fraction: f64,
    pub seed: u64,
    /// Name the v2 frames address to a registry model (`FEAT_MODEL`
    /// section); `None` offers nameless traffic (the default model).  v1
    /// frames cannot carry a name and always hit the default.
    pub model: Option<String>,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            addr: SocketAddr::from(([127, 0, 0, 1], 0)),
            connections: 16,
            rate: 10_000.0,
            duration: Duration::from_secs(2),
            v1_fraction: 0.5,
            seed: 0xB14D,
            model: None,
        }
    }
}

/// What one open-loop run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// The configured arrival rate (images/sec).
    pub offered_ips: f64,
    /// Requests actually written to sockets.
    pub sent: u64,
    /// OK responses received.
    pub completed: u64,
    /// Typed error responses (overload shedding, backend refusals).
    pub errors: u64,
    /// `completed / wall` — what the server actually sustained.
    pub achieved_ips: f64,
    /// **Success-only** latency percentiles in µs, measured from
    /// *scheduled* send time.  Error responses are excluded so overload
    /// shedding and chaos faults can't flatter (fast typed refusals) or
    /// smear (latency-spiked crashes) the service numbers.
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub max_us: f64,
    /// Error-response latency percentiles in µs (same scheduled-send
    /// clock) — how long callers waited to be *refused*.  Zero when no
    /// errors occurred.
    pub err_p50_us: f64,
    pub err_p99_us: f64,
    pub err_max_us: f64,
    /// Start of the arrival schedule to the last response read.
    pub wall: Duration,
}

/// One pre-planned request: when to send, what bytes, how many response
/// frames it answers with (always 1 — single-image frames only).
struct PlannedSend {
    offset: Duration,
    frame: Vec<u8>,
    v1: bool,
}

/// Drive `cfg.rate` images/sec of mixed v1/v2 traffic at `cfg.addr` for
/// `cfg.duration`, open-loop.  `images` is the pool requests draw from
/// (round-robin); every image must be 784 bits wide when `v1_fraction > 0`
/// (v1 is fixed-width).
pub fn run_open_loop(images: &[Packed], cfg: &LoadConfig) -> Result<LoadReport> {
    anyhow::ensure!(!images.is_empty(), "load generation needs ≥ 1 image");
    anyhow::ensure!(cfg.connections >= 1, "need ≥ 1 connection");
    anyhow::ensure!(cfg.rate > 0.0, "arrival rate must be positive");
    anyhow::ensure!(
        (0.0..=1.0).contains(&cfg.v1_fraction),
        "v1_fraction must be in [0, 1]"
    );
    if cfg.v1_fraction > 0.0 {
        for img in images {
            anyhow::ensure!(
                img.n_bits == IMAGE_BITS,
                "v1 traffic requires {IMAGE_BITS}-bit images, got {}",
                img.n_bits
            );
        }
    }

    let total = (cfg.rate * cfg.duration.as_secs_f64()).floor() as usize;
    anyhow::ensure!(total >= 1, "rate × duration must yield ≥ 1 request");

    // Pre-encode the whole schedule so the pacer threads do no per-request
    // work beyond a sleep and a write (encoding jitter would otherwise eat
    // into the arrival grid at high rates).  Request k goes out at
    // `k / rate` on connection `k % connections`.
    let mut rng = Xoshiro256::new(cfg.seed);
    let mut plans: Vec<Vec<PlannedSend>> = (0..cfg.connections).map(|_| Vec::new()).collect();
    let mut next_id: u64 = 1;
    for k in 0..total {
        let img = &images[k % images.len()];
        let v1 = rng.next_f64() < cfg.v1_fraction;
        let frame = if v1 {
            encode_request(img).context("encoding a v1 load frame")?
        } else {
            let id = next_id;
            next_id = next_id.wrapping_add(1);
            encode_request_v2_for(
                std::slice::from_ref(img),
                id,
                InferOptions::digits_only(),
                cfg.model.as_deref(),
            )
            .context("encoding a v2 load frame")?
        };
        plans[k % cfg.connections].push(PlannedSend {
            offset: Duration::from_secs_f64(k as f64 / cfg.rate),
            frame,
            v1,
        });
    }

    // Connect everything up front; a small grace period before the schedule
    // starts so connect latency doesn't pollute the first samples.
    let mut writers: Vec<TcpStream> = Vec::with_capacity(cfg.connections);
    for i in 0..cfg.connections {
        let s = TcpStream::connect(cfg.addr)
            .with_context(|| format!("connecting load connection {i} to {}", cfg.addr))?;
        s.set_nodelay(true).ok();
        writers.push(s);
    }
    let start = Instant::now() + Duration::from_millis(50);
    // Readers must eventually give up if the server wedges: generously past
    // the schedule end.
    let read_deadline = cfg.duration + Duration::from_secs(10);

    struct ConnOutcome {
        sent: u64,
        completed: u64,
        errors: u64,
        latencies_ns: Vec<u64>,
        err_latencies_ns: Vec<u64>,
        last_read_at: Option<Instant>,
    }

    let outcomes: Vec<Result<ConnOutcome>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(cfg.connections);
        for (plan, stream) in plans.into_iter().zip(writers.into_iter()) {
            handles.push(scope.spawn(move || -> Result<ConnOutcome> {
                let mut reader = stream.try_clone().context("cloning for the reader side")?;
                reader
                    .set_read_timeout(Some(read_deadline))
                    .context("setting the reader deadline")?;
                let expected: Vec<(Duration, bool)> =
                    plan.iter().map(|p| (p.offset, p.v1)).collect();

                // Writer half: pace the schedule.  Never reads, never waits
                // on responses — that's what keeps the loop open.
                let writer = scope.spawn(move || -> Result<u64> {
                    let mut stream = stream;
                    let mut sent = 0u64;
                    for p in &plan {
                        let due = start + p.offset;
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        stream
                            .write_all(&p.frame)
                            .context("writing a load frame")?;
                        sent += 1;
                    }
                    Ok(sent)
                });

                // Reader half: responses come back in request order on this
                // connection; latency is measured from the *scheduled* send.
                let mut completed = 0u64;
                let mut errors = 0u64;
                let mut latencies_ns = Vec::with_capacity(expected.len());
                let mut err_latencies_ns = Vec::new();
                let mut last_read_at = None;
                for &(offset, v1) in &expected {
                    let status = if v1 {
                        let mut frame = [0u8; 7];
                        if let Err(e) = reader.read_exact(&mut frame) {
                            bail!("reading a v1 response: {e}");
                        }
                        match frame[0] {
                            MAGIC_RESP => WireStatus::from_u8(frame[2]),
                            MAGIC_ERR => {
                                let st = WireStatus::from_u8(frame[1]);
                                if st == WireStatus::Ok {
                                    WireStatus::Unknown
                                } else {
                                    st
                                }
                            }
                            m => bail!("bad response magic {m:#x} mid-stream"),
                        }
                    } else {
                        match read_response_v2(&mut reader) {
                            Ok(resp) => resp.status,
                            Err(e) => bail!("reading a v2 response: {e}"),
                        }
                    };
                    last_read_at = Some(Instant::now());
                    let lat = Instant::now().saturating_duration_since(start + offset);
                    let lat_ns = lat.as_nanos().min(u64::MAX as u128) as u64;
                    if status == WireStatus::Ok {
                        completed += 1;
                        latencies_ns.push(lat_ns);
                    } else {
                        errors += 1;
                        err_latencies_ns.push(lat_ns);
                    }
                }
                let sent = match writer.join() {
                    Ok(r) => r.context("load writer failed")?,
                    Err(_) => bail!("load writer panicked"),
                };
                Ok(ConnOutcome {
                    sent,
                    completed,
                    errors,
                    latencies_ns,
                    err_latencies_ns,
                    last_read_at,
                })
            }));
        }
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("load connection thread panicked")),
            })
            .collect()
    });

    let mut sent = 0u64;
    let mut completed = 0u64;
    let mut errors = 0u64;
    let mut latencies_us: Vec<f64> = Vec::new();
    let mut err_latencies_us: Vec<f64> = Vec::new();
    let mut last_read_at: Option<Instant> = None;
    for outcome in outcomes {
        let o = outcome?;
        sent += o.sent;
        completed += o.completed;
        errors += o.errors;
        latencies_us.extend(o.latencies_ns.iter().map(|&ns| ns as f64 / 1000.0));
        err_latencies_us.extend(o.err_latencies_ns.iter().map(|&ns| ns as f64 / 1000.0));
        last_read_at = match (last_read_at, o.last_read_at) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    let wall = last_read_at
        .map(|t| t.saturating_duration_since(start))
        .unwrap_or(cfg.duration)
        .max(Duration::from_millis(1));
    latencies_us.sort_by(f64::total_cmp);
    err_latencies_us.sort_by(f64::total_cmp);
    let pct = |sorted: &[f64], p: f64| -> f64 {
        if sorted.is_empty() {
            0.0
        } else {
            percentile_sorted(sorted, p)
        }
    };
    Ok(LoadReport {
        offered_ips: cfg.rate,
        sent,
        completed,
        errors,
        achieved_ips: completed as f64 / wall.as_secs_f64(),
        p50_us: pct(&latencies_us, 50.0),
        p99_us: pct(&latencies_us, 99.0),
        p999_us: pct(&latencies_us, 99.9),
        max_us: latencies_us.last().copied().unwrap_or(0.0),
        err_p50_us: pct(&err_latencies_us, 50.0),
        err_p99_us: pct(&err_latencies_us, 99.0),
        err_max_us: err_latencies_us.last().copied().unwrap_or(0.0),
        wall,
    })
}
