//! Pluggable inference backends.
//!
//! All three implement the same batch contract and are asserted
//! prediction-equivalent in the integration suite — the coordinator can
//! route to any of them interchangeably:
//!
//! * [`NativeBackend`] — the bit-packed Rust hot path (lowest latency),
//!   with six kernel schedules selected by [`Kernel`];
//! * [`PjrtBackend`] — the AOT-compiled JAX/Pallas artifacts via PJRT
//!   (the paper's "CPU" platform in Table 5);
//! * [`SimBackend`] — the cycle-accurate FPGA simulator (the paper's
//!   hardware platform; also reports simulated-hardware latency).
//!
//! ## Flat-logits contract (DESIGN.md §Flat logits)
//!
//! `infer_batch` writes into a **caller-owned** [`LogitsBuf`] (one flat
//! `i32` arena, `images.len()` rows × `n_classes` stride) and reuses a
//! caller-owned [`InferScratch`], instead of returning `Vec<Vec<i32>>`.
//! Workers own one scratch + one logits arena each (`coordinator::pool`),
//! so the steady-state batch path performs no per-request allocation and
//! backends stay shareable behind `&self`.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::bnn::packing::Packed;
use crate::bnn::{
    argmax_i32, BnnModel, PreparedModel, DEFAULT_BLOCK_ROWS, DEFAULT_RING_CAP, DEFAULT_TILE_IMGS,
};
use crate::runtime::Engine;
use crate::sim::{Accelerator, SimConfig};

/// Kernel schedule for [`NativeBackend`].  All tiers are bit-identical
/// (asserted in `bnn::model` tests and the golden-vector + differential
/// conformance suites in `rust/tests/kernel_conformance.rs`, which
/// enumerate [`Kernel::registry`]); they differ only in how compute is
/// scheduled over the weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// One neuron per pass over the input — the semantics reference.
    Scalar,
    /// `block_rows` neurons per pass over one image
    /// ([`BnnModel::logits_into_blocked`]).
    Blocked {
        /// Rows per pass, ≥ 1 (see [`DEFAULT_BLOCK_ROWS`]).
        block_rows: usize,
    },
    /// Weight-stationary batch tile: each `block_rows` weight block is
    /// loaded once per `tile_imgs`-image tile
    /// ([`BnnModel::logits_batch_into_tiled`]) — the serving default.
    Tiled {
        /// Rows per pass, ≥ 1.
        block_rows: usize,
        /// Images per tile, ≥ 1 (see [`DEFAULT_TILE_IMGS`]).
        tile_imgs: usize,
    },
    /// Explicitly vectorized tile: the tiled schedule with every
    /// pre-activation tile computed on AVX2/NEON vectors when the host
    /// supports them ([`BnnModel::logits_batch_into_simd`]; runtime
    /// dispatch via [`crate::bnn::simd_level`], portable fallback to the
    /// tiled kernel elsewhere or under `BNN_FORCE_SCALAR=1`).
    Simd {
        /// Rows per pass, ≥ 1.
        block_rows: usize,
        /// Images per tile, ≥ 1.
        tile_imgs: usize,
    },
    /// Fused threshold-pack: popcount → threshold-compare → activation
    /// bit-pack in registers, one packed `u64` written per (image, 64-row
    /// panel) of every hidden layer — the hidden-layer `i32` tile arena
    /// and its repack pass disappear
    /// ([`PreparedModel::logits_batch_into`]).  Runs on engine-prepared
    /// panel weights built once at construction
    /// ([`NativeBackend::with_kernel`] → [`PreparedModel::new`]), with the
    /// same [`crate::bnn::simd_level`] runtime dispatch as the simd tier.
    /// No `block_rows` knob: the panel width is fixed at
    /// [`crate::bnn::PANEL_ROWS`] (64) rows = one activation word.
    Fused {
        /// Images per tile, ≥ 1.
        tile_imgs: usize,
    },
    /// Streaming layer-pipelined dataflow: one stage worker thread per
    /// hidden layer (output stage on the calling thread), chained by
    /// fixed-capacity SPSC rings of packed `u64` activation words — the
    /// software analogue of the paper's layer-parallel Verilog datapath
    /// and the FINN/Fraser et al. dataflow architectures
    /// ([`PreparedModel::logits_batch_pipelined`]).  Runs on the same
    /// engine-prepared panel weights as the fused tier, so throughput
    /// scales with cores × layers on a *single* batch where the fused
    /// split only scales with batch size.  No `block_rows`/`tile_imgs`
    /// knobs: images stream one at a time, panel width is fixed at 64.
    Pipelined {
        /// In-flight images buffered per inter-stage ring, ≥ 1 (see
        /// [`crate::bnn::DEFAULT_RING_CAP`]; capacity 1 runs the stages
        /// hand-over-hand, larger caps absorb per-layer compute jitter).
        ring_cap: usize,
    },
}

impl Default for Kernel {
    fn default() -> Self {
        Kernel::Tiled {
            block_rows: DEFAULT_BLOCK_ROWS,
            tile_imgs: DEFAULT_TILE_IMGS,
        }
    }
}

impl Kernel {
    /// Short human-readable name (metrics/tables).
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Blocked { .. } => "blocked",
            Kernel::Tiled { .. } => "tiled",
            Kernel::Simd { .. } => "simd",
            Kernel::Fused { .. } => "fused",
            Kernel::Pipelined { .. } => "pipelined",
        }
    }

    /// Reject a degenerate shape (both knobs must be ≥ 1).
    pub fn validate(&self) -> Result<()> {
        match *self {
            Kernel::Scalar => {}
            Kernel::Blocked { block_rows } => {
                anyhow::ensure!(block_rows >= 1, "block_rows must be ≥ 1");
            }
            Kernel::Tiled {
                block_rows,
                tile_imgs,
            }
            | Kernel::Simd {
                block_rows,
                tile_imgs,
            } => {
                anyhow::ensure!(block_rows >= 1, "block_rows must be ≥ 1");
                anyhow::ensure!(tile_imgs >= 1, "tile_imgs must be ≥ 1");
            }
            Kernel::Fused { tile_imgs } => {
                anyhow::ensure!(tile_imgs >= 1, "tile_imgs must be ≥ 1");
            }
            Kernel::Pipelined { ring_cap } => {
                anyhow::ensure!(ring_cap >= 1, "ring_cap must be ≥ 1");
            }
        }
        Ok(())
    }

    /// Panicking [`Self::validate`] (construction-time assertion).
    pub fn assert_valid(&self) {
        if let Err(e) = self.validate() {
            panic!("{e}");
        }
    }

    /// The same tier reshaped to new `block_rows`/`tile_imgs` knobs
    /// (`Scalar` has no shape; `Blocked` ignores `tile_imgs`; `Fused`
    /// ignores `block_rows` — its panel width is fixed at 64 rows;
    /// `Pipelined` has neither knob and keeps its `ring_cap`, which
    /// [`Self::with_ring_cap`] re-shapes instead).  This is how CLI flags
    /// re-shape a config-file kernel without re-parsing its name.
    pub fn with_shape(self, block_rows: usize, tile_imgs: usize) -> Kernel {
        match self {
            Kernel::Scalar => Kernel::Scalar,
            Kernel::Blocked { .. } => Kernel::Blocked { block_rows },
            Kernel::Tiled { .. } => Kernel::Tiled {
                block_rows,
                tile_imgs,
            },
            Kernel::Simd { .. } => Kernel::Simd {
                block_rows,
                tile_imgs,
            },
            Kernel::Fused { .. } => Kernel::Fused { tile_imgs },
            Kernel::Pipelined { ring_cap } => Kernel::Pipelined { ring_cap },
        }
    }

    /// The same tier re-shaped to a new inter-stage ring capacity — only
    /// the pipelined tier has one; every other tier passes through
    /// unchanged.  The `[coordinator] ring_cap` / `--ring-cap` plumbing
    /// applies this after [`Self::parse`]/[`Self::with_shape`], mirroring
    /// how `block_rows`/`tile_imgs` reach the other tiers.
    pub fn with_ring_cap(self, ring_cap: usize) -> Kernel {
        match self {
            Kernel::Pipelined { .. } => Kernel::Pipelined { ring_cap },
            other => other,
        }
    }

    /// Parse a kernel name (`scalar|blocked|tiled|simd|fused|pipelined` —
    /// the config/CLI vocabulary) with explicit shape knobs.  `pipelined`
    /// starts at [`DEFAULT_RING_CAP`]; apply [`Self::with_ring_cap`] to
    /// override.
    pub fn parse(name: &str, block_rows: usize, tile_imgs: usize) -> Result<Kernel> {
        Ok(match name {
            "scalar" => Kernel::Scalar,
            "blocked" => Kernel::Blocked { block_rows },
            "tiled" => Kernel::Tiled {
                block_rows,
                tile_imgs,
            },
            "simd" => Kernel::Simd {
                block_rows,
                tile_imgs,
            },
            "fused" => Kernel::Fused { tile_imgs },
            "pipelined" => Kernel::Pipelined {
                ring_cap: DEFAULT_RING_CAP,
            },
            other => {
                anyhow::bail!(
                    "kernel must be scalar|blocked|tiled|simd|fused|pipelined, got '{other}'"
                )
            }
        })
    }

    /// **The kernel registry**: every tier, at the given shape knobs.
    ///
    /// Conformance suites (`rust/tests/kernel_conformance.rs`, the
    /// golden-vector test, the pool equality tests) enumerate kernels from
    /// here instead of hand-listing variants, so a future tier added to
    /// the enum is automatically pinned bit-identical to the scalar
    /// reference and the FPGA simulator.  The `const` guard below makes
    /// forgetting to extend this registry a compile error: a new enum
    /// variant leaves its match non-exhaustive, and the fix-up lands next
    /// to the list that must grow with it.
    pub fn registry_with(block_rows: usize, tile_imgs: usize) -> Vec<Kernel> {
        // every variant must appear here AND in the vec below — a new enum
        // variant fails this match (and every dispatch match in this file)
        // at compile time, so a missing dispatch arm is a build error, not
        // a silently unexercised tier
        const _: fn(Kernel) = |k| match k {
            Kernel::Scalar
            | Kernel::Blocked { .. }
            | Kernel::Tiled { .. }
            | Kernel::Simd { .. }
            | Kernel::Fused { .. }
            | Kernel::Pipelined { .. } => {}
        };
        vec![
            Kernel::Scalar,
            Kernel::Blocked { block_rows },
            Kernel::Tiled {
                block_rows,
                tile_imgs,
            },
            Kernel::Simd {
                block_rows,
                tile_imgs,
            },
            Kernel::Fused { tile_imgs },
            Kernel::Pipelined {
                ring_cap: DEFAULT_RING_CAP,
            },
        ]
    }

    /// [`Self::registry_with`] at the default shape knobs.
    pub fn registry() -> Vec<Kernel> {
        Self::registry_with(DEFAULT_BLOCK_ROWS, DEFAULT_TILE_IMGS)
    }
}

/// Caller-owned flat logits arena: `rows × stride` `i32`, row-major.
///
/// Ownership convention: the **caller** (worker thread, bench loop, test)
/// owns the buffer and hands it to [`InferBackend::infer_batch`], which
/// resets it to `images.len()` rows and fills every row.  Rows are valid
/// until the next `infer_batch` call with the same buffer; capacity is
/// retained across calls, so steady-state reuse allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct LogitsBuf {
    data: Vec<i32>,
    rows: usize,
    stride: usize,
}

impl LogitsBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize to `rows × stride` and zero-fill (no allocation once the
    /// high-water capacity is reached).
    pub fn reset(&mut self, rows: usize, stride: usize) {
        assert!(stride >= 1, "class stride must be ≥ 1");
        self.rows = rows;
        self.stride = stride;
        self.data.clear();
        self.data.resize(rows * stride, 0);
    }

    /// Number of logits rows (= images in the last batch).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Classes per row.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Logits of image `i`.
    pub fn row(&self, i: usize) -> &[i32] {
        &self.data[i * self.stride..(i + 1) * self.stride]
    }

    /// Mutable logits of image `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [i32] {
        &mut self.data[i * self.stride..(i + 1) * self.stride]
    }

    /// The whole arena, row-major (`rows × stride`).
    pub fn flat(&self) -> &[i32] {
        &self.data
    }

    /// Mutable whole arena (kernel writers).
    pub fn flat_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    /// Iterate rows in image order.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[i32]> {
        self.data.chunks_exact(self.stride.max(1))
    }

    /// Copy out as one `Vec` per image (tests/tools — allocates).
    pub fn to_vecs(&self) -> Vec<Vec<i32>> {
        self.iter_rows().map(|r| r.to_vec()).collect()
    }
}

/// Caller-owned, backend-agnostic scratch reused across `infer_batch`
/// calls (one per worker thread).  Keeping it outside the backend lets
/// backends stay `&self`-shareable across workers while the hot path
/// stays allocation-free after warmup.
#[derive(Clone, Debug, Default)]
pub struct InferScratch {
    /// Native: forward-pass arenas (activations + pre-activation tiles).
    model: crate::bnn::model::Scratch,
    /// Native tiled path: flat packed-input arena (`batch × input_words`).
    input: Vec<u64>,
    /// PJRT: u32 staging arena for the fixed-shape artifact input.
    staging: Vec<u32>,
}

/// A batch inference engine: packed images in, integer logits out.
pub trait InferBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Largest batch the backend can execute in one call.
    fn max_batch(&self) -> usize;

    /// Exact input width (bits) this backend accepts, when it knows it.
    /// Serving engines reject mismatched images **at submit time** so one
    /// bad request can never fail a whole co-scheduled batch; `None`
    /// defers the check to `infer_batch` (which must then error cleanly).
    fn expected_bits(&self) -> Option<usize> {
        None
    }

    /// Classify a batch into the caller-owned `out` arena
    /// (`images.len()` rows × `n_classes` stride), reusing `scratch`.
    fn infer_batch(
        &self,
        images: &[&Packed],
        scratch: &mut InferScratch,
        out: &mut LogitsBuf,
    ) -> Result<()>;

    /// Allocating convenience (tests/tools): one logits `Vec` per image.
    fn infer_logits(&self, images: &[Packed]) -> Result<Vec<Vec<i32>>> {
        let refs: Vec<&Packed> = images.iter().collect();
        let mut scratch = InferScratch::default();
        let mut out = LogitsBuf::new();
        self.infer_batch(&refs, &mut scratch, &mut out)?;
        Ok(out.to_vecs())
    }

    /// Allocation-free single-image predict over caller-owned arenas —
    /// the steady-state form of [`Self::predict`] (top-1 straight off the
    /// flat logits row, mirroring `BnnModel::predict_into`).
    fn predict_into(
        &self,
        image: &Packed,
        scratch: &mut InferScratch,
        out: &mut LogitsBuf,
    ) -> Result<u8> {
        self.infer_batch(&[image], scratch, out)?;
        Ok(argmax_i32(out.row(0)) as u8)
    }

    /// Convenience single-image predict (allocates fresh arenas; loops
    /// should hold arenas and call [`Self::predict_into`]).
    fn predict(&self, image: &Packed) -> Result<u8> {
        let mut scratch = InferScratch::default();
        let mut out = LogitsBuf::new();
        self.predict_into(image, &mut scratch, &mut out)
    }
}

// ---------------------------------------------------------------------------

/// Native bit-packed software BNN with a selectable [`Kernel`] schedule.
pub struct NativeBackend {
    model: BnnModel,
    kernel: Kernel,
    /// Fused panel layout, built once at construction when the kernel is
    /// [`Kernel::Fused`] or [`Kernel::Pipelined`] (both walk the panels) —
    /// `Engine::build()` pays the re-layout cost, the request path never
    /// does.  Each pool replica owns its copy, keeping the worker's hot
    /// loop on core-local weights.
    prepared: Option<PreparedModel>,
}

impl NativeBackend {
    /// Scalar-kernel backend (the semantics reference).
    pub fn new(model: BnnModel) -> Self {
        Self::with_kernel(model, Kernel::Scalar)
    }

    /// Blocked-kernel backend; `block_rows` ≥ 1
    /// (see [`crate::bnn::DEFAULT_BLOCK_ROWS`]).
    pub fn with_block_rows(model: BnnModel, block_rows: usize) -> Self {
        Self::with_kernel(model, Kernel::Blocked { block_rows })
    }

    /// Backend with an explicit kernel schedule.  For [`Kernel::Fused`]
    /// and [`Kernel::Pipelined`] this is where the panel weights are
    /// prepared (construction happens inside `Engine::build()` on the
    /// serving path) — a model the panel layout cannot represent (invalid
    /// layer chaining) panics here, at build time, exactly like an
    /// invalid kernel shape.
    pub fn with_kernel(model: BnnModel, kernel: Kernel) -> Self {
        kernel.assert_valid();
        let prepared = matches!(kernel, Kernel::Fused { .. } | Kernel::Pipelined { .. }).then(|| {
            PreparedModel::new(&model).expect("panel kernels need a valid hidden/output model")
        });
        Self {
            model,
            kernel,
            prepared,
        }
    }

    pub fn model(&self) -> &BnnModel {
        &self.model
    }

    /// The configured kernel schedule.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The engine-prepared fused panel layout (`Some` iff the kernel is
    /// [`Kernel::Fused`] or [`Kernel::Pipelined`] — both walk the panel
    /// weights).
    pub fn prepared(&self) -> Option<&PreparedModel> {
        self.prepared.as_ref()
    }
}

impl InferBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }

    fn expected_bits(&self) -> Option<usize> {
        Some(self.model.n_in())
    }

    fn infer_batch(
        &self,
        images: &[&Packed],
        scratch: &mut InferScratch,
        out: &mut LogitsBuf,
    ) -> Result<()> {
        // Reject size-mismatched images with an Err (the batch executor's
        // designed failure path: submitters observe a disconnected reply
        // channel) — a panic here would instead kill the worker thread and
        // strand everything queued on its shard.
        let n_in = self.model.n_in();
        for img in images {
            anyhow::ensure!(
                img.n_bits == n_in,
                "image has {} bits, model expects {n_in}",
                img.n_bits
            );
        }
        let nc = self.model.n_classes();
        out.reset(images.len(), nc);
        match self.kernel {
            Kernel::Tiled {
                block_rows,
                tile_imgs,
            }
            | Kernel::Simd {
                block_rows,
                tile_imgs,
            } => {
                // gather the packed inputs into the flat arena, then one
                // weight-stationary pass over the whole batch; the two
                // tiers share the walk and differ only in the tile kernel
                scratch.input.clear();
                for img in images {
                    scratch.input.extend_from_slice(&img.words);
                }
                if matches!(self.kernel, Kernel::Simd { .. }) {
                    self.model.logits_batch_into_simd(
                        &scratch.input,
                        images.len(),
                        &mut scratch.model,
                        out.flat_mut(),
                        block_rows,
                        tile_imgs,
                    );
                } else {
                    self.model.logits_batch_into_tiled(
                        &scratch.input,
                        images.len(),
                        &mut scratch.model,
                        out.flat_mut(),
                        block_rows,
                        tile_imgs,
                    );
                }
            }
            Kernel::Fused { tile_imgs } => {
                // same flat-arena gather as the tiled tiers, then the
                // fused threshold-pack walk over the panels prepared at
                // construction — hidden-layer sums never touch memory
                scratch.input.clear();
                for img in images {
                    scratch.input.extend_from_slice(&img.words);
                }
                self.prepared
                    .as_ref()
                    .expect("fused panels are prepared with the kernel at construction")
                    .logits_batch_into(
                        &scratch.input,
                        images.len(),
                        &mut scratch.model,
                        out.flat_mut(),
                        tile_imgs,
                    );
            }
            Kernel::Pipelined { ring_cap } => {
                // same flat-arena gather, then the streaming dataflow
                // walk: one stage thread per hidden layer over the same
                // engine-prepared panels, output stage on this thread
                scratch.input.clear();
                for img in images {
                    scratch.input.extend_from_slice(&img.words);
                }
                self.prepared
                    .as_ref()
                    .expect("pipelined stages are prepared with the kernel at construction")
                    .logits_batch_pipelined(
                        &scratch.input,
                        images.len(),
                        out.flat_mut(),
                        ring_cap,
                    );
            }
            Kernel::Blocked { block_rows } => {
                for (i, img) in images.iter().enumerate() {
                    self.model.logits_into_blocked(
                        &img.words,
                        &mut scratch.model,
                        out.row_mut(i),
                        block_rows,
                    );
                }
            }
            Kernel::Scalar => {
                for (i, img) in images.iter().enumerate() {
                    self.model
                        .logits_into(&img.words, &mut scratch.model, out.row_mut(i));
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// PJRT backend over the AOT artifact ladder: picks the smallest compiled
/// batch ≥ the request batch and zero-pads (padding rows are discarded).
pub struct PjrtBackend {
    engine: Arc<Engine>,
    ladder: Vec<usize>,
    input_words: usize,
    n_classes: usize,
}

impl PjrtBackend {
    pub fn new(engine: Arc<Engine>) -> Result<Self> {
        let ladder = engine.manifest.batch_ladder("bnn");
        anyhow::ensure!(!ladder.is_empty(), "no bnn artifacts in manifest");
        let name = engine
            .manifest
            .name_for("bnn", ladder[0])
            .expect("ladder entry")
            .to_string();
        let spec = engine.manifest.get(&name)?.clone();
        Ok(Self {
            input_words: spec.input.shape[1],
            n_classes: spec.output.shape[1],
            engine,
            ladder,
        })
    }

    /// Smallest compiled batch ≥ n (or the max available).
    pub fn pick_batch(&self, n: usize) -> usize {
        *self
            .ladder
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or(self.ladder.last().unwrap())
    }
}

impl InferBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        *self.ladder.last().unwrap()
    }

    fn infer_batch(
        &self,
        images: &[&Packed],
        scratch: &mut InferScratch,
        out: &mut LogitsBuf,
    ) -> Result<()> {
        let nc = self.n_classes;
        out.reset(images.len(), nc);
        let mut start = 0;
        while start < images.len() {
            let remaining = images.len() - start;
            let exec_batch = self.pick_batch(remaining);
            let chunk = remaining.min(exec_batch);
            // stage + zero-pad to the artifact's fixed shape (arena reused)
            scratch.staging.clear();
            scratch.staging.resize(exec_batch * self.input_words, 0);
            for (i, img) in images[start..start + chunk].iter().enumerate() {
                crate::bnn::packing::u64_words_to_u32_into(
                    &img.words,
                    img.n_bits,
                    &mut scratch.staging[i * self.input_words..(i + 1) * self.input_words],
                );
            }
            let name = self
                .engine
                .manifest
                .name_for("bnn", exec_batch)
                .expect("ladder batch has artifact")
                .to_string();
            // padded rows beyond `chunk` are computed by the artifact but
            // never copied out
            self.engine.run_u32_to_i32_into(
                &name,
                &scratch.staging,
                &mut out.flat_mut()[start * nc..(start + chunk) * nc],
            )?;
            start += chunk;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// FPGA-simulator backend: single-image hardware, batches run sequentially
/// (exactly what the physical accelerator would do).
pub struct SimBackend {
    acc: Mutex<Accelerator>,
    n_in: usize,
    n_classes: usize,
    /// Simulated-hardware nanoseconds accumulated (distinct from wall time).
    pub simulated_ns: Mutex<f64>,
}

impl SimBackend {
    pub fn new(model: &BnnModel, cfg: SimConfig) -> Result<Self> {
        Ok(Self {
            acc: Mutex::new(Accelerator::new(model, cfg)?),
            n_in: model.n_in(),
            n_classes: model.n_classes(),
            simulated_ns: Mutex::new(0.0),
        })
    }
}

impl InferBackend for SimBackend {
    fn name(&self) -> &'static str {
        "fpga-sim"
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn expected_bits(&self) -> Option<usize> {
        Some(self.n_in)
    }

    fn infer_batch(
        &self,
        images: &[&Packed],
        _scratch: &mut InferScratch,
        out: &mut LogitsBuf,
    ) -> Result<()> {
        out.reset(images.len(), self.n_classes);
        let mut acc = self.acc.lock().unwrap();
        let mut sim_ns = 0.0;
        for (i, img) in images.iter().enumerate() {
            let r = acc.run_image(img);
            sim_ns += r.latency_ns;
            out.row_mut(i).copy_from_slice(&r.scores);
        }
        *self.simulated_ns.lock().unwrap() += sim_ns;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::model_from_sign_rows;
    use crate::bnn::packing::pack_bits_u64;
    use crate::sim::MemStyle;
    use crate::util::prng::Xoshiro256;

    fn tiny_model(seed: u64) -> BnnModel {
        let mut rng = Xoshiro256::new(seed);
        let dims = [784usize, 128, 64, 10];
        let mut spec = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let rows: Vec<Vec<i8>> = (0..w[1])
                .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            let thr = (li + 2 < dims.len()).then(|| vec![0i32; w[1]]);
            spec.push((rows, thr));
        }
        model_from_sign_rows(spec).unwrap()
    }

    fn images(n: usize, seed: u64) -> Vec<Packed> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                Packed {
                    words: pack_bits_u64(&bits),
                    n_bits: 784,
                }
            })
            .collect()
    }

    #[test]
    fn native_and_sim_agree() {
        let model = tiny_model(11);
        let native = NativeBackend::new(model.clone());
        let sim = SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
        let imgs = images(5, 12);
        let a = native.infer_logits(&imgs).unwrap();
        let b = sim.infer_logits(&imgs).unwrap();
        assert_eq!(a, b);
        assert!(*sim.simulated_ns.lock().unwrap() > 0.0);
    }

    #[test]
    fn all_native_kernels_agree() {
        // every registered tier (plus the default) against the scalar
        // reference — the registry is the single source of truth, so a new
        // tier is pinned here automatically
        let model = tiny_model(15);
        let imgs = images(9, 16);
        let scalar = NativeBackend::new(model.clone()).infer_logits(&imgs).unwrap();
        let mut kernels = Kernel::registry_with(16, 4);
        kernels.push(Kernel::default());
        for kernel in kernels {
            let b = NativeBackend::with_kernel(model.clone(), kernel);
            assert_eq!(b.infer_logits(&imgs).unwrap(), scalar, "{kernel:?}");
        }
    }

    #[test]
    fn registry_covers_every_kernel_tier() {
        // one entry per enum variant, with distinct names — the
        // conformance suites rely on this being exhaustive
        let reg = Kernel::registry();
        assert_eq!(reg.len(), 6);
        let names: Vec<&str> = reg.iter().map(|k| k.name()).collect();
        for want in ["scalar", "blocked", "tiled", "simd", "fused", "pipelined"] {
            assert!(names.contains(&want), "registry missing {want}: {names:?}");
        }
        // parse() round-trips the registry's vocabulary
        for k in &reg {
            let parsed = Kernel::parse(k.name(), 16, 4).unwrap();
            assert_eq!(parsed.name(), k.name());
        }
        assert!(Kernel::parse("gpu", 16, 4).is_err());
    }

    #[test]
    fn with_shape_reshapes_without_changing_the_tier() {
        for k in Kernel::registry_with(16, 4) {
            let r = k.with_shape(32, 8);
            assert_eq!(r.name(), k.name());
            r.validate().unwrap();
            match r {
                Kernel::Scalar => {}
                Kernel::Blocked { block_rows } => assert_eq!(block_rows, 32),
                Kernel::Tiled {
                    block_rows,
                    tile_imgs,
                }
                | Kernel::Simd {
                    block_rows,
                    tile_imgs,
                } => {
                    assert_eq!((block_rows, tile_imgs), (32, 8));
                }
                Kernel::Fused { tile_imgs } => assert_eq!(tile_imgs, 8),
                // no block_rows/tile_imgs knobs: with_shape keeps the
                // ring untouched, with_ring_cap re-shapes it instead
                Kernel::Pipelined { ring_cap } => assert_eq!(ring_cap, DEFAULT_RING_CAP),
            }
        }
        assert!(Kernel::Blocked { block_rows: 0 }.validate().is_err());
        assert!(Kernel::Tiled { block_rows: 4, tile_imgs: 0 }.validate().is_err());
        assert!(Kernel::Fused { tile_imgs: 0 }.validate().is_err());
        assert!(Kernel::Pipelined { ring_cap: 0 }.validate().is_err());
    }

    #[test]
    fn with_ring_cap_only_reshapes_the_pipelined_tier() {
        for k in Kernel::registry_with(16, 4) {
            let r = k.with_ring_cap(5);
            assert_eq!(r.name(), k.name());
            match r {
                Kernel::Pipelined { ring_cap } => assert_eq!(ring_cap, 5),
                other => assert_eq!(other, k, "non-pipelined tiers pass through"),
            }
        }
    }

    #[test]
    fn fused_backend_prepares_panels_at_construction() {
        // the fused tier carries its engine-prepared layout; every other
        // tier does not pay for it
        let model = tiny_model(21);
        let fused = NativeBackend::with_kernel(model.clone(), Kernel::Fused { tile_imgs: 4 });
        let prepared = fused.prepared().expect("fused backend owns prepared panels");
        assert_eq!(prepared.n_in(), model.n_in());
        assert_eq!(prepared.n_classes(), model.n_classes());
        assert!(NativeBackend::new(model.clone()).prepared().is_none());
        // ...and serves through them bit-identically to the scalar path
        let imgs = images(7, 22);
        assert_eq!(
            fused.infer_logits(&imgs).unwrap(),
            NativeBackend::new(model).infer_logits(&imgs).unwrap()
        );
    }

    #[test]
    fn pipelined_backend_prepares_stages_at_construction() {
        // the pipelined tier shares the fused tier's engine-prepared
        // panel layout and serves through it bit-identically
        let model = tiny_model(23);
        let piped = NativeBackend::with_kernel(model.clone(), Kernel::Pipelined { ring_cap: 2 });
        assert!(piped.prepared().is_some(), "pipelined backend owns prepared stages");
        let imgs = images(6, 24);
        assert_eq!(
            piped.infer_logits(&imgs).unwrap(),
            NativeBackend::new(model).infer_logits(&imgs).unwrap()
        );
    }

    #[test]
    fn logits_buf_is_reused_without_reallocation() {
        let model = tiny_model(17);
        let backend = NativeBackend::with_kernel(model, Kernel::default());
        let mut scratch = InferScratch::default();
        let mut out = LogitsBuf::new();
        let warm = images(8, 18);
        let refs: Vec<&Packed> = warm.iter().collect();
        backend.infer_batch(&refs, &mut scratch, &mut out).unwrap();
        assert_eq!(out.rows(), 8);
        assert_eq!(out.stride(), 10);
        let cap = out.flat().len();
        // a smaller follow-up batch must not grow the arena
        let small = images(3, 19);
        let refs: Vec<&Packed> = small.iter().collect();
        backend.infer_batch(&refs, &mut scratch, &mut out).unwrap();
        assert_eq!(out.rows(), 3);
        assert!(out.flat().len() <= cap);
        for (img, row) in small.iter().zip(out.iter_rows()) {
            assert_eq!(row, backend.model().logits(&img.words), "row mismatch");
        }
    }

    #[test]
    fn predict_is_argmax_of_batch1() {
        let model = tiny_model(13);
        let native = NativeBackend::new(model.clone());
        let imgs = images(1, 14);
        let logits = native.infer_logits(&imgs).unwrap();
        assert_eq!(
            native.predict(&imgs[0]).unwrap() as usize,
            argmax_i32(&logits[0])
        );
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let model = tiny_model(20);
        let backend = NativeBackend::with_kernel(model, Kernel::default());
        let mut scratch = InferScratch::default();
        let mut out = LogitsBuf::new();
        backend.infer_batch(&[], &mut scratch, &mut out).unwrap();
        assert_eq!(out.rows(), 0);
        assert_eq!(out.iter_rows().count(), 0);
    }
}
