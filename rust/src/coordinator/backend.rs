//! Pluggable inference backends.
//!
//! All three implement the same batch contract and are asserted
//! prediction-equivalent in the integration suite — the coordinator can
//! route to any of them interchangeably:
//!
//! * [`NativeBackend`] — the bit-packed Rust hot path (lowest latency);
//! * [`PjrtBackend`] — the AOT-compiled JAX/Pallas artifacts via PJRT
//!   (the paper's "CPU" platform in Table 5);
//! * [`SimBackend`] — the cycle-accurate FPGA simulator (the paper's
//!   hardware platform; also reports simulated-hardware latency).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::bnn::packing::Packed;
use crate::bnn::{argmax_i32, BnnModel};
use crate::runtime::Engine;
use crate::sim::{Accelerator, SimConfig};

/// A batch inference engine: packed images in, integer logits out.
pub trait InferBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Largest batch the backend can execute in one call.
    fn max_batch(&self) -> usize;

    /// Classify a batch; returns one logits vector per input.
    fn infer_batch(&self, images: &[Packed]) -> Result<Vec<Vec<i32>>>;

    /// Convenience single-image predict.
    fn predict(&self, image: &Packed) -> Result<u8> {
        let logits = self.infer_batch(std::slice::from_ref(image))?;
        Ok(argmax_i32(&logits[0]) as u8)
    }
}

// ---------------------------------------------------------------------------

/// Native bit-packed software BNN.
///
/// Two kernel schedules, both bit-identical (asserted in `bnn::model`
/// tests and `rust/tests/integration.rs`):
/// * scalar — one neuron per pass over the input ([`BnnModel::logits_into`]),
///   the semantics reference;
/// * blocked — `block_rows` neurons per pass
///   ([`BnnModel::logits_into_blocked`]), the serving default.
pub struct NativeBackend {
    model: BnnModel,
    /// `Some(b)` → blocked kernel with `b` rows per pass; `None` → scalar.
    block_rows: Option<usize>,
}

impl NativeBackend {
    /// Scalar-kernel backend (the semantics reference).
    pub fn new(model: BnnModel) -> Self {
        Self {
            model,
            block_rows: None,
        }
    }

    /// Blocked-kernel backend; `block_rows` ≥ 1
    /// (see [`crate::bnn::DEFAULT_BLOCK_ROWS`]).
    pub fn with_block_rows(model: BnnModel, block_rows: usize) -> Self {
        assert!(block_rows >= 1, "block_rows must be ≥ 1");
        Self {
            model,
            block_rows: Some(block_rows),
        }
    }

    pub fn model(&self) -> &BnnModel {
        &self.model
    }

    /// The configured block size (`None` = scalar path).
    pub fn block_rows(&self) -> Option<usize> {
        self.block_rows
    }
}

impl InferBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }

    fn infer_batch(&self, images: &[Packed]) -> Result<Vec<Vec<i32>>> {
        let mut scratch = crate::bnn::model::Scratch::default();
        let nc = self.model.n_classes();
        let mut out = Vec::with_capacity(images.len());
        for img in images {
            let mut logits = vec![0i32; nc];
            match self.block_rows {
                Some(b) => self
                    .model
                    .logits_into_blocked(&img.words, &mut scratch, &mut logits, b),
                None => self.model.logits_into(&img.words, &mut scratch, &mut logits),
            }
            out.push(logits);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------

/// PJRT backend over the AOT artifact ladder: picks the smallest compiled
/// batch ≥ the request batch and zero-pads (padding rows are discarded).
pub struct PjrtBackend {
    engine: Arc<Engine>,
    ladder: Vec<usize>,
    input_words: usize,
    n_classes: usize,
}

impl PjrtBackend {
    pub fn new(engine: Arc<Engine>) -> Result<Self> {
        let ladder = engine.manifest.batch_ladder("bnn");
        anyhow::ensure!(!ladder.is_empty(), "no bnn artifacts in manifest");
        let name = engine
            .manifest
            .name_for("bnn", ladder[0])
            .expect("ladder entry")
            .to_string();
        let spec = engine.manifest.get(&name)?.clone();
        Ok(Self {
            input_words: spec.input.shape[1],
            n_classes: spec.output.shape[1],
            engine,
            ladder,
        })
    }

    /// Smallest compiled batch ≥ n (or the max available).
    pub fn pick_batch(&self, n: usize) -> usize {
        *self
            .ladder
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or(self.ladder.last().unwrap())
    }
}

impl InferBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        *self.ladder.last().unwrap()
    }

    fn infer_batch(&self, images: &[Packed]) -> Result<Vec<Vec<i32>>> {
        let mut out = Vec::with_capacity(images.len());
        let mut start = 0;
        while start < images.len() {
            let remaining = images.len() - start;
            let exec_batch = self.pick_batch(remaining);
            let chunk = remaining.min(exec_batch);
            // flatten + zero-pad to the artifact's fixed shape
            let mut input = vec![0u32; exec_batch * self.input_words];
            for (i, img) in images[start..start + chunk].iter().enumerate() {
                let w32 = img.to_u32_words();
                input[i * self.input_words..i * self.input_words + w32.len()]
                    .copy_from_slice(&w32);
            }
            let name = self
                .engine
                .manifest
                .name_for("bnn", exec_batch)
                .expect("ladder batch has artifact")
                .to_string();
            let logits = self.engine.run_u32_to_i32(&name, &input)?;
            for i in 0..chunk {
                out.push(logits[i * self.n_classes..(i + 1) * self.n_classes].to_vec());
            }
            start += chunk;
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------

/// FPGA-simulator backend: single-image hardware, batches run sequentially
/// (exactly what the physical accelerator would do).
pub struct SimBackend {
    acc: Mutex<Accelerator>,
    /// Simulated-hardware nanoseconds accumulated (distinct from wall time).
    pub simulated_ns: Mutex<f64>,
}

impl SimBackend {
    pub fn new(model: &BnnModel, cfg: SimConfig) -> Result<Self> {
        Ok(Self {
            acc: Mutex::new(Accelerator::new(model, cfg)?),
            simulated_ns: Mutex::new(0.0),
        })
    }
}

impl InferBackend for SimBackend {
    fn name(&self) -> &'static str {
        "fpga-sim"
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn infer_batch(&self, images: &[Packed]) -> Result<Vec<Vec<i32>>> {
        let mut acc = self.acc.lock().unwrap();
        let mut sim_ns = 0.0;
        let out = images
            .iter()
            .map(|img| {
                let r = acc.run_image(img);
                sim_ns += r.latency_ns;
                r.scores
            })
            .collect();
        *self.simulated_ns.lock().unwrap() += sim_ns;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::model_from_sign_rows;
    use crate::bnn::packing::pack_bits_u64;
    use crate::sim::MemStyle;
    use crate::util::prng::Xoshiro256;

    fn tiny_model(seed: u64) -> BnnModel {
        let mut rng = Xoshiro256::new(seed);
        let dims = [784usize, 128, 64, 10];
        let mut spec = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let rows: Vec<Vec<i8>> = (0..w[1])
                .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            let thr = (li + 2 < dims.len()).then(|| vec![0i32; w[1]]);
            spec.push((rows, thr));
        }
        model_from_sign_rows(spec).unwrap()
    }

    fn images(n: usize, seed: u64) -> Vec<Packed> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                Packed {
                    words: pack_bits_u64(&bits),
                    n_bits: 784,
                }
            })
            .collect()
    }

    #[test]
    fn native_and_sim_agree() {
        let model = tiny_model(11);
        let native = NativeBackend::new(model.clone());
        let sim = SimBackend::new(&model, SimConfig::new(64, MemStyle::Bram)).unwrap();
        let imgs = images(5, 12);
        let a = native.infer_batch(&imgs).unwrap();
        let b = sim.infer_batch(&imgs).unwrap();
        assert_eq!(a, b);
        assert!(*sim.simulated_ns.lock().unwrap() > 0.0);
    }

    #[test]
    fn predict_is_argmax_of_batch1() {
        let model = tiny_model(13);
        let native = NativeBackend::new(model.clone());
        let imgs = images(1, 14);
        let logits = native.infer_batch(&imgs).unwrap();
        assert_eq!(
            native.predict(&imgs[0]).unwrap() as usize,
            argmax_i32(&logits[0])
        );
    }
}
