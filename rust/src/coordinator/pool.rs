//! Sharded worker pool — the multi-worker inference engine.
//!
//! Where [`super::server::Coordinator`] runs N workers draining **one**
//! shared queue into **one** backend, the pool gives every worker its own
//! queue shard *and its own backend replica*:
//!
//! ```text
//!   submit ──► pick_shard (round-robin + power-of-two-choices)
//!                │
//!                ├─► shard 0 ──► worker 0 ──► replica 0 (native/sim/…)
//!                ├─► shard 1 ──► worker 1 ──► replica 1
//!                └─► shard … ──► worker … ──► replica …
//! ```
//!
//! This mirrors the FPGA's neuron-level parallelism one level up — FINN and
//! Fraser et al. (PAPERS.md) show BNN throughput scales near-linearly when
//! compute is partitioned across independent processing elements, and the
//! same holds in software once workers stop contending on a single queue
//! mutex and a shared model.  Native replicas clone the (small, read-only)
//! packed weights so each worker's hot loop touches only core-local state.
//!
//! Dispatch is round-robin refined by power-of-two-choices: each submit
//! compares the round-robin shard with its neighbour and takes the
//! shallower queue, which keeps shards balanced under skewed drain rates at
//! the cost of two cheap depth probes (no global lock).  Each worker runs
//! the same drain policy as the single-queue coordinator
//! ([`super::batcher::decide`]), so batching semantics are identical.
//!
//! Metrics: lock-free counters (submitted/completed/rejected/batches) are
//! recorded into both the pool-wide aggregate and the owning worker's
//! [`Metrics`]; the mutex-guarded latency histograms are recorded **per
//! worker only** — a shared aggregate histogram would re-serialize the
//! workers on one lock — and merged on read
//! ([`WorkerPool::latency_snapshot`], `per_worker_report`).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::backend::{InferBackend, InferScratch, Kernel, LogitsBuf, NativeBackend};
use super::batcher::{decide, BatcherConfig, DrainDecision};
use super::metrics::Metrics;
use super::request::{top_k_i32, Failure, InferOptions, InferRequest, InferResponse, Reply, Ticket};
use crate::bnn::packing::Packed;
use crate::bnn::{argmax_i32, BnnModel};
use crate::sim::SimConfig;

/// A queued request plus its reply channel (shared by the pool and the
/// single-queue coordinator in `server.rs`).
pub(crate) struct Pending {
    pub(crate) req: InferRequest,
    pub(crate) reply: mpsc::Sender<Reply>,
}

/// Worker supervision: how often a panicking worker is rebuilt before its
/// shard is declared dead, and how the restart delay grows.
///
/// The crash counter is *consecutive*: any successfully executed batch
/// resets it, so an occasional fault (a chaos panic, a cosmic ray) never
/// accumulates toward the death sentence — only a worker that can no
/// longer make progress at all exhausts the budget.  A dead shard resolves
/// its queued and future requests with the typed
/// [`Failure::WorkerCrashed`] instead of hanging them.
#[derive(Clone, Copy, Debug)]
pub struct RestartPolicy {
    /// Consecutive crashes tolerated before the worker stays down.
    pub max_restarts: u32,
    /// Delay before restart `n` is `base_backoff << (n-1)`, capped at
    /// [`Self::max_backoff`].
    pub base_backoff: Duration,
    pub max_backoff: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            max_restarts: 1024,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(50),
        }
    }
}

impl RestartPolicy {
    /// The sleep before consecutive restart number `n` (1-based).
    pub fn backoff_for(&self, n: u32) -> Duration {
        let base = self.base_backoff.as_nanos();
        let d = (base << n.saturating_sub(1).min(64))
            .min(self.max_backoff.as_nanos())
            .min(u64::MAX as u128) as u64;
        Duration::from_nanos(d)
    }
}

/// Execute one drained batch on `backend`, record into `mine` (the owning
/// worker's metrics — counters and histograms) and, when present, into the
/// pool aggregate `agg` (lock-free counters only; see the module doc), then
/// answer each reply channel.  Failure paths, all keeping
/// `submitted == completed + rejected`:
///
/// - request deadline expired while queued → shed on dequeue with the
///   typed [`Failure::DeadlineExceeded`] (`rejected` + `deadline_expired`)
///   before it can burn backend time;
/// - backend `Err` (width mismatch, …) or a mis-shaped logits arena →
///   the batch counts `rejected`, replies are dropped (submitters observe
///   a disconnected channel with the classic "dropped by the backend"
///   diagnostic);
/// - backend **panic** → every waiter gets the typed
///   [`Failure::WorkerCrashed`], the batch counts `rejected`, and the
///   panic resumes so the worker's supervisor can restart it.
///
/// `scratch` and `logits` are the worker's long-lived arenas
/// ([`InferScratch`], [`LogitsBuf`]): images are passed to the backend by
/// reference and logits come back in one flat buffer, so the steady-state
/// batch path performs no per-request allocation — the only remaining
/// per-request heap traffic is the `n_classes`-element logits copy inside
/// each [`InferResponse`] envelope.
pub(crate) fn execute_batch(
    backend: &dyn InferBackend,
    agg: Option<&Metrics>,
    mine: &Metrics,
    batch: Vec<Pending>,
    scratch: &mut InferScratch,
    logits: &mut LogitsBuf,
) {
    let now = Instant::now();
    let (batch, expired): (Vec<Pending>, Vec<Pending>) =
        batch.into_iter().partition(|p| !p.req.opts.expired_at(now));
    if !expired.is_empty() {
        let n = expired.len() as u64;
        for m in std::iter::once(mine).chain(agg) {
            m.rejected.fetch_add(n, Ordering::Relaxed);
            m.deadline_expired.fetch_add(n, Ordering::Relaxed);
        }
        for p in expired {
            let _ = p.reply.send(Err(Failure::DeadlineExceeded));
        }
    }
    if batch.is_empty() {
        return;
    }
    let images: Vec<&Packed> = batch.iter().map(|p| &p.req.image).collect();
    let batch_size = images.len();
    mine.record_batch(batch_size);
    if let Some(a) = agg {
        a.record_batch(batch_size);
    }
    let exec_start = Instant::now();
    let result = catch_unwind(AssertUnwindSafe(|| backend.infer_batch(&images, scratch, logits)));
    drop(images);
    let result = match result {
        Ok(r) => r,
        Err(panic) => {
            // the backend panicked mid-batch: resolve every waiter with
            // the typed failure *before* resuming the panic, so tickets
            // unblock even if supervision itself is torn down
            for m in std::iter::once(mine).chain(agg) {
                m.rejected.fetch_add(batch_size as u64, Ordering::Relaxed);
            }
            for p in batch {
                let _ = p.reply.send(Err(Failure::WorkerCrashed));
            }
            std::panic::resume_unwind(panic);
        }
    };
    // shape guard: a backend that "succeeds" but leaves the arena sized
    // for a different batch (chaos wrong-shape fault, or a genuinely buggy
    // backend) must not serve another request's logits row
    let result = result.and_then(|()| {
        anyhow::ensure!(
            logits.rows() == batch_size,
            "backend returned {} logit rows for a batch of {batch_size}",
            logits.rows()
        );
        Ok(())
    });
    match result {
        Ok(()) => {
            for (i, p) in batch.into_iter().enumerate() {
                let latency_ns = p.req.enqueued_at.elapsed().as_nanos() as u64;
                let wait_ns = (exec_start - p.req.enqueued_at).as_nanos() as u64;
                mine.record_queue_wait(wait_ns);
                mine.record_latency(latency_ns);
                if let Some(a) = agg {
                    a.completed.fetch_add(1, Ordering::Relaxed);
                }
                // Response shape follows the request's InferOptions: the
                // logits copy and the top-k selection are both opt-in.
                let row = logits.row(i);
                let opts = p.req.opts;
                let _ = p.reply.send(Ok(InferResponse {
                    id: p.req.id,
                    // u16, never u8: a >255-class model's argmax must not
                    // wrap (class ids share the top-k u16 carrier)
                    digit: argmax_i32(row) as u16,
                    logits: if opts.include_logits { row.to_vec() } else { Vec::new() },
                    top_k: match opts.top_k {
                        Some(k) => top_k_i32(row, k),
                        None => Vec::new(),
                    },
                    latency_ns,
                    queue_wait_ns: wait_ns,
                    batch_size,
                    backend: backend.name(),
                }));
            }
        }
        Err(e) => {
            mine.rejected.fetch_add(batch_size as u64, Ordering::Relaxed);
            if let Some(a) = agg {
                a.rejected.fetch_add(batch_size as u64, Ordering::Relaxed);
            }
            eprintln!("[coordinator] batch of {batch_size} failed: {e:#}");
        }
    }
}

struct Shard {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    /// Set (under the queue lock) when the shard's worker exhausted its
    /// restart budget: queued requests were resolved with
    /// [`Failure::WorkerCrashed`] and submits fail fast with the same
    /// typed substring.  [`WorkerPool::pick_shard`] routes around it.
    dead: AtomicBool,
}

struct PoolShared {
    shards: Vec<Shard>,
    shutdown: AtomicBool,
    cfg: BatcherConfig,
    /// Backpressure bound per shard (submit fails beyond it).
    shard_cap: usize,
    restart: RestartPolicy,
}

/// Multi-worker sharded inference engine: one queue shard + one backend
/// replica + one metrics instance per worker, plus a pool-wide aggregate.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Pool-wide aggregate metrics.
    pub metrics: Arc<Metrics>,
    /// Per-worker metrics, index-aligned with the replicas.
    pub worker_metrics: Vec<Arc<Metrics>>,
    next_id: AtomicU64,
    rr: AtomicUsize,
    workers: Vec<std::thread::JoinHandle<()>>,
    backend_name: &'static str,
    /// Input width every replica agrees on (submit-time rejection); `None`
    /// when any replica doesn't know its width or they disagree.
    expected_bits: Option<usize>,
}

impl WorkerPool {
    /// Spawn one worker thread per replica, each draining its own shard.
    /// Crate-internal: the public construction path is `Engine::builder()`.
    ///
    /// `cfg.max_batch` is clamped to the smallest replica `max_batch` so a
    /// drained batch always fits whichever worker drains it; `queue_cap`
    /// is the per-shard backpressure bound.
    pub(crate) fn start(
        replicas: Vec<Arc<dyn InferBackend>>,
        cfg: BatcherConfig,
        queue_cap: usize,
    ) -> Result<WorkerPool> {
        Self::start_supervised(replicas, cfg, queue_cap, RestartPolicy::default())
    }

    /// [`Self::start`] with an explicit worker [`RestartPolicy`].
    pub(crate) fn start_supervised(
        replicas: Vec<Arc<dyn InferBackend>>,
        cfg: BatcherConfig,
        queue_cap: usize,
        restart: RestartPolicy,
    ) -> Result<WorkerPool> {
        anyhow::ensure!(!replicas.is_empty(), "worker pool needs ≥ 1 replica");
        cfg.validate()?;
        anyhow::ensure!(queue_cap >= 1, "queue_cap must be ≥ 1");
        let min_max_batch = replicas.iter().map(|r| r.max_batch()).min().unwrap();
        let cfg = BatcherConfig {
            max_batch: cfg.max_batch.min(min_max_batch),
            ..cfg
        };
        let backend_name = replicas[0].name();
        let mut expected_bits = replicas[0].expected_bits();
        for r in &replicas[1..] {
            if r.expected_bits() != expected_bits {
                expected_bits = None;
            }
        }
        let shared = Arc::new(PoolShared {
            shards: (0..replicas.len())
                .map(|_| Shard {
                    queue: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    dead: AtomicBool::new(false),
                })
                .collect(),
            shutdown: AtomicBool::new(false),
            cfg,
            shard_cap: queue_cap,
            restart,
        });
        let metrics = Arc::new(Metrics::new());
        let worker_metrics: Vec<Arc<Metrics>> =
            (0..shared.shards.len()).map(|_| Arc::new(Metrics::new())).collect();
        let mut workers = Vec::new();
        for (w, replica) in replicas.into_iter().enumerate() {
            let shared = shared.clone();
            let agg = metrics.clone();
            let mine = worker_metrics[w].clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bnn-pool-{w}"))
                    .spawn(move || supervise_shard_worker(shared, w, replica, agg, mine))
                    .expect("spawn pool worker"),
            );
        }
        Ok(WorkerPool {
            shared,
            metrics,
            worker_metrics,
            next_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            workers,
            backend_name,
            expected_bits,
        })
    }

    /// Pool of `workers` native replicas, each owning its own copy of the
    /// packed model, running the given [`Kernel`] schedule
    /// (`Kernel::default()` = the weight-stationary tiled serving path).
    pub(crate) fn native(
        model: &BnnModel,
        workers: usize,
        kernel: Kernel,
        cfg: BatcherConfig,
        queue_cap: usize,
    ) -> Result<WorkerPool> {
        Self::native_supervised(model, workers, kernel, cfg, queue_cap, RestartPolicy::default())
    }

    /// [`Self::native`] with an explicit worker [`RestartPolicy`].
    pub(crate) fn native_supervised(
        model: &BnnModel,
        workers: usize,
        kernel: Kernel,
        cfg: BatcherConfig,
        queue_cap: usize,
        restart: RestartPolicy,
    ) -> Result<WorkerPool> {
        let replicas: Vec<Arc<dyn InferBackend>> = (0..workers.max(1))
            .map(|_| -> Arc<dyn InferBackend> {
                Arc::new(NativeBackend::with_kernel(model.clone(), kernel))
            })
            .collect();
        Self::start_supervised(replicas, cfg, queue_cap, restart)
    }

    /// Pool of `workers` independent cycle-accurate simulator replicas —
    /// software's version of deploying several accelerator boards.
    pub(crate) fn fpga_sim(
        model: &BnnModel,
        workers: usize,
        sim_cfg: SimConfig,
        cfg: BatcherConfig,
        queue_cap: usize,
    ) -> Result<WorkerPool> {
        Self::fpga_sim_supervised(model, workers, sim_cfg, cfg, queue_cap, RestartPolicy::default())
    }

    /// [`Self::fpga_sim`] with an explicit worker [`RestartPolicy`].
    pub(crate) fn fpga_sim_supervised(
        model: &BnnModel,
        workers: usize,
        sim_cfg: SimConfig,
        cfg: BatcherConfig,
        queue_cap: usize,
        restart: RestartPolicy,
    ) -> Result<WorkerPool> {
        let mut replicas: Vec<Arc<dyn InferBackend>> = Vec::new();
        for _ in 0..workers.max(1) {
            replicas.push(Arc::new(super::backend::SimBackend::new(model, sim_cfg)?));
        }
        Self::start_supervised(replicas, cfg, queue_cap, restart)
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Number of workers (= shards = replicas).
    pub fn workers(&self) -> usize {
        self.shared.shards.len()
    }

    /// Current depth of every shard (observability / tests).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shared
            .shards
            .iter()
            .map(|s| s.queue.lock().unwrap().len())
            .collect()
    }

    /// Total queued requests across shards.
    pub fn queue_depth(&self) -> usize {
        self.queue_depths().iter().sum()
    }

    /// Round-robin refined by power-of-two-choices: compare the round-robin
    /// shard with its neighbour, take the shallower queue.  Dead shards
    /// (worker crashed for good) are routed around; only when every shard
    /// is dead does the pick fall through, so the submit fails with the
    /// typed worker-crashed refusal instead of a panic.
    fn pick_shard(&self) -> usize {
        let n = self.shared.shards.len();
        if n == 1 {
            return 0;
        }
        let alive = |s: usize| !self.shared.shards[s].dead.load(Ordering::SeqCst);
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let j = (i + 1) % n;
        match (alive(i), alive(j)) {
            (true, false) => return i,
            (false, true) => return j,
            (false, false) => return (0..n).find(|&s| alive(s)).unwrap_or(i),
            (true, true) => {}
        }
        let di = self.shared.shards[i].queue.lock().unwrap().len();
        let dj = self.shared.shards[j].queue.lock().unwrap().len();
        if dj < di {
            j
        } else {
            i
        }
    }

    /// Enqueue one image on the least-loaded candidate shard, with explicit
    /// per-request options.
    pub fn submit_with(&self, image: Packed, opts: InferOptions) -> Result<Ticket> {
        let s = self.pick_shard();
        // width check at the door: a mismatched image must never reach a
        // shard, where it would fail everything co-batched with it (books:
        // counted as submitted AND rejected on the picked shard's ledger,
        // same as a backend rejection)
        if let Some(want) = self.expected_bits {
            if image.n_bits != want {
                for m in [self.metrics.as_ref(), self.worker_metrics[s].as_ref()] {
                    m.submitted.fetch_add(1, Ordering::Relaxed);
                    m.rejected.fetch_add(1, Ordering::Relaxed);
                }
                anyhow::bail!("image has {} bits, backend expects {want}", image.n_bits);
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let shard = &self.shared.shards[s];
        {
            let mut q = shard.queue.lock().unwrap();
            // dead-shard check under the queue lock (the worker marks the
            // shard dead and drains it under the same lock, so a submit
            // can never slip a request into a queue nobody will drain)
            if shard.dead.load(Ordering::SeqCst) {
                for m in [self.metrics.as_ref(), self.worker_metrics[s].as_ref()] {
                    m.submitted.fetch_add(1, Ordering::Relaxed);
                    m.rejected.fetch_add(1, Ordering::Relaxed);
                }
                anyhow::bail!(
                    "shard {s} is dead: its worker crashed and exhausted its restart budget"
                );
            }
            if q.len() >= self.shared.shard_cap {
                // every arrival counts as submitted, so the books keep
                // `submitted == completed + rejected` on every path
                for m in [self.metrics.as_ref(), self.worker_metrics[s].as_ref()] {
                    m.submitted.fetch_add(1, Ordering::Relaxed);
                    m.rejected.fetch_add(1, Ordering::Relaxed);
                }
                anyhow::bail!("shard {s} full ({} requests, cap {})", q.len(), self.shared.shard_cap);
            }
            q.push_back(Pending {
                req: InferRequest::with_opts(id, image, opts),
                reply: tx,
            });
        }
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.worker_metrics[s].submitted.fetch_add(1, Ordering::Relaxed);
        shard.cv.notify_one();
        Ok(Ticket::new(id, rx, self.metrics.clone()))
    }

    /// Enqueue one image; returns its [`Ticket`].
    pub fn submit(&self, image: Packed) -> Result<Ticket> {
        self.submit_with(image, InferOptions::default())
    }

    /// Blocking classify (the [`super::InferService`] default, kept as an
    /// inherent method so callers don't need the trait in scope).
    pub fn infer(&self, image: Packed) -> Result<InferResponse> {
        super::InferService::infer(self, image)
    }

    /// Submit many, wait for all (responses in submission order).
    pub fn infer_many(&self, images: Vec<Packed>) -> Result<Vec<InferResponse>> {
        super::InferService::infer_many(self, images)
    }

    /// Latency histogram merged across workers (the aggregate [`Metrics`]
    /// carries counters only — no shared histogram lock on the hot path).
    pub fn latency_snapshot(&self) -> crate::util::stats::LatencyHistogram {
        let mut h = crate::util::stats::LatencyHistogram::new();
        for m in &self.worker_metrics {
            h.merge(&m.latency_snapshot());
        }
        h
    }

    /// Pool-wide summary (aggregate counters + merged latency histogram).
    pub fn summary_line(&self) -> String {
        self.metrics.summary_line_with(&self.latency_snapshot())
    }

    /// One metrics line per worker (queue skew / stragglers at a glance).
    pub fn per_worker_report(&self) -> String {
        let mut out = String::new();
        for (w, m) in self.worker_metrics.iter().enumerate() {
            out.push_str(&format!("worker {w}: {}\n", m.summary_line()));
        }
        out
    }

    /// Stop workers; in-flight batches finish, queued work is abandoned.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for s in &self.shared.shards {
            s.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Supervisor wrapper around [`shard_worker_loop`]: catches worker panics
/// (the loop resolves the in-flight batch with typed failures before the
/// panic reaches here — see [`execute_batch`]), rebuilds the worker with
/// fresh arenas under the pool's [`RestartPolicy`], and counts
/// `worker_restarts` on both ledgers.  A worker that crashes
/// `max_restarts + 1` times in a row stays down: its shard is marked dead
/// and drained with [`Failure::WorkerCrashed`] so no ticket ever hangs.
fn supervise_shard_worker(
    shared: Arc<PoolShared>,
    idx: usize,
    backend: Arc<dyn InferBackend>,
    agg: Arc<Metrics>,
    mine: Arc<Metrics>,
) {
    // consecutive crash counter, reset by the loop on every batch that
    // executes without panicking
    let consecutive = AtomicU32::new(0);
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            shard_worker_loop(&shared, idx, backend.as_ref(), &agg, &mine, &consecutive)
        }));
        match run {
            Ok(()) => return, // clean shutdown
            Err(_) => {
                let crashes = consecutive.fetch_add(1, Ordering::Relaxed) + 1;
                if crashes > shared.restart.max_restarts {
                    declare_shard_dead(&shared, idx, &agg, &mine, crashes);
                    return;
                }
                mine.worker_restarts.fetch_add(1, Ordering::Relaxed);
                agg.worker_restarts.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(shared.restart.backoff_for(crashes));
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

/// Mark shard `idx` dead and resolve everything queued on it with
/// [`Failure::WorkerCrashed`] (counted `rejected`, so the ledger stays
/// balanced).  Runs under the queue lock, which [`WorkerPool::submit_with`]
/// also holds for its dead check — a submit either saw the flag (typed
/// refusal) or enqueued before it and is drained here.
fn declare_shard_dead(shared: &PoolShared, idx: usize, agg: &Metrics, mine: &Metrics, crashes: u32) {
    let shard = &shared.shards[idx];
    let mut q = shard.queue.lock().unwrap();
    shard.dead.store(true, Ordering::SeqCst);
    let n = q.len() as u64;
    if n > 0 {
        for m in [mine, agg] {
            m.rejected.fetch_add(n, Ordering::Relaxed);
        }
    }
    for p in q.drain(..) {
        let _ = p.reply.send(Err(Failure::WorkerCrashed));
    }
    eprintln!(
        "[pool] worker {idx} crashed {crashes}× consecutively — shard {idx} is dead \
         ({n} queued requests resolved with worker-crashed)"
    );
}

fn shard_worker_loop(
    shared: &PoolShared,
    idx: usize,
    backend: &dyn InferBackend,
    agg: &Metrics,
    mine: &Metrics,
    consecutive: &AtomicU32,
) {
    let shard = &shared.shards[idx];
    // Per-worker arenas: grow to the steady-state batch size once, then
    // every subsequent batch runs allocation-free through the backend.
    // Rebuilt fresh on every (re)start, so a panic can never leak a
    // half-written arena into the next batch.
    let mut scratch = InferScratch::default();
    let mut logits = LogitsBuf::new();
    loop {
        // Decide under the shard lock, execute outside it (so a panicking
        // backend can never poison the shard mutex).
        let batch: Vec<Pending> = {
            let mut q = shard.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match decide(
                    q.len(),
                    q.front().map(|p| p.req.enqueued_at),
                    &shared.cfg,
                    Instant::now(),
                ) {
                    DrainDecision::Launch(n) => break q.drain(..n).collect(),
                    DrainDecision::Wait(d) => {
                        let (guard, _) = shard.cv.wait_timeout(q, d).unwrap();
                        q = guard;
                    }
                    DrainDecision::Idle => {
                        let (guard, _) = shard
                            .cv
                            .wait_timeout(q, std::time::Duration::from_millis(50))
                            .unwrap();
                        q = guard;
                    }
                }
            }
        };
        execute_batch(backend, Some(agg), mine, batch, &mut scratch, &mut logits);
        // the batch executed without panicking — the worker is healthy, so
        // its crash budget refills (see RestartPolicy)
        consecutive.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::random_model;
    use crate::bnn::packing::pack_bits_u64;
    use crate::coordinator::server::DEFAULT_QUEUE_CAP;
    use crate::util::prng::Xoshiro256;
    use std::time::Duration;

    fn imgs(n: usize, seed: u64) -> Vec<Packed> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                Packed {
                    words: pack_bits_u64(&bits),
                    n_bits: 784,
                }
            })
            .collect()
    }

    #[test]
    fn pool_serves_and_matches_direct_inference() {
        let model = random_model(&[784, 128, 64, 10], 51);
        let pool = WorkerPool::native(
            &model,
            4,
            Kernel::default(),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        assert_eq!(pool.workers(), 4);
        let images = imgs(120, 52);
        let responses = pool.infer_many(images.clone()).unwrap();
        assert_eq!(responses.len(), 120);
        for (img, r) in images.iter().zip(&responses) {
            assert_eq!(r.logits, model.logits(&img.words), "req {}", r.id);
            assert_eq!(r.digit as usize, model.predict(&img.words));
            assert_eq!(r.backend, "native");
        }
        // no request lost or duplicated
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 120);
        pool.shutdown();
    }

    #[test]
    fn per_worker_metrics_sum_to_aggregate() {
        let model = random_model(&[784, 128, 64, 10], 53);
        let pool = WorkerPool::native(
            &model,
            3,
            Kernel::default(),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(50),
            },
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        let n = 90;
        pool.infer_many(imgs(n, 54)).unwrap();
        let agg = pool.metrics.completed.load(Ordering::Relaxed);
        let per: u64 = pool
            .worker_metrics
            .iter()
            .map(|m| m.completed.load(Ordering::Relaxed))
            .sum();
        assert_eq!(agg, n as u64);
        assert_eq!(per, agg, "per-worker completions must sum to the aggregate");
        // merged latency histogram sees every request; the aggregate
        // Metrics records counters only (no shared histogram lock)
        assert_eq!(pool.latency_snapshot().count(), n as u64);
        assert_eq!(pool.metrics.latency_snapshot().count(), 0);
        assert!(pool.summary_line().contains("completed=90"), "{}", pool.summary_line());
        // dispatch actually spreads load: more than one worker saw traffic
        let busy = pool
            .worker_metrics
            .iter()
            .filter(|m| m.completed.load(Ordering::Relaxed) > 0)
            .count();
        assert!(busy >= 2, "only {busy}/3 workers saw traffic");
        let report = pool.per_worker_report();
        assert!(report.contains("worker 0:") && report.contains("worker 2:"), "{report}");
        pool.shutdown();
    }

    #[test]
    fn all_kernel_pools_agree() {
        // every registered kernel tier must serve identical logits for the
        // same request stream (the registry keeps this exhaustive as new
        // tiers land).
        let model = random_model(&[784, 128, 64, 10], 55);
        let cfg = BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(50),
        };
        let images = imgs(30, 56);
        let scalar_pool =
            WorkerPool::native(&model, 2, Kernel::Scalar, cfg, DEFAULT_QUEUE_CAP).unwrap();
        let want = scalar_pool.infer_many(images.clone()).unwrap();
        scalar_pool.shutdown();
        let mut kernels = Kernel::registry_with(16, 4);
        kernels.push(Kernel::Blocked { block_rows: 32 });
        kernels.push(Kernel::default());
        for kernel in kernels {
            let pool = WorkerPool::native(&model, 2, kernel, cfg, DEFAULT_QUEUE_CAP).unwrap();
            let got = pool.infer_many(images.clone()).unwrap();
            for (x, y) in got.iter().zip(&want) {
                assert_eq!(x.logits, y.logits, "{kernel:?}");
                assert_eq!(x.digit, y.digit, "{kernel:?}");
            }
            pool.shutdown();
        }
    }

    #[test]
    fn single_worker_pool_degenerates_to_coordinator_semantics() {
        let model = random_model(&[784, 128, 64, 10], 57);
        let pool = WorkerPool::native(
            &model,
            1,
            Kernel::default(),
            BatcherConfig::default(),
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        assert_eq!(pool.workers(), 1);
        let r = pool.infer(imgs(1, 58).pop().unwrap()).unwrap();
        assert_eq!(r.batch_size, 1);
        assert_eq!(pool.queue_depth(), 0);
        pool.shutdown();
    }

    #[test]
    fn shutdown_terminates_workers() {
        let model = random_model(&[784, 128, 64, 10], 59);
        let pool = WorkerPool::native(
            &model,
            4,
            Kernel::Scalar,
            BatcherConfig::default(),
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        pool.shutdown(); // must not hang
    }

    #[test]
    fn pipelined_pool_ledger_balances_at_mid_drain_shutdown() {
        // ISSUE 6 satellite: burst-stress the pipelined tier (pulled from
        // the registry, not hand-built) and check the metrics ledger still
        // balances when the pool is shut down while a pipelined worker is
        // mid-drain.  Accounting contract under shutdown: in-flight
        // batches finish (counted completed), queued work is abandoned —
        // counted submitted but never completed/rejected, its waiters see
        // a disconnected reply channel.  Every ticket is waited, so
        // nothing may count cancelled.
        let kernel = *Kernel::registry()
            .iter()
            .find(|k| k.name() == "pipelined")
            .expect("registry carries the pipelined tier");
        let model = random_model(&[784, 128, 64, 10], 63);
        let pool = WorkerPool::native(
            &model,
            1, // one worker: the burst must outrun a single drain loop
            kernel,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(10),
            },
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        let n = 256usize;
        let mut tickets = Vec::with_capacity(n);
        for img in imgs(n, 64) {
            tickets.push(pool.submit(img).unwrap());
        }
        // resolve a handful, then pull the plug with the rest in flight
        let mut completed_seen = 0u64;
        for t in tickets.drain(..4) {
            t.wait().unwrap();
            completed_seen += 1;
        }
        let metrics = Arc::clone(&pool.metrics);
        pool.shutdown();
        // classify every remaining ticket: executed before the stop flag
        // (reply delivered → Ok) or abandoned on the shard queue (reply
        // sender dropped → Err).  wait() resolves the ticket either way,
        // so none of these may be counted cancelled.
        let mut abandoned = 0u64;
        for t in tickets {
            match t.wait() {
                Ok(_) => completed_seen += 1,
                Err(_) => abandoned += 1,
            }
        }
        let submitted = metrics.submitted.load(Ordering::Relaxed);
        let completed = metrics.completed.load(Ordering::Relaxed);
        let rejected = metrics.rejected.load(Ordering::Relaxed);
        let cancelled = metrics.cancelled.load(Ordering::Relaxed);
        assert_eq!(submitted, n as u64, "every burst submit is counted");
        assert_eq!(rejected, 0, "well-formed images are never rejected");
        assert_eq!(cancelled, 0, "waited tickets must not count cancelled");
        assert_eq!(
            completed, completed_seen,
            "completed counter must match delivered replies"
        );
        assert_eq!(
            submitted,
            completed + rejected + abandoned,
            "ledger must balance at mid-drain shutdown \
             (submitted == completed + rejected + abandoned)"
        );
    }

    /// Panics on call numbers in `panic_calls`, delegates to a native
    /// replica otherwise — a hand-rolled fault plan for supervision tests
    /// (the general tool is `coordinator::chaos::ChaosBackend`).
    struct PanicOnCalls {
        inner: NativeBackend,
        calls: AtomicU64,
        panic_below: u64,
    }

    impl InferBackend for PanicOnCalls {
        fn name(&self) -> &'static str {
            "panic-on-calls"
        }
        fn max_batch(&self) -> usize {
            self.inner.max_batch()
        }
        fn expected_bits(&self) -> Option<usize> {
            self.inner.expected_bits()
        }
        fn infer_batch(
            &self,
            images: &[&Packed],
            scratch: &mut InferScratch,
            out: &mut LogitsBuf,
        ) -> Result<()> {
            if self.calls.fetch_add(1, Ordering::SeqCst) < self.panic_below {
                panic!("test: injected worker panic");
            }
            self.inner.infer_batch(images, scratch, out)
        }
    }

    #[test]
    fn crashed_worker_resolves_tickets_typed_and_restarts() {
        let model = random_model(&[784, 128, 64, 10], 71);
        let backend = Arc::new(PanicOnCalls {
            inner: NativeBackend::with_kernel(model.clone(), Kernel::default()),
            calls: AtomicU64::new(0),
            panic_below: 1, // first batch crashes, everything after serves
        });
        let pool = WorkerPool::start_supervised(
            vec![backend],
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(10),
            },
            DEFAULT_QUEUE_CAP,
            RestartPolicy {
                max_restarts: 8,
                base_backoff: Duration::from_micros(10),
                max_backoff: Duration::from_micros(100),
            },
        )
        .unwrap();
        // first request rides the crashing batch: typed failure, no hang
        let img = imgs(1, 72).pop().unwrap();
        let e = pool.submit(img.clone()).unwrap().wait().unwrap_err();
        assert!(format!("{e}").contains("worker crashed"), "{e}");
        // the supervisor rebuilt the worker: the next request serves
        let r = pool.infer(img.clone()).unwrap();
        assert_eq!(r.logits, model.logits(&img.words));
        let m = &pool.metrics;
        assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 1);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
        pool.shutdown();
    }

    #[test]
    fn exhausted_restart_budget_kills_the_shard_typed() {
        // a worker that can never make progress must not hang its clients:
        // every request resolves with the typed worker-crashed failure,
        // and once the restart budget runs out submits fail fast
        let model = random_model(&[784, 32, 10], 73);
        let backend = Arc::new(PanicOnCalls {
            inner: NativeBackend::with_kernel(model, Kernel::default()),
            calls: AtomicU64::new(0),
            panic_below: u64::MAX, // always panics
        });
        let pool = WorkerPool::start_supervised(
            vec![backend],
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(10),
            },
            DEFAULT_QUEUE_CAP,
            RestartPolicy {
                max_restarts: 2,
                base_backoff: Duration::from_micros(10),
                max_backoff: Duration::from_micros(100),
            },
        )
        .unwrap();
        let mut waited_typed = 0u64;
        let mut failed_fast = 0u64;
        for img in imgs(20, 74) {
            match pool.submit(img) {
                Ok(t) => {
                    let e = t.wait().unwrap_err();
                    assert!(format!("{e}").contains("worker crashed"), "{e}");
                    waited_typed += 1;
                }
                Err(e) => {
                    assert!(format!("{e}").contains("worker crashed"), "{e}");
                    failed_fast += 1;
                }
            }
        }
        assert!(waited_typed >= 1, "some requests rode crashing batches");
        assert!(failed_fast >= 1, "the dead shard must fail fast eventually");
        let m = &pool.metrics;
        assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 2, "budget was 2");
        assert_eq!(m.submitted.load(Ordering::Relaxed), 20);
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 20, "ledger balances");
        pool.shutdown();
    }

    #[test]
    fn expired_deadlines_are_shed_with_typed_failures() {
        let model = random_model(&[784, 128, 64, 10], 75);
        let pool = WorkerPool::native(
            &model,
            1,
            Kernel::default(),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(10),
            },
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        let img = imgs(1, 76).pop().unwrap();
        // an already-expired deadline is shed on dequeue, typed
        let expired = InferOptions::default()
            .with_deadline(Instant::now() - Duration::from_millis(1));
        let e = pool.submit_with(img.clone(), expired).unwrap().wait().unwrap_err();
        assert!(format!("{e}").contains("deadline exceeded"), "{e}");
        // a generous budget serves normally
        let roomy = InferOptions::default().with_budget(Duration::from_secs(30));
        let r = pool.submit_with(img.clone(), roomy).unwrap().wait().unwrap();
        assert_eq!(r.logits, model.logits(&img.words));
        let m = &pool.metrics;
        assert_eq!(m.submitted.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 0);
        pool.shutdown();
    }

    #[test]
    fn size_mismatched_image_is_rejected_not_fatal() {
        // A wrong-width image must surface as an Err at submit time
        // (expected_bits gate — it never reaches a shard, so it can't
        // poison a co-scheduled batch), and the worker keeps serving
        // well-formed requests afterwards.
        let model = random_model(&[784, 128, 64, 10], 61);
        let pool = WorkerPool::native(
            &model,
            1,
            Kernel::default(),
            BatcherConfig::default(),
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        let bad = Packed::from_bits(&vec![1u8; 100]); // 100 ≠ 784 bits
        assert!(pool.infer(bad).is_err(), "mismatched image must error");
        let good = imgs(1, 62).pop().unwrap();
        let r = pool.infer(good.clone()).unwrap();
        assert_eq!(r.logits, model.logits(&good.words), "worker must still serve");
        pool.shutdown();
    }
}
