//! Sharded worker pool — the multi-worker inference engine.
//!
//! Where [`super::server::Coordinator`] runs N workers draining **one**
//! shared queue into **one** backend, the pool gives every worker its own
//! queue shard *and its own backend replica*:
//!
//! ```text
//!   submit ──► pick_shard (round-robin + power-of-two-choices)
//!                │
//!                ├─► shard 0 ──► worker 0 ──► replica 0 (native/sim/…)
//!                ├─► shard 1 ──► worker 1 ──► replica 1
//!                └─► shard … ──► worker … ──► replica …
//! ```
//!
//! This mirrors the FPGA's neuron-level parallelism one level up — FINN and
//! Fraser et al. (PAPERS.md) show BNN throughput scales near-linearly when
//! compute is partitioned across independent processing elements, and the
//! same holds in software once workers stop contending on a single queue
//! mutex and a shared model.  Native replicas clone the (small, read-only)
//! packed weights so each worker's hot loop touches only core-local state.
//!
//! Dispatch is round-robin refined by power-of-two-choices: each submit
//! compares the round-robin shard with its neighbour and takes the
//! shallower queue, which keeps shards balanced under skewed drain rates at
//! the cost of two cheap depth probes (no global lock).  Each worker runs
//! the same drain policy as the single-queue coordinator
//! ([`super::batcher::decide`]), so batching semantics are identical.
//!
//! Metrics: lock-free counters (submitted/completed/rejected/batches) are
//! recorded into both the pool-wide aggregate and the owning worker's
//! [`Metrics`]; the mutex-guarded latency histograms are recorded **per
//! worker only** — a shared aggregate histogram would re-serialize the
//! workers on one lock — and merged on read
//! ([`WorkerPool::latency_snapshot`], `per_worker_report`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::backend::{InferBackend, InferScratch, Kernel, LogitsBuf, NativeBackend};
use super::batcher::{decide, BatcherConfig, DrainDecision};
use super::metrics::Metrics;
use super::request::{top_k_i32, InferOptions, InferRequest, InferResponse, Ticket};
use crate::bnn::packing::Packed;
use crate::bnn::{argmax_i32, BnnModel};
use crate::sim::SimConfig;

/// A queued request plus its reply channel (shared by the pool and the
/// single-queue coordinator in `server.rs`).
pub(crate) struct Pending {
    pub(crate) req: InferRequest,
    pub(crate) reply: mpsc::Sender<InferResponse>,
}

/// Execute one drained batch on `backend`, record into `mine` (the owning
/// worker's metrics — counters and histograms) and, when present, into the
/// pool aggregate `agg` (lock-free counters only; see the module doc), then
/// answer each reply channel.  On backend failure the replies are dropped
/// (submitters observe a disconnected channel) and the batch counts as
/// rejected.
///
/// `scratch` and `logits` are the worker's long-lived arenas
/// ([`InferScratch`], [`LogitsBuf`]): images are passed to the backend by
/// reference and logits come back in one flat buffer, so the steady-state
/// batch path performs no per-request allocation — the only remaining
/// per-request heap traffic is the `n_classes`-element logits copy inside
/// each [`InferResponse`] envelope.
pub(crate) fn execute_batch(
    backend: &dyn InferBackend,
    agg: Option<&Metrics>,
    mine: &Metrics,
    batch: Vec<Pending>,
    scratch: &mut InferScratch,
    logits: &mut LogitsBuf,
) {
    let images: Vec<&Packed> = batch.iter().map(|p| &p.req.image).collect();
    let batch_size = images.len();
    mine.record_batch(batch_size);
    if let Some(a) = agg {
        a.record_batch(batch_size);
    }
    let exec_start = Instant::now();
    let result = backend.infer_batch(&images, scratch, logits);
    drop(images);
    match result {
        Ok(()) => {
            for (i, p) in batch.into_iter().enumerate() {
                let latency_ns = p.req.enqueued_at.elapsed().as_nanos() as u64;
                let wait_ns = (exec_start - p.req.enqueued_at).as_nanos() as u64;
                mine.record_queue_wait(wait_ns);
                mine.record_latency(latency_ns);
                if let Some(a) = agg {
                    a.completed.fetch_add(1, Ordering::Relaxed);
                }
                // Response shape follows the request's InferOptions: the
                // logits copy and the top-k selection are both opt-in.
                let row = logits.row(i);
                let opts = p.req.opts;
                let _ = p.reply.send(InferResponse {
                    id: p.req.id,
                    // u16, never u8: a >255-class model's argmax must not
                    // wrap (class ids share the top-k u16 carrier)
                    digit: argmax_i32(row) as u16,
                    logits: if opts.include_logits { row.to_vec() } else { Vec::new() },
                    top_k: match opts.top_k {
                        Some(k) => top_k_i32(row, k),
                        None => Vec::new(),
                    },
                    latency_ns,
                    queue_wait_ns: wait_ns,
                    batch_size,
                    backend: backend.name(),
                });
            }
        }
        Err(e) => {
            mine.rejected.fetch_add(batch_size as u64, Ordering::Relaxed);
            if let Some(a) = agg {
                a.rejected.fetch_add(batch_size as u64, Ordering::Relaxed);
            }
            eprintln!("[coordinator] batch of {batch_size} failed: {e:#}");
        }
    }
}

struct Shard {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
}

struct PoolShared {
    shards: Vec<Shard>,
    shutdown: AtomicBool,
    cfg: BatcherConfig,
    /// Backpressure bound per shard (submit fails beyond it).
    shard_cap: usize,
}

/// Multi-worker sharded inference engine: one queue shard + one backend
/// replica + one metrics instance per worker, plus a pool-wide aggregate.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Pool-wide aggregate metrics.
    pub metrics: Arc<Metrics>,
    /// Per-worker metrics, index-aligned with the replicas.
    pub worker_metrics: Vec<Arc<Metrics>>,
    next_id: AtomicU64,
    rr: AtomicUsize,
    workers: Vec<std::thread::JoinHandle<()>>,
    backend_name: &'static str,
    /// Input width every replica agrees on (submit-time rejection); `None`
    /// when any replica doesn't know its width or they disagree.
    expected_bits: Option<usize>,
}

impl WorkerPool {
    /// Spawn one worker thread per replica, each draining its own shard.
    /// Crate-internal: the public construction path is `Engine::builder()`.
    ///
    /// `cfg.max_batch` is clamped to the smallest replica `max_batch` so a
    /// drained batch always fits whichever worker drains it; `queue_cap`
    /// is the per-shard backpressure bound.
    pub(crate) fn start(
        replicas: Vec<Arc<dyn InferBackend>>,
        cfg: BatcherConfig,
        queue_cap: usize,
    ) -> Result<WorkerPool> {
        anyhow::ensure!(!replicas.is_empty(), "worker pool needs ≥ 1 replica");
        cfg.validate()?;
        anyhow::ensure!(queue_cap >= 1, "queue_cap must be ≥ 1");
        let min_max_batch = replicas.iter().map(|r| r.max_batch()).min().unwrap();
        let cfg = BatcherConfig {
            max_batch: cfg.max_batch.min(min_max_batch),
            ..cfg
        };
        let backend_name = replicas[0].name();
        let mut expected_bits = replicas[0].expected_bits();
        for r in &replicas[1..] {
            if r.expected_bits() != expected_bits {
                expected_bits = None;
            }
        }
        let shared = Arc::new(PoolShared {
            shards: (0..replicas.len())
                .map(|_| Shard {
                    queue: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                })
                .collect(),
            shutdown: AtomicBool::new(false),
            cfg,
            shard_cap: queue_cap,
        });
        let metrics = Arc::new(Metrics::new());
        let worker_metrics: Vec<Arc<Metrics>> =
            (0..shared.shards.len()).map(|_| Arc::new(Metrics::new())).collect();
        let mut workers = Vec::new();
        for (w, replica) in replicas.into_iter().enumerate() {
            let shared = shared.clone();
            let agg = metrics.clone();
            let mine = worker_metrics[w].clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bnn-pool-{w}"))
                    .spawn(move || shard_worker_loop(shared, w, replica, agg, mine))
                    .expect("spawn pool worker"),
            );
        }
        Ok(WorkerPool {
            shared,
            metrics,
            worker_metrics,
            next_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            workers,
            backend_name,
            expected_bits,
        })
    }

    /// Pool of `workers` native replicas, each owning its own copy of the
    /// packed model, running the given [`Kernel`] schedule
    /// (`Kernel::default()` = the weight-stationary tiled serving path).
    pub(crate) fn native(
        model: &BnnModel,
        workers: usize,
        kernel: Kernel,
        cfg: BatcherConfig,
        queue_cap: usize,
    ) -> Result<WorkerPool> {
        let replicas: Vec<Arc<dyn InferBackend>> = (0..workers.max(1))
            .map(|_| -> Arc<dyn InferBackend> {
                Arc::new(NativeBackend::with_kernel(model.clone(), kernel))
            })
            .collect();
        Self::start(replicas, cfg, queue_cap)
    }

    /// Pool of `workers` independent cycle-accurate simulator replicas —
    /// software's version of deploying several accelerator boards.
    pub(crate) fn fpga_sim(
        model: &BnnModel,
        workers: usize,
        sim_cfg: SimConfig,
        cfg: BatcherConfig,
        queue_cap: usize,
    ) -> Result<WorkerPool> {
        let mut replicas: Vec<Arc<dyn InferBackend>> = Vec::new();
        for _ in 0..workers.max(1) {
            replicas.push(Arc::new(super::backend::SimBackend::new(model, sim_cfg)?));
        }
        Self::start(replicas, cfg, queue_cap)
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Number of workers (= shards = replicas).
    pub fn workers(&self) -> usize {
        self.shared.shards.len()
    }

    /// Current depth of every shard (observability / tests).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shared
            .shards
            .iter()
            .map(|s| s.queue.lock().unwrap().len())
            .collect()
    }

    /// Total queued requests across shards.
    pub fn queue_depth(&self) -> usize {
        self.queue_depths().iter().sum()
    }

    /// Round-robin refined by power-of-two-choices: compare the round-robin
    /// shard with its neighbour, take the shallower queue.
    fn pick_shard(&self) -> usize {
        let n = self.shared.shards.len();
        if n == 1 {
            return 0;
        }
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let j = (i + 1) % n;
        let di = self.shared.shards[i].queue.lock().unwrap().len();
        let dj = self.shared.shards[j].queue.lock().unwrap().len();
        if dj < di {
            j
        } else {
            i
        }
    }

    /// Enqueue one image on the least-loaded candidate shard, with explicit
    /// per-request options.
    pub fn submit_with(&self, image: Packed, opts: InferOptions) -> Result<Ticket> {
        let s = self.pick_shard();
        // width check at the door: a mismatched image must never reach a
        // shard, where it would fail everything co-batched with it (books:
        // counted as submitted AND rejected on the picked shard's ledger,
        // same as a backend rejection)
        if let Some(want) = self.expected_bits {
            if image.n_bits != want {
                for m in [self.metrics.as_ref(), self.worker_metrics[s].as_ref()] {
                    m.submitted.fetch_add(1, Ordering::Relaxed);
                    m.rejected.fetch_add(1, Ordering::Relaxed);
                }
                anyhow::bail!("image has {} bits, backend expects {want}", image.n_bits);
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let shard = &self.shared.shards[s];
        {
            let mut q = shard.queue.lock().unwrap();
            if q.len() >= self.shared.shard_cap {
                // every arrival counts as submitted, so the books keep
                // `submitted == completed + rejected` on every path
                for m in [self.metrics.as_ref(), self.worker_metrics[s].as_ref()] {
                    m.submitted.fetch_add(1, Ordering::Relaxed);
                    m.rejected.fetch_add(1, Ordering::Relaxed);
                }
                anyhow::bail!("shard {s} full ({} requests, cap {})", q.len(), self.shared.shard_cap);
            }
            q.push_back(Pending {
                req: InferRequest::with_opts(id, image, opts),
                reply: tx,
            });
        }
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.worker_metrics[s].submitted.fetch_add(1, Ordering::Relaxed);
        shard.cv.notify_one();
        Ok(Ticket::new(id, rx, self.metrics.clone()))
    }

    /// Enqueue one image; returns its [`Ticket`].
    pub fn submit(&self, image: Packed) -> Result<Ticket> {
        self.submit_with(image, InferOptions::default())
    }

    /// Blocking classify (the [`super::InferService`] default, kept as an
    /// inherent method so callers don't need the trait in scope).
    pub fn infer(&self, image: Packed) -> Result<InferResponse> {
        super::InferService::infer(self, image)
    }

    /// Submit many, wait for all (responses in submission order).
    pub fn infer_many(&self, images: Vec<Packed>) -> Result<Vec<InferResponse>> {
        super::InferService::infer_many(self, images)
    }

    /// Latency histogram merged across workers (the aggregate [`Metrics`]
    /// carries counters only — no shared histogram lock on the hot path).
    pub fn latency_snapshot(&self) -> crate::util::stats::LatencyHistogram {
        let mut h = crate::util::stats::LatencyHistogram::new();
        for m in &self.worker_metrics {
            h.merge(&m.latency_snapshot());
        }
        h
    }

    /// Pool-wide summary (aggregate counters + merged latency histogram).
    pub fn summary_line(&self) -> String {
        self.metrics.summary_line_with(&self.latency_snapshot())
    }

    /// One metrics line per worker (queue skew / stragglers at a glance).
    pub fn per_worker_report(&self) -> String {
        let mut out = String::new();
        for (w, m) in self.worker_metrics.iter().enumerate() {
            out.push_str(&format!("worker {w}: {}\n", m.summary_line()));
        }
        out
    }

    /// Stop workers; in-flight batches finish, queued work is abandoned.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for s in &self.shared.shards {
            s.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn shard_worker_loop(
    shared: Arc<PoolShared>,
    idx: usize,
    backend: Arc<dyn InferBackend>,
    agg: Arc<Metrics>,
    mine: Arc<Metrics>,
) {
    let shard = &shared.shards[idx];
    // Per-worker arenas: grow to the steady-state batch size once, then
    // every subsequent batch runs allocation-free through the backend.
    let mut scratch = InferScratch::default();
    let mut logits = LogitsBuf::new();
    loop {
        // Decide under the shard lock, execute outside it.
        let batch: Vec<Pending> = {
            let mut q = shard.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match decide(
                    q.len(),
                    q.front().map(|p| p.req.enqueued_at),
                    &shared.cfg,
                    Instant::now(),
                ) {
                    DrainDecision::Launch(n) => break q.drain(..n).collect(),
                    DrainDecision::Wait(d) => {
                        let (guard, _) = shard.cv.wait_timeout(q, d).unwrap();
                        q = guard;
                    }
                    DrainDecision::Idle => {
                        let (guard, _) = shard
                            .cv
                            .wait_timeout(q, std::time::Duration::from_millis(50))
                            .unwrap();
                        q = guard;
                    }
                }
            }
        };
        execute_batch(
            backend.as_ref(),
            Some(agg.as_ref()),
            mine.as_ref(),
            batch,
            &mut scratch,
            &mut logits,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::random_model;
    use crate::bnn::packing::pack_bits_u64;
    use crate::coordinator::server::DEFAULT_QUEUE_CAP;
    use crate::util::prng::Xoshiro256;
    use std::time::Duration;

    fn imgs(n: usize, seed: u64) -> Vec<Packed> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                Packed {
                    words: pack_bits_u64(&bits),
                    n_bits: 784,
                }
            })
            .collect()
    }

    #[test]
    fn pool_serves_and_matches_direct_inference() {
        let model = random_model(&[784, 128, 64, 10], 51);
        let pool = WorkerPool::native(
            &model,
            4,
            Kernel::default(),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        assert_eq!(pool.workers(), 4);
        let images = imgs(120, 52);
        let responses = pool.infer_many(images.clone()).unwrap();
        assert_eq!(responses.len(), 120);
        for (img, r) in images.iter().zip(&responses) {
            assert_eq!(r.logits, model.logits(&img.words), "req {}", r.id);
            assert_eq!(r.digit as usize, model.predict(&img.words));
            assert_eq!(r.backend, "native");
        }
        // no request lost or duplicated
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 120);
        pool.shutdown();
    }

    #[test]
    fn per_worker_metrics_sum_to_aggregate() {
        let model = random_model(&[784, 128, 64, 10], 53);
        let pool = WorkerPool::native(
            &model,
            3,
            Kernel::default(),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(50),
            },
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        let n = 90;
        pool.infer_many(imgs(n, 54)).unwrap();
        let agg = pool.metrics.completed.load(Ordering::Relaxed);
        let per: u64 = pool
            .worker_metrics
            .iter()
            .map(|m| m.completed.load(Ordering::Relaxed))
            .sum();
        assert_eq!(agg, n as u64);
        assert_eq!(per, agg, "per-worker completions must sum to the aggregate");
        // merged latency histogram sees every request; the aggregate
        // Metrics records counters only (no shared histogram lock)
        assert_eq!(pool.latency_snapshot().count(), n as u64);
        assert_eq!(pool.metrics.latency_snapshot().count(), 0);
        assert!(pool.summary_line().contains("completed=90"), "{}", pool.summary_line());
        // dispatch actually spreads load: more than one worker saw traffic
        let busy = pool
            .worker_metrics
            .iter()
            .filter(|m| m.completed.load(Ordering::Relaxed) > 0)
            .count();
        assert!(busy >= 2, "only {busy}/3 workers saw traffic");
        let report = pool.per_worker_report();
        assert!(report.contains("worker 0:") && report.contains("worker 2:"), "{report}");
        pool.shutdown();
    }

    #[test]
    fn all_kernel_pools_agree() {
        // every registered kernel tier must serve identical logits for the
        // same request stream (the registry keeps this exhaustive as new
        // tiers land).
        let model = random_model(&[784, 128, 64, 10], 55);
        let cfg = BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(50),
        };
        let images = imgs(30, 56);
        let scalar_pool =
            WorkerPool::native(&model, 2, Kernel::Scalar, cfg, DEFAULT_QUEUE_CAP).unwrap();
        let want = scalar_pool.infer_many(images.clone()).unwrap();
        scalar_pool.shutdown();
        let mut kernels = Kernel::registry_with(16, 4);
        kernels.push(Kernel::Blocked { block_rows: 32 });
        kernels.push(Kernel::default());
        for kernel in kernels {
            let pool = WorkerPool::native(&model, 2, kernel, cfg, DEFAULT_QUEUE_CAP).unwrap();
            let got = pool.infer_many(images.clone()).unwrap();
            for (x, y) in got.iter().zip(&want) {
                assert_eq!(x.logits, y.logits, "{kernel:?}");
                assert_eq!(x.digit, y.digit, "{kernel:?}");
            }
            pool.shutdown();
        }
    }

    #[test]
    fn single_worker_pool_degenerates_to_coordinator_semantics() {
        let model = random_model(&[784, 128, 64, 10], 57);
        let pool = WorkerPool::native(
            &model,
            1,
            Kernel::default(),
            BatcherConfig::default(),
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        assert_eq!(pool.workers(), 1);
        let r = pool.infer(imgs(1, 58).pop().unwrap()).unwrap();
        assert_eq!(r.batch_size, 1);
        assert_eq!(pool.queue_depth(), 0);
        pool.shutdown();
    }

    #[test]
    fn shutdown_terminates_workers() {
        let model = random_model(&[784, 128, 64, 10], 59);
        let pool = WorkerPool::native(
            &model,
            4,
            Kernel::Scalar,
            BatcherConfig::default(),
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        pool.shutdown(); // must not hang
    }

    #[test]
    fn pipelined_pool_ledger_balances_at_mid_drain_shutdown() {
        // ISSUE 6 satellite: burst-stress the pipelined tier (pulled from
        // the registry, not hand-built) and check the metrics ledger still
        // balances when the pool is shut down while a pipelined worker is
        // mid-drain.  Accounting contract under shutdown: in-flight
        // batches finish (counted completed), queued work is abandoned —
        // counted submitted but never completed/rejected, its waiters see
        // a disconnected reply channel.  Every ticket is waited, so
        // nothing may count cancelled.
        let kernel = *Kernel::registry()
            .iter()
            .find(|k| k.name() == "pipelined")
            .expect("registry carries the pipelined tier");
        let model = random_model(&[784, 128, 64, 10], 63);
        let pool = WorkerPool::native(
            &model,
            1, // one worker: the burst must outrun a single drain loop
            kernel,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(10),
            },
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        let n = 256usize;
        let mut tickets = Vec::with_capacity(n);
        for img in imgs(n, 64) {
            tickets.push(pool.submit(img).unwrap());
        }
        // resolve a handful, then pull the plug with the rest in flight
        let mut completed_seen = 0u64;
        for t in tickets.drain(..4) {
            t.wait().unwrap();
            completed_seen += 1;
        }
        let metrics = Arc::clone(&pool.metrics);
        pool.shutdown();
        // classify every remaining ticket: executed before the stop flag
        // (reply delivered → Ok) or abandoned on the shard queue (reply
        // sender dropped → Err).  wait() resolves the ticket either way,
        // so none of these may be counted cancelled.
        let mut abandoned = 0u64;
        for t in tickets {
            match t.wait() {
                Ok(_) => completed_seen += 1,
                Err(_) => abandoned += 1,
            }
        }
        let submitted = metrics.submitted.load(Ordering::Relaxed);
        let completed = metrics.completed.load(Ordering::Relaxed);
        let rejected = metrics.rejected.load(Ordering::Relaxed);
        let cancelled = metrics.cancelled.load(Ordering::Relaxed);
        assert_eq!(submitted, n as u64, "every burst submit is counted");
        assert_eq!(rejected, 0, "well-formed images are never rejected");
        assert_eq!(cancelled, 0, "waited tickets must not count cancelled");
        assert_eq!(
            completed, completed_seen,
            "completed counter must match delivered replies"
        );
        assert_eq!(
            submitted,
            completed + rejected + abandoned,
            "ledger must balance at mid-drain shutdown \
             (submitted == completed + rejected + abandoned)"
        );
    }

    #[test]
    fn size_mismatched_image_is_rejected_not_fatal() {
        // A wrong-width image must surface as an Err at submit time
        // (expected_bits gate — it never reaches a shard, so it can't
        // poison a co-scheduled batch), and the worker keeps serving
        // well-formed requests afterwards.
        let model = random_model(&[784, 128, 64, 10], 61);
        let pool = WorkerPool::native(
            &model,
            1,
            Kernel::default(),
            BatcherConfig::default(),
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        let bad = Packed::from_bits(&vec![1u8; 100]); // 100 ≠ 784 bits
        assert!(pool.infer(bad).is_err(), "mismatched image must error");
        let good = imgs(1, 62).pop().unwrap();
        let r = pool.infer(good.clone()).unwrap();
        assert_eq!(r.logits, model.logits(&good.words), "worker must still serve");
        pool.shutdown();
    }
}
