//! Serving metrics: counters + latency histograms, cheaply shareable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::LatencyHistogram;

/// Coordinator-wide metrics.  Counters are lock-free; histograms take a
/// short mutex on record (off the per-bit hot path — one lock per batch).
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// Tickets dropped by the caller before resolving (drop-to-cancel —
    /// see [`super::request::Ticket`]).  A client-side signal: the request
    /// may still have executed, so this is tracked *alongside* the
    /// `submitted == completed + rejected` balance, not inside it.
    pub cancelled: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Connection gauges, maintained by the wire servers (both blocking and
    /// async): every accepted socket increments `conn_accepted`; admitted
    /// ones hold `conn_open` until teardown, which moves them to
    /// `conn_closed`.  Invariant at any quiescent point:
    /// `conn_accepted == conn_closed + conn_open` (see
    /// [`Self::conn_books_balance`]).
    pub conn_accepted: AtomicU64,
    pub conn_open: AtomicU64,
    pub conn_closed: AtomicU64,
    /// Times a supervised worker was rebuilt after a panic (pool and
    /// single-queue cores both count here; per-worker metrics carry each
    /// worker's own restarts).  A crashed batch's requests count `rejected`
    /// — this gauge tracks the *worker* lifecycle, not the request ledger.
    pub worker_restarts: AtomicU64,
    /// Requests shed because their [`super::request::InferOptions::deadline`]
    /// passed before execution.  Each one also counts `rejected` (the shed
    /// request resolved with a typed error), so the ledger still balances;
    /// this gauge splits deadline sheds out of generic rejection.
    pub deadline_expired: AtomicU64,
    /// Client-side retry attempts (bounded backoff on Overloaded/Timeout)
    /// booked by front ends that own a [`Metrics`]; serving cores never
    /// touch it.
    pub retries_attempted: AtomicU64,
    latency: Mutex<LatencyHistogram>,
    queue_wait: Mutex<LatencyHistogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// `conn_accepted == conn_closed + conn_open` — true whenever no
    /// accept/teardown is mid-flight (the servers update the gauges with
    /// `SeqCst` ordering, accepted first, so the books only ever lag by a
    /// connection that is actively being admitted or torn down).
    pub fn conn_books_balance(&self) -> bool {
        self.conn_accepted.load(Ordering::SeqCst)
            == self.conn_closed.load(Ordering::SeqCst) + self.conn_open.load(Ordering::SeqCst)
    }

    pub fn record_batch(&self, batch_size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(batch_size as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, ns: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().record(ns);
    }

    pub fn record_queue_wait(&self, ns: u64) {
        self.queue_wait.lock().unwrap().record(ns);
    }

    pub fn latency_snapshot(&self) -> LatencyHistogram {
        self.latency.lock().unwrap().clone()
    }

    pub fn queue_wait_snapshot(&self) -> LatencyHistogram {
        self.queue_wait.lock().unwrap().clone()
    }

    /// p50 end-to-end request latency (ns), from the shared histogram the
    /// async event loop books per resolved slot.
    pub fn latency_p50_ns(&self) -> u64 {
        self.latency.lock().unwrap().percentile_ns(50.0)
    }

    /// p99 end-to-end request latency (ns).
    pub fn latency_p99_ns(&self) -> u64 {
        self.latency.lock().unwrap().percentile_ns(99.0)
    }

    /// p50 queue wait (ns): submit → batch-pickup, the admission-pressure
    /// signal (distinct from latency, which includes compute).
    pub fn queue_wait_p50_ns(&self) -> u64 {
        self.queue_wait.lock().unwrap().percentile_ns(50.0)
    }

    /// p99 queue wait (ns).
    pub fn queue_wait_p99_ns(&self) -> u64 {
        self.queue_wait.lock().unwrap().percentile_ns(99.0)
    }

    /// Mean requests per executed batch — the batching efficiency signal.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn summary_line(&self) -> String {
        self.summary_line_with(&self.latency_snapshot())
    }

    /// Summary with an externally-supplied latency histogram — the worker
    /// pool merges per-worker histograms instead of locking a shared one on
    /// the hot path.
    pub fn summary_line_with(&self, lat: &LatencyHistogram) -> String {
        format!(
            "submitted={} completed={} rejected={} cancelled={} batches={} mean_batch={:.2} \
             restarts={} deadline_expired={} retries={} p50={}µs p99={}µs max={}µs",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.cancelled.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.worker_restarts.load(Ordering::Relaxed),
            self.deadline_expired.load(Ordering::Relaxed),
            self.retries_attempted.load(Ordering::Relaxed),
            lat.percentile_ns(50.0) / 1000,
            lat.percentile_ns(99.0) / 1000,
            lat.max_ns() / 1000,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_efficiency() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_accessors_track_both_histograms() {
        let m = Metrics::new();
        // 9 fast requests + 1 slow one: p50 stays in the fast buckets,
        // p99 lands in the slow one (log2 buckets: upper bound ≥ sample)
        for _ in 0..9 {
            m.record_latency(1_000);
            m.record_queue_wait(500);
        }
        m.record_latency(4_000_000);
        m.record_queue_wait(2_000_000);
        assert!(m.latency_p50_ns() <= 2_048, "{}", m.latency_p50_ns());
        assert!(m.latency_p99_ns() >= 4_000_000, "{}", m.latency_p99_ns());
        assert!(m.queue_wait_p50_ns() <= 1_024, "{}", m.queue_wait_p50_ns());
        assert!(m.queue_wait_p99_ns() >= 2_000_000, "{}", m.queue_wait_p99_ns());
        assert!(m.latency_p50_ns() <= m.latency_p99_ns());
    }

    #[test]
    fn latency_flow() {
        let m = Metrics::new();
        m.submitted.fetch_add(2, Ordering::Relaxed);
        m.record_latency(1_000);
        m.record_latency(2_000);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.latency_snapshot().count(), 2);
        let line = m.summary_line();
        assert!(line.contains("completed=2"), "{line}");
    }

    #[test]
    fn fault_counters_surface_in_the_summary() {
        let m = Metrics::new();
        m.worker_restarts.fetch_add(2, Ordering::Relaxed);
        m.deadline_expired.fetch_add(5, Ordering::Relaxed);
        m.retries_attempted.fetch_add(7, Ordering::Relaxed);
        let line = m.summary_line();
        assert!(line.contains("restarts=2"), "{line}");
        assert!(line.contains("deadline_expired=5"), "{line}");
        assert!(line.contains("retries=7"), "{line}");
    }
}
