//! The public serving API: [`Engine`] and its typed builder — the **one**
//! construction path for every serving topology (PR 4 API redesign).
//!
//! ```
//! use bnn_fpga::bnn::model::random_model;
//! use bnn_fpga::bnn::Packed;
//! use bnn_fpga::coordinator::{BatcherConfig, Engine, Kernel};
//!
//! # fn main() -> anyhow::Result<()> {
//! let model = random_model(&[784, 128, 64, 10], 1);
//! let engine = Engine::builder()
//!     .native(&model)
//!     .kernel(Kernel::default())
//!     .workers(4)
//!     .batcher(BatcherConfig::default())
//!     .queue_cap(50_000)
//!     .build()?;
//! let ticket = engine.submit(Packed::from_bits(&vec![1u8; 784]))?;
//! let response = ticket.wait()?;
//! assert!(response.digit < 10);
//! engine.shutdown();
//! # Ok(())
//! # }
//! ```
//!
//! The builder picks the right core for the backend spec:
//!
//! * [`EngineBuilder::native`] / [`EngineBuilder::fpga_sim`] /
//!   [`EngineBuilder::replicas`] — the sharded [`WorkerPool`] (one queue
//!   shard + one backend replica per worker, the scaling path);
//! * [`EngineBuilder::shared`] — the single-queue [`Coordinator`] (N
//!   workers draining one queue into **one** shared backend; right for
//!   PJRT, whose engine serializes dispatch anyway).
//!
//! Both cores speak the same [`super::InferService`] contract, so
//! everything above them — wire server, router, load drivers — is
//! topology-blind.

use std::sync::Arc;

use anyhow::Result;

use super::backend::{InferBackend, Kernel, NativeBackend, SimBackend};
use super::batcher::BatcherConfig;
use super::chaos::{ChaosBackend, ChaosConfig};
use super::metrics::Metrics;
use super::pool::{RestartPolicy, WorkerPool};
use super::request::{InferOptions, InferResponse, Ticket};
use super::server::{Coordinator, DEFAULT_QUEUE_CAP};
use crate::bnn::packing::Packed;
use crate::bnn::BnnModel;
use crate::sim::SimConfig;
use crate::util::stats::LatencyHistogram;

/// What an [`Engine`] runs on.  Usually constructed through the named
/// builder methods ([`EngineBuilder::native`] etc.); the `From` impls let
/// `.backend(...)` accept a backend `Arc` or a replica list directly.
pub enum BackendSpec {
    /// One shared backend behind a single queue (the [`Coordinator`] core).
    Shared(Arc<dyn InferBackend>),
    /// Explicit per-worker replicas (the [`WorkerPool`] core; one worker
    /// per replica).
    Replicas(Vec<Arc<dyn InferBackend>>),
    /// Native replicas cloned from this model, shaped by the builder's
    /// [`Kernel`] (the [`WorkerPool`] core).
    Native(BnnModel),
    /// Cycle-accurate simulator replicas (the [`WorkerPool`] core) — the
    /// software version of deploying several accelerator boards.
    FpgaSim(BnnModel, SimConfig),
}

impl From<Arc<dyn InferBackend>> for BackendSpec {
    fn from(backend: Arc<dyn InferBackend>) -> Self {
        BackendSpec::Shared(backend)
    }
}

impl From<Vec<Arc<dyn InferBackend>>> for BackendSpec {
    fn from(replicas: Vec<Arc<dyn InferBackend>>) -> Self {
        BackendSpec::Replicas(replicas)
    }
}

impl From<&BnnModel> for BackendSpec {
    fn from(model: &BnnModel) -> Self {
        BackendSpec::Native(model.clone())
    }
}

/// Typed builder for [`Engine`] — see the module docs for the shape of a
/// typical call chain.  Defaults: 1 worker, [`Kernel::default`],
/// [`BatcherConfig::default`], [`DEFAULT_QUEUE_CAP`].
pub struct EngineBuilder {
    spec: Option<BackendSpec>,
    kernel: Kernel,
    workers: Option<usize>,
    batcher: BatcherConfig,
    queue_cap: usize,
    chaos: Option<ChaosConfig>,
    restart: RestartPolicy,
}

impl EngineBuilder {
    fn new() -> Self {
        Self {
            spec: None,
            kernel: Kernel::default(),
            workers: None,
            batcher: BatcherConfig::default(),
            queue_cap: DEFAULT_QUEUE_CAP,
            chaos: None,
            restart: RestartPolicy::default(),
        }
    }

    /// Set the backend spec directly (see the `From` impls on
    /// [`BackendSpec`]); the named methods below are usually clearer.
    pub fn backend(mut self, spec: impl Into<BackendSpec>) -> Self {
        self.spec = Some(spec.into());
        self
    }

    /// Native bit-packed replicas of `model`, one per worker, running the
    /// builder's [`Self::kernel`].
    pub fn native(self, model: &BnnModel) -> Self {
        self.backend(BackendSpec::Native(model.clone()))
    }

    /// One shared backend behind a single queue (`workers` threads drain
    /// it) — the PJRT topology.
    pub fn shared(self, backend: Arc<dyn InferBackend>) -> Self {
        self.backend(BackendSpec::Shared(backend))
    }

    /// Explicit per-worker replicas; the worker count is the list length.
    pub fn replicas(self, replicas: Vec<Arc<dyn InferBackend>>) -> Self {
        self.backend(BackendSpec::Replicas(replicas))
    }

    /// Cycle-accurate FPGA-simulator replicas, one per worker.
    pub fn fpga_sim(self, model: &BnnModel, sim_cfg: SimConfig) -> Self {
        self.backend(BackendSpec::FpgaSim(model.clone(), sim_cfg))
    }

    /// Native kernel tier (ignored by non-native specs).  For
    /// [`Kernel::Fused`], `build()` also prepares the fused panel weight
    /// layout ([`crate::bnn::PreparedModel`]) — one re-layout per replica
    /// at build time, never on the request path.
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Worker threads (sharded cores: also the replica count).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    /// Dynamic-batching policy.
    pub fn batcher(mut self, cfg: BatcherConfig) -> Self {
        self.batcher = cfg;
        self
    }

    /// Backpressure bound: submits fail once this many requests are queued
    /// (per shard on the sharded core).
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }

    /// Wrap every backend (replica) in a [`ChaosBackend`] running this
    /// seeded fault plan — the chaos-soak hook (tests, `loadgen --chaos-*`).
    pub fn chaos(mut self, cfg: ChaosConfig) -> Self {
        self.chaos = Some(cfg);
        self
    }

    /// Worker supervision policy: how many consecutive crashes a worker
    /// may take (with what backoff) before its shard is declared dead.
    pub fn restart_policy(mut self, policy: RestartPolicy) -> Self {
        self.restart = policy;
        self
    }

    /// Validate and start the engine (spawns the worker threads).
    pub fn build(self) -> Result<Engine> {
        let spec = self.spec.ok_or_else(|| {
            anyhow::anyhow!(
                "Engine::builder() needs a backend: call .native(), .shared(), \
                 .replicas() or .fpga_sim() before .build()"
            )
        })?;
        anyhow::ensure!(self.queue_cap >= 1, "queue_cap must be ≥ 1");
        self.batcher.validate()?;
        self.kernel.validate()?;
        if let Some(w) = self.workers {
            anyhow::ensure!(w >= 1, "workers must be ≥ 1");
        }
        let workers = self.workers.unwrap_or(1);
        // the chaos hook decorates every backend the engine will run, so
        // the fault plan applies uniformly across replicas/the shared core
        let chaos = self.chaos.clone();
        let wrap = |b: Arc<dyn InferBackend>| -> Arc<dyn InferBackend> {
            match &chaos {
                Some(cfg) => Arc::new(ChaosBackend::new(b, cfg.clone())),
                None => b,
            }
        };
        let core = match spec {
            BackendSpec::Native(model) => {
                let pool = if chaos.is_some() {
                    let replicas: Vec<Arc<dyn InferBackend>> = (0..workers)
                        .map(|_| {
                            wrap(Arc::new(NativeBackend::with_kernel(
                                model.clone(),
                                self.kernel,
                            )))
                        })
                        .collect();
                    WorkerPool::start_supervised(replicas, self.batcher, self.queue_cap, self.restart)?
                } else {
                    WorkerPool::native_supervised(
                        &model,
                        workers,
                        self.kernel,
                        self.batcher,
                        self.queue_cap,
                        self.restart,
                    )?
                };
                EngineCore::Sharded(pool)
            }
            BackendSpec::FpgaSim(model, sim_cfg) => {
                let pool = if chaos.is_some() {
                    let mut replicas: Vec<Arc<dyn InferBackend>> = Vec::new();
                    for _ in 0..workers {
                        replicas.push(wrap(Arc::new(SimBackend::new(&model, sim_cfg)?)));
                    }
                    WorkerPool::start_supervised(replicas, self.batcher, self.queue_cap, self.restart)?
                } else {
                    WorkerPool::fpga_sim_supervised(
                        &model,
                        workers,
                        sim_cfg,
                        self.batcher,
                        self.queue_cap,
                        self.restart,
                    )?
                };
                EngineCore::Sharded(pool)
            }
            BackendSpec::Replicas(replicas) => {
                if let Some(w) = self.workers {
                    anyhow::ensure!(
                        w == replicas.len(),
                        "workers({w}) conflicts with {} explicit replicas — drop .workers() \
                         or make the counts match",
                        replicas.len()
                    );
                }
                let replicas = replicas.into_iter().map(wrap).collect();
                EngineCore::Sharded(WorkerPool::start_supervised(
                    replicas,
                    self.batcher,
                    self.queue_cap,
                    self.restart,
                )?)
            }
            BackendSpec::Shared(backend) => EngineCore::Single(Coordinator::start_supervised(
                wrap(backend),
                self.batcher,
                workers,
                self.queue_cap,
                self.restart,
            )?),
        };
        Ok(Engine { core })
    }
}

enum EngineCore {
    Single(Coordinator),
    Sharded(WorkerPool),
}

/// A running serving engine (workers spawned, queue live).  Construct with
/// [`Engine::builder`]; submit through [`Engine::submit`]/[`Engine::infer`]
/// or the [`super::InferService`] trait.
pub struct Engine {
    core: EngineCore,
}

impl Engine {
    /// Start building an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Enqueue one image with explicit per-request options.
    pub fn submit_with(&self, image: Packed, opts: InferOptions) -> Result<Ticket> {
        match &self.core {
            EngineCore::Single(c) => c.submit_with(image, opts),
            EngineCore::Sharded(p) => p.submit_with(image, opts),
        }
    }

    // Inherent mirrors of the `InferService` defaults (so callers don't
    // need the trait in scope) — one implementation, in the trait.

    /// Enqueue one image; returns its [`Ticket`].
    pub fn submit(&self, image: Packed) -> Result<Ticket> {
        super::InferService::submit(self, image)
    }

    /// Blocking classify.
    pub fn infer(&self, image: Packed) -> Result<InferResponse> {
        super::InferService::infer(self, image)
    }

    /// Blocking classify with options.
    pub fn infer_with(&self, image: Packed, opts: InferOptions) -> Result<InferResponse> {
        super::InferService::infer_with(self, image, opts)
    }

    /// Submit many, wait for all (responses in submission order).
    pub fn infer_many(&self, images: Vec<Packed>) -> Result<Vec<InferResponse>> {
        super::InferService::infer_many(self, images)
    }

    /// Engine-wide aggregate metrics.
    pub fn metrics(&self) -> &Arc<Metrics> {
        match &self.core {
            EngineCore::Single(c) => &c.metrics,
            EngineCore::Sharded(p) => &p.metrics,
        }
    }

    /// Per-worker metrics (sharded core only; empty for the single queue).
    pub fn worker_metrics(&self) -> &[Arc<Metrics>] {
        match &self.core {
            EngineCore::Single(_) => &[],
            EngineCore::Sharded(p) => &p.worker_metrics,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        match &self.core {
            EngineCore::Single(c) => c.backend_name(),
            EngineCore::Sharded(p) => p.backend_name(),
        }
    }

    /// Worker thread count.
    pub fn workers(&self) -> usize {
        match &self.core {
            EngineCore::Single(c) => c.workers(),
            EngineCore::Sharded(p) => p.workers(),
        }
    }

    /// Total queued requests (across shards on the sharded core).
    pub fn queue_depth(&self) -> usize {
        match &self.core {
            EngineCore::Single(c) => c.queue_depth(),
            EngineCore::Sharded(p) => p.queue_depth(),
        }
    }

    /// Latency histogram: the single queue's own, or the per-worker
    /// histograms merged (the pool aggregate records counters only — no
    /// shared histogram lock on the hot path).
    pub fn latency_snapshot(&self) -> LatencyHistogram {
        match &self.core {
            EngineCore::Single(c) => c.metrics.latency_snapshot(),
            EngineCore::Sharded(p) => p.latency_snapshot(),
        }
    }

    /// One-line metrics summary (latency from [`Self::latency_snapshot`]).
    pub fn summary_line(&self) -> String {
        match &self.core {
            EngineCore::Single(c) => c.metrics.summary_line(),
            EngineCore::Sharded(p) => p.summary_line(),
        }
    }

    /// One metrics line per worker (sharded core only).
    pub fn per_worker_report(&self) -> Option<String> {
        match &self.core {
            EngineCore::Single(_) => None,
            EngineCore::Sharded(p) => Some(p.per_worker_report()),
        }
    }

    /// Stop workers; in-flight batches finish, queued work is abandoned.
    pub fn shutdown(self) {
        match self.core {
            EngineCore::Single(c) => c.shutdown(),
            EngineCore::Sharded(p) => p.shutdown(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::random_model;
    use crate::bnn::packing::pack_bits_u64;
    use crate::coordinator::backend::{InferScratch, LogitsBuf, NativeBackend};
    use crate::util::prng::Xoshiro256;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Condvar, Mutex};
    use std::time::Duration;

    fn imgs(n: usize, seed: u64) -> Vec<Packed> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                Packed {
                    words: pack_bits_u64(&bits),
                    n_bits: 784,
                }
            })
            .collect()
    }

    #[test]
    fn builder_requires_a_backend_and_sane_knobs() {
        assert!(Engine::builder().build().is_err(), "no backend must fail");
        let model = random_model(&[784, 32, 10], 71);
        assert!(Engine::builder().native(&model).queue_cap(0).build().is_err());
        assert!(Engine::builder().native(&model).workers(0).build().is_err());
        assert!(Engine::builder()
            .native(&model)
            .kernel(Kernel::Blocked { block_rows: 0 })
            .build()
            .is_err());
        assert!(Engine::builder()
            .native(&model)
            .batcher(BatcherConfig {
                max_batch: 0,
                max_wait: Duration::from_micros(1),
            })
            .build()
            .is_err());
        // explicit replicas conflicting with .workers() is a build error
        let replicas: Vec<Arc<dyn InferBackend>> = (0..2)
            .map(|_| -> Arc<dyn InferBackend> { Arc::new(NativeBackend::new(model.clone())) })
            .collect();
        assert!(Engine::builder().replicas(replicas).workers(3).build().is_err());
    }

    #[test]
    fn sharded_and_single_cores_agree_with_direct_inference() {
        let model = random_model(&[784, 128, 64, 10], 72);
        let images = imgs(40, 73);
        let sharded = Engine::builder()
            .native(&model)
            .kernel(Kernel::default())
            .workers(3)
            .batcher(BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .unwrap();
        assert_eq!(sharded.workers(), 3);
        assert_eq!(sharded.backend_name(), "native");
        assert_eq!(sharded.worker_metrics().len(), 3);
        let single = Engine::builder()
            .shared(Arc::new(NativeBackend::new(model.clone())))
            .workers(2)
            .build()
            .unwrap();
        assert_eq!(single.workers(), 2);
        assert!(single.worker_metrics().is_empty());
        assert!(single.per_worker_report().is_none());
        for engine in [&sharded, &single] {
            let responses = engine.infer_many(images.clone()).unwrap();
            for (img, r) in images.iter().zip(&responses) {
                assert_eq!(r.logits, model.logits(&img.words));
                assert_eq!(r.digit as usize, model.predict(&img.words));
            }
            assert_eq!(
                engine.metrics().completed.load(Ordering::Relaxed),
                images.len() as u64
            );
            assert_eq!(engine.latency_snapshot().count(), images.len() as u64);
            assert!(engine.summary_line().contains("completed=40"));
        }
        sharded.shutdown();
        single.shutdown();
    }

    #[test]
    fn fused_engine_prepares_at_build_and_serves() {
        // Kernel::Fused through the one public construction path: the
        // panel re-layout happens inside build(), and the served logits
        // are bit-identical to the direct scalar reference.
        let model = random_model(&[784, 128, 64, 10], 85);
        let engine = Engine::builder()
            .native(&model)
            .kernel(Kernel::Fused { tile_imgs: 8 })
            .workers(2)
            .batcher(BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            })
            .build()
            .unwrap();
        let images = imgs(24, 86);
        let responses = engine.infer_many(images.clone()).unwrap();
        for (img, r) in images.iter().zip(&responses) {
            assert_eq!(r.logits, model.logits(&img.words));
            assert_eq!(r.digit as usize, model.predict(&img.words));
        }
        engine.shutdown();
    }

    #[test]
    fn options_flow_through_the_engine() {
        let model = random_model(&[784, 64, 10], 74);
        let engine = Engine::builder().native(&model).workers(2).build().unwrap();
        let img = imgs(1, 75).pop().unwrap();
        let want = model.logits(&img.words);
        let r = engine
            .infer_with(img.clone(), InferOptions::digits_only().with_top_k(3))
            .unwrap();
        assert!(r.logits.is_empty(), "digits_only suppresses the logits copy");
        assert_eq!(r.top_k, crate::coordinator::request::top_k_i32(&want, 3));
        assert_eq!(r.top_k[0].0, r.digit);
        engine.shutdown();
    }

    #[test]
    fn dropped_ticket_is_counted_cancelled() {
        let model = random_model(&[784, 32, 10], 76);
        let engine = Engine::builder().native(&model).workers(1).build().unwrap();
        let mut two = imgs(2, 77);
        let abandoned = engine.submit(two.pop().unwrap()).unwrap();
        drop(abandoned);
        assert_eq!(engine.metrics().cancelled.load(Ordering::Relaxed), 1);
        // a waited request is not a cancel
        engine.infer(two.pop().unwrap()).unwrap();
        assert_eq!(engine.metrics().cancelled.load(Ordering::Relaxed), 1);
        engine.shutdown();
    }

    /// Backend that blocks inside `infer_batch` until the test opens its
    /// gate — makes queue-overflow rejection deterministic.
    struct GateBackend {
        gate: Mutex<bool>,
        cv: Condvar,
        entered: AtomicU64,
    }

    impl GateBackend {
        fn new() -> Self {
            Self {
                gate: Mutex::new(false),
                cv: Condvar::new(),
                entered: AtomicU64::new(0),
            }
        }

        fn open(&self) {
            *self.gate.lock().unwrap() = true;
            self.cv.notify_all();
        }
    }

    impl InferBackend for GateBackend {
        fn name(&self) -> &'static str {
            "gate"
        }

        fn max_batch(&self) -> usize {
            1
        }

        fn infer_batch(
            &self,
            images: &[&Packed],
            _scratch: &mut InferScratch,
            out: &mut LogitsBuf,
        ) -> Result<()> {
            self.entered.fetch_add(1, Ordering::SeqCst);
            let mut open = self.gate.lock().unwrap();
            while !*open {
                open = self.cv.wait(open).unwrap();
            }
            drop(open);
            out.reset(images.len(), 10);
            Ok(())
        }
    }

    #[test]
    fn tiny_queue_cap_rejects_and_counts_deterministically() {
        // worker 0 blocks in the gate backend holding request 1; the
        // 2-slot queue then absorbs exactly two more submits, and every
        // further submit must be rejected with the rejection counted.
        let backend = Arc::new(GateBackend::new());
        let engine = Engine::builder()
            .shared(backend.clone())
            .workers(1)
            .batcher(BatcherConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
            })
            .queue_cap(2)
            .build()
            .unwrap();
        let mut pool = imgs(6, 78).into_iter();
        let t1 = engine.submit(pool.next().unwrap()).unwrap();
        // wait until the worker is provably inside the backend (its request
        // has left the queue)
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while backend.entered.load(Ordering::SeqCst) == 0 {
            assert!(std::time::Instant::now() < deadline, "worker never started");
            std::thread::sleep(Duration::from_millis(1));
        }
        let t2 = engine.submit(pool.next().unwrap()).unwrap();
        let t3 = engine.submit(pool.next().unwrap()).unwrap();
        // queue is now full at the cap: the rest must bounce
        for img in pool {
            assert!(engine.submit(img).is_err(), "over-cap submit must fail");
        }
        let m = engine.metrics();
        assert_eq!(m.rejected.load(Ordering::Relaxed), 3);
        // bounced arrivals still count as submitted, keeping the books
        // balanced on the rejection path too
        assert_eq!(m.submitted.load(Ordering::Relaxed), 6);
        backend.open();
        for t in [t1, t2, t3] {
            t.wait().unwrap();
        }
        assert_eq!(m.completed.load(Ordering::Relaxed), 3);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
        assert_eq!(
            m.submitted.load(Ordering::Relaxed),
            m.completed.load(Ordering::Relaxed) + m.rejected.load(Ordering::Relaxed),
            "books must balance even across queue-full rejections"
        );
        engine.shutdown();
    }

    #[test]
    fn backend_failure_is_rejected_not_cancelled() {
        struct FailBackend;
        impl InferBackend for FailBackend {
            fn name(&self) -> &'static str {
                "fail"
            }
            fn max_batch(&self) -> usize {
                1
            }
            fn infer_batch(
                &self,
                _images: &[&Packed],
                _scratch: &mut InferScratch,
                _out: &mut LogitsBuf,
            ) -> Result<()> {
                anyhow::bail!("injected failure")
            }
        }
        let engine = Engine::builder()
            .shared(Arc::new(FailBackend))
            .workers(1)
            .build()
            .unwrap();
        // infer_many over a failing backend: every ticket still resolves,
        // so the books say rejected — never phantom client cancellations
        assert!(engine.infer_many(imgs(4, 81)).is_err());
        let m = engine.metrics();
        assert_eq!(m.submitted.load(Ordering::Relaxed), 4);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 4);
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 0);
        engine.shutdown();
    }

    #[test]
    fn mismatched_width_is_rejected_at_submit_time() {
        // the expected_bits gate: a wrong-width image errors at submit —
        // it never reaches a queue where it could fail a co-scheduled
        // batch — and the books stay balanced
        let model = random_model(&[784, 32, 10], 82);
        let engine = Engine::builder().native(&model).workers(2).build().unwrap();
        let narrow = Packed::from_bits(&vec![1u8; 64]);
        assert!(engine.submit(narrow).is_err());
        let m = engine.metrics();
        assert_eq!(m.submitted.load(Ordering::Relaxed), 1);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 1);
        // per-worker ledgers carry the rejection too
        let per: u64 = engine
            .worker_metrics()
            .iter()
            .map(|w| w.rejected.load(Ordering::Relaxed))
            .sum();
        assert_eq!(per, 1);
        // well-formed traffic is unaffected
        let good = imgs(1, 83).pop().unwrap();
        assert_eq!(
            engine.infer(good.clone()).unwrap().digit as usize,
            model.predict(&good.words)
        );
        engine.shutdown();
    }

    #[test]
    fn chaos_and_restart_policy_flow_through_the_builder() {
        use crate::coordinator::chaos::{ChaosConfig, FaultKind};
        use crate::coordinator::pool::RestartPolicy;
        let model = random_model(&[784, 32, 10], 90);
        let engine = Engine::builder()
            .native(&model)
            .workers(1)
            .chaos(ChaosConfig::new(3, 1.0).with_kinds(&[FaultKind::Panic]))
            .restart_policy(RestartPolicy {
                max_restarts: 2,
                ..Default::default()
            })
            .build()
            .unwrap();
        assert_eq!(engine.backend_name(), "chaos", "replicas are wrapped");
        // every call panics: 2 supervised restarts, then the third crash
        // kills the shard; every ticket still resolves typed and the
        // books balance on the all-rejected path
        for img in imgs(8, 91) {
            match engine.submit(img) {
                Ok(t) => assert!(t.wait().is_err()),
                Err(e) => assert!(format!("{e:#}").contains("worker crashed"), "{e:#}"),
            }
        }
        let m = engine.metrics();
        assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 2);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 8);
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 8);
        engine.shutdown();
    }

    #[test]
    fn fpga_sim_spec_builds_a_replica_pool() {
        let model = random_model(&[784, 32, 10], 79);
        let engine = Engine::builder()
            .fpga_sim(&model, crate::sim::SimConfig::new(64, crate::sim::MemStyle::Bram))
            .workers(2)
            .build()
            .unwrap();
        assert_eq!(engine.backend_name(), "fpga-sim");
        let img = imgs(1, 80).pop().unwrap();
        let r = engine.infer(img.clone()).unwrap();
        assert_eq!(r.digit as usize, model.predict(&img.words));
        // the simulated hardware is single-image: batches of 1 regardless
        // of the default batcher (max_batch clamped to the replica's 1)
        assert_eq!(r.batch_size, 1);
        engine.shutdown();
    }
}
