//! Request router over named serving engines.
//!
//! Policies:
//! * **Named** — caller pins an engine (`route("fpga-sim", …)`);
//! * **LeastQueue** — default routing picks the engine with the shallowest
//!   queue (ties → first registered), the standard load-balancing policy
//!   for heterogeneous backends.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::engine::Engine;
use super::request::InferResponse;
use crate::bnn::packing::Packed;

/// A named collection of serving engines (each built with
/// [`Engine::builder`]).
#[derive(Default)]
pub struct Router {
    backends: BTreeMap<String, Engine>,
    order: Vec<String>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, name: &str, engine: Engine) -> &mut Self {
        if self.backends.insert(name.to_string(), engine).is_none() {
            self.order.push(name.to_string());
        }
        self
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn get(&self, name: &str) -> Result<&Engine> {
        self.backends
            .get(name)
            .with_context(|| format!("no backend '{name}' (have: {:?})", self.order))
    }

    /// Route to a named engine.
    pub fn route(&self, name: &str, image: Packed) -> Result<InferResponse> {
        self.get(name)?.infer(image)
    }

    /// Route by least queue depth.
    pub fn route_least_queue(&self, image: Packed) -> Result<InferResponse> {
        if self.order.is_empty() {
            bail!("router has no backends");
        }
        let name = self
            .order
            .iter()
            .min_by_key(|n| self.backends[*n].queue_depth())
            .unwrap();
        self.backends[name].infer(image)
    }

    /// Aggregate metrics lines per engine.
    pub fn metrics_report(&self) -> String {
        let mut out = String::new();
        for n in &self.order {
            out.push_str(&format!("{n}: {}\n", self.backends[n].summary_line()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::model_from_sign_rows;
    use crate::bnn::packing::pack_bits_u64;
    use crate::coordinator::{BatcherConfig, Kernel};
    use crate::util::prng::Xoshiro256;

    fn setup() -> (Router, crate::bnn::BnnModel) {
        let mut rng = Xoshiro256::new(41);
        let dims = [784usize, 128, 64, 10];
        let mut spec = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let rows: Vec<Vec<i8>> = (0..w[1])
                .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            spec.push((rows, (li + 2 < dims.len()).then(|| vec![0i32; w[1]])));
        }
        let model = model_from_sign_rows(spec).unwrap();
        let mut router = Router::new();
        for name in ["a", "b"] {
            router.register(
                name,
                Engine::builder()
                    .native(&model)
                    .kernel(Kernel::Scalar)
                    .workers(1)
                    .batcher(BatcherConfig::default())
                    .build()
                    .unwrap(),
            );
        }
        (router, model)
    }

    fn img(seed: u64) -> Packed {
        let mut rng = Xoshiro256::new(seed);
        let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
        Packed {
            words: pack_bits_u64(&bits),
            n_bits: 784,
        }
    }

    #[test]
    fn named_routing_and_errors() {
        let (router, model) = setup();
        let image = img(5);
        let r = router.route("a", image.clone()).unwrap();
        assert_eq!(r.digit as usize, model.predict(&image.words));
        assert!(router.route("zzz", image).is_err());
        assert_eq!(router.names(), &["a", "b"]);
    }

    #[test]
    fn least_queue_serves_all() {
        let (router, model) = setup();
        for seed in 0..20 {
            let image = img(seed);
            let r = router.route_least_queue(image.clone()).unwrap();
            assert_eq!(r.digit as usize, model.predict(&image.words));
        }
        // both engines must have seen traffic counters (routing totals add up)
        let total: u64 = ["a", "b"]
            .iter()
            .map(|n| {
                router.get(n).unwrap().metrics().completed
                    .load(std::sync::atomic::Ordering::Relaxed)
            })
            .sum();
        assert_eq!(total, 20);
        let report = router.metrics_report();
        assert!(report.contains("a:") && report.contains("b:"));
    }
}
