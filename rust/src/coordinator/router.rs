//! Request routing over named serving engines: the static [`Router`] and
//! the hot-swappable [`ModelRegistry`].
//!
//! [`Router`] policies:
//! * **Named** — caller pins an engine (`route("fpga-sim", …)`);
//! * **LeastQueue** — default routing picks the engine with the shallowest
//!   queue (ties → first registered), the standard load-balancing policy
//!   for heterogeneous backends.
//!
//! [`ModelRegistry`] is the multi-model, multi-tenant seam (ROADMAP item
//! 4): named models behind `Arc<Engine>` handles, per-model in-flight
//! quotas, and **zero-downtime hot swap** — build the replacement engine
//! off-thread ([`ModelRegistry::hot_swap`]), atomically swap the `Arc`
//! ([`ModelRegistry::swap`]) so new submits land on the new engine while
//! in-flight tickets drain on the old one, then wait for the outgoing
//! engine's queue to empty and its
//! `submitted == completed + rejected` ledger to balance before dropping
//! it ([`ModelRegistry::drain`]).  Both wire servers can dispatch through
//! a registry (wire-v2 `FEAT_MODEL` names the model per frame; absent ⇒
//! the default model, so existing clients are untouched).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::engine::Engine;
use super::request::{InferOptions, InferResponse, Ticket};
use super::InferService;
use crate::bnn::packing::Packed;

/// A named collection of serving engines (each built with
/// [`Engine::builder`]).
#[derive(Default)]
pub struct Router {
    backends: BTreeMap<String, Engine>,
    order: Vec<String>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `engine` under `name`.  Re-registering an existing name
    /// returns the displaced engine — the caller decides whether to drain
    /// or shut it down; it is never silently dropped (a dropped `Engine`
    /// abandons its queued work).
    pub fn register(&mut self, name: &str, engine: Engine) -> Option<Engine> {
        let displaced = self.backends.insert(name.to_string(), engine);
        if displaced.is_none() {
            self.order.push(name.to_string());
        }
        displaced
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn get(&self, name: &str) -> Result<&Engine> {
        self.backends
            .get(name)
            .with_context(|| format!("no backend '{name}' (have: {:?})", self.order))
    }

    /// Route to a named engine.
    pub fn route(&self, name: &str, image: Packed) -> Result<InferResponse> {
        self.get(name)?.infer(image)
    }

    /// Route by least queue depth.
    pub fn route_least_queue(&self, image: Packed) -> Result<InferResponse> {
        if self.order.is_empty() {
            bail!("router has no backends");
        }
        let name = self
            .order
            .iter()
            .min_by_key(|n| self.backends[*n].queue_depth())
            .unwrap();
        self.backends[name].infer(image)
    }

    /// Aggregate metrics lines per engine.
    pub fn metrics_report(&self) -> String {
        let mut out = String::new();
        for n in &self.order {
            out.push_str(&format!("{n}: {}\n", self.backends[n].summary_line()));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// ModelRegistry: named models, quotas, zero-downtime hot swap

/// How long [`ModelRegistry::swap_and_drain`] waits for the outgoing
/// engine to empty its queue and balance its ledger before giving up.
pub const DEFAULT_DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

struct ModelEntry {
    engine: Arc<Engine>,
    /// Max in-flight requests for this model (`None` = unbounded).  The
    /// per-model *queue* bound is the engine's own `queue_cap`, set at
    /// build time; this bound additionally covers requests already handed
    /// to clients as unresolved tickets.
    quota: Option<usize>,
    /// Requests admitted through the quota gate whose tickets have not
    /// yet resolved (shared with ticket observers, so it outlives swaps).
    inflight: Arc<AtomicUsize>,
    /// Swap count for this name (observability; starts at 0).
    generation: u64,
}

#[derive(Default)]
struct RegistryInner {
    models: BTreeMap<String, ModelEntry>,
    order: Vec<String>,
    default: Option<String>,
}

/// A hot-swappable registry of named serving engines.
///
/// * **Lookup** takes a read lock only long enough to clone the model's
///   `Arc<Engine>`; submits run outside the lock, so a swap (brief write
///   lock) never blocks behind a slow backend.
/// * **Swap** replaces the `Arc` atomically: submits that resolved the old
///   engine keep their tickets (the old engine drains them), submits after
///   the swap land on the new engine.  Per-model in-flight accounting is
///   shared across the swap, so quotas stay correct mid-handoff.
/// * **Quota** admission failures count `submitted` *and* `rejected` on
///   the model's current engine, keeping the
///   `submitted == completed + rejected (+ cancelled)` ledger balanced on
///   every refusal path, same as queue-cap rejections.
pub struct ModelRegistry {
    inner: RwLock<RegistryInner>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self {
            inner: RwLock::new(RegistryInner::default()),
        }
    }

    /// Register `engine` under `name` with no in-flight quota.  The first
    /// registered model becomes the default.  Returns the displaced engine
    /// on re-registration (prefer [`Self::swap`] for live replacement —
    /// it is the same operation, but named for intent and generation-
    /// counted).
    pub fn register(&self, name: &str, engine: Engine) -> Option<Arc<Engine>> {
        self.register_with_quota(name, engine, None)
    }

    /// [`Self::register`] with a per-model max-in-flight quota.
    pub fn register_with_quota(
        &self,
        name: &str,
        engine: Engine,
        quota: Option<usize>,
    ) -> Option<Arc<Engine>> {
        let mut inner = self.inner.write().unwrap();
        if inner.default.is_none() {
            inner.default = Some(name.to_string());
        }
        let generation = inner.models.get(name).map_or(0, |e| e.generation + 1);
        let inflight = inner
            .models
            .get(name)
            .map_or_else(|| Arc::new(AtomicUsize::new(0)), |e| e.inflight.clone());
        let displaced = inner.models.insert(
            name.to_string(),
            ModelEntry {
                engine: Arc::new(engine),
                quota,
                inflight,
                generation,
            },
        );
        if displaced.is_none() {
            inner.order.push(name.to_string());
        }
        displaced.map(|e| e.engine)
    }

    /// Atomically replace `name`'s engine, returning the outgoing
    /// `Arc<Engine>` so the caller can drain it ([`Self::drain`]) before
    /// letting it drop.  Fails if `name` was never registered (a swap
    /// cannot invent a model); quota and in-flight accounting carry over.
    pub fn swap(&self, name: &str, engine: Engine) -> Result<Arc<Engine>> {
        let mut inner = self.inner.write().unwrap();
        let entry = inner
            .models
            .get_mut(name)
            .with_context(|| format!("unknown model '{name}': cannot swap"))?;
        entry.generation += 1;
        Ok(std::mem::replace(&mut entry.engine, Arc::new(engine)))
    }

    /// Wait until `engine` has an empty queue and a balanced ledger
    /// (`submitted == completed + rejected` — cancelled tickets still
    /// complete or reject inside the engine, so the base identity is the
    /// drain criterion).  Errors if `timeout` elapses first.
    pub fn drain(engine: &Engine, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        loop {
            let m = engine.metrics();
            let submitted = m.submitted.load(Ordering::SeqCst);
            let completed = m.completed.load(Ordering::SeqCst);
            let rejected = m.rejected.load(Ordering::SeqCst);
            if engine.queue_depth() == 0 && submitted == completed + rejected {
                return Ok(());
            }
            if t0.elapsed() > timeout {
                bail!(
                    "drain timed out after {:?}: queue_depth={} ledger {}!={}+{}",
                    timeout,
                    engine.queue_depth(),
                    submitted,
                    completed,
                    rejected
                );
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// [`Self::swap`], then drain the outgoing engine and shut it down.
    /// Zero-downtime: new submits already land on the replacement while
    /// the old engine finishes its in-flight work.
    pub fn swap_and_drain(&self, name: &str, engine: Engine, timeout: Duration) -> Result<()> {
        let old = self.swap(name, engine)?;
        Self::drain(&old, timeout)?;
        // Dropping the last Arc joins the old engine's workers; if a
        // client still holds a clone, teardown happens when it lets go.
        drop(old);
        Ok(())
    }

    /// The full hot-swap protocol on a background thread: build the
    /// replacement engine off-thread (construction — weight prep, worker
    /// spawn — never blocks serving), swap atomically, drain and drop the
    /// outgoing engine.  Join the handle for the result.
    pub fn hot_swap<F>(
        self: &Arc<Self>,
        name: &str,
        build: F,
    ) -> std::thread::JoinHandle<Result<()>>
    where
        F: FnOnce() -> Result<Engine> + Send + 'static,
    {
        let registry = self.clone();
        let name = name.to_string();
        std::thread::Builder::new()
            .name(format!("bnn-swap-{name}"))
            .spawn(move || {
                let engine = build().with_context(|| format!("building replacement '{name}'"))?;
                registry.swap_and_drain(&name, engine, DEFAULT_DRAIN_TIMEOUT)
            })
            .expect("spawning the hot-swap thread")
    }

    /// Registered model names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.inner.read().unwrap().order.clone()
    }

    /// The model used when a request names none.
    pub fn default_model(&self) -> Option<String> {
        self.inner.read().unwrap().default.clone()
    }

    /// Point the default at another registered model.
    pub fn set_default(&self, name: &str) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        if !inner.models.contains_key(name) {
            bail!("unknown model '{name}': cannot set default");
        }
        inner.default = Some(name.to_string());
        Ok(())
    }

    /// The current engine for `name` (its `Arc` survives swaps happening
    /// after this call — callers observe a consistent engine).
    pub fn engine(&self, name: &str) -> Result<Arc<Engine>> {
        let inner = self.inner.read().unwrap();
        inner
            .models
            .get(name)
            .map(|e| e.engine.clone())
            .with_context(|| {
                format!("unknown model '{name}' (have: {:?})", inner.order)
            })
    }

    /// Requests admitted for `name` whose tickets are still unresolved.
    pub fn inflight(&self, name: &str) -> Result<usize> {
        let inner = self.inner.read().unwrap();
        inner
            .models
            .get(name)
            .map(|e| e.inflight.load(Ordering::SeqCst))
            .with_context(|| format!("unknown model '{name}'"))
    }

    /// Submit one image to `model` (or the default when `None`).  Unknown
    /// names and quota refusals are typed by message ("unknown model …" /
    /// "… quota exceeded …") so the wire layer maps them to
    /// `WireStatus::UnknownModel` / `WireStatus::Overloaded`.
    pub fn submit_to(
        &self,
        model: Option<&str>,
        image: Packed,
        opts: InferOptions,
    ) -> Result<Ticket> {
        let (engine, quota, inflight) = {
            let inner = self.inner.read().unwrap();
            let name = match model {
                Some(n) => n,
                None => inner
                    .default
                    .as_deref()
                    .context("model registry is empty (no default model)")?,
            };
            let entry = inner.models.get(name).with_context(|| {
                format!("unknown model '{name}' (have: {:?})", inner.order)
            })?;
            (entry.engine.clone(), entry.quota, entry.inflight.clone())
        };
        if let Some(q) = quota {
            // admit-if-below: the slot is held until the ticket resolves
            // or is dropped (the observer below releases it)
            let mut cur = inflight.load(Ordering::SeqCst);
            let admitted = loop {
                if cur >= q {
                    break false;
                }
                match inflight.compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst)
                {
                    Ok(_) => break true,
                    Err(now) => cur = now,
                }
            };
            if !admitted {
                // count the refusal on the model's current engine so its
                // ledger keeps balancing (submitted == completed+rejected)
                let m = engine.metrics();
                m.submitted.fetch_add(1, Ordering::Relaxed);
                m.rejected.fetch_add(1, Ordering::Relaxed);
                bail!(
                    "model {} quota exceeded ({q} requests in flight)",
                    model.map_or_else(|| "<default>".into(), |n| format!("'{n}'"))
                );
            }
            match engine.submit_with(image, opts) {
                Ok(t) => {
                    let slot = inflight.clone();
                    Ok(t.with_observer(Box::new(move || {
                        slot.fetch_sub(1, Ordering::SeqCst);
                    })))
                }
                Err(e) => {
                    // the engine refused (queue cap / width): release the
                    // quota slot immediately, the ticket never existed
                    inflight.fetch_sub(1, Ordering::SeqCst);
                    Err(e)
                }
            }
        } else {
            engine.submit_with(image, opts)
        }
    }

    /// Per-model metrics lines: generation, quota, in-flight, engine books.
    pub fn metrics_report(&self) -> String {
        let inner = self.inner.read().unwrap();
        let mut out = String::new();
        for n in &inner.order {
            let e = &inner.models[n];
            let default_marker = if inner.default.as_deref() == Some(n.as_str()) {
                "*"
            } else {
                ""
            };
            out.push_str(&format!(
                "{n}{default_marker} gen={} inflight={} quota={} {}\n",
                e.generation,
                e.inflight.load(Ordering::SeqCst),
                e.quota.map_or_else(|| "-".into(), |q| q.to_string()),
                e.engine.summary_line()
            ));
        }
        out
    }
}

/// Model-blind submits route to the default model — a registry slots in
/// anywhere an [`InferService`] is expected (v1 wire frames, loadgen).
impl InferService for ModelRegistry {
    fn submit_with(&self, image: Packed, opts: InferOptions) -> Result<Ticket> {
        self.submit_to(None, image, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::model_from_sign_rows;
    use crate::bnn::packing::pack_bits_u64;
    use crate::coordinator::{BatcherConfig, Kernel};
    use crate::util::prng::Xoshiro256;

    fn setup() -> (Router, crate::bnn::BnnModel) {
        let mut rng = Xoshiro256::new(41);
        let dims = [784usize, 128, 64, 10];
        let mut spec = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let rows: Vec<Vec<i8>> = (0..w[1])
                .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            spec.push((rows, (li + 2 < dims.len()).then(|| vec![0i32; w[1]])));
        }
        let model = model_from_sign_rows(spec).unwrap();
        let mut router = Router::new();
        for name in ["a", "b"] {
            router.register(
                name,
                Engine::builder()
                    .native(&model)
                    .kernel(Kernel::Scalar)
                    .workers(1)
                    .batcher(BatcherConfig::default())
                    .build()
                    .unwrap(),
            );
        }
        (router, model)
    }

    fn img(seed: u64) -> Packed {
        let mut rng = Xoshiro256::new(seed);
        let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
        Packed {
            words: pack_bits_u64(&bits),
            n_bits: 784,
        }
    }

    fn engine(model: &crate::bnn::BnnModel) -> Engine {
        Engine::builder()
            .native(model)
            .kernel(Kernel::Scalar)
            .workers(1)
            .batcher(BatcherConfig::default())
            .build()
            .unwrap()
    }

    #[test]
    fn named_routing_and_errors() {
        let (router, model) = setup();
        let image = img(5);
        let r = router.route("a", image.clone()).unwrap();
        assert_eq!(r.digit as usize, model.predict(&image.words));
        assert!(router.route("zzz", image).is_err());
        assert_eq!(router.names(), &["a", "b"]);
    }

    #[test]
    fn reregistration_returns_the_displaced_engine() {
        let (mut router, model) = setup();
        // warm the engine being displaced so we can tell it apart
        router.route("a", img(1)).unwrap();
        let displaced = router.register("a", engine(&model));
        let displaced = displaced.expect("re-registering must hand back the old engine");
        assert_eq!(
            displaced.metrics().completed.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "the displaced engine is the one that served traffic"
        );
        // the replacement serves under the same name; order stays dup-free
        assert_eq!(router.names(), &["a", "b"]);
        let image = img(2);
        let r = router.route("a", image.clone()).unwrap();
        assert_eq!(r.digit as usize, model.predict(&image.words));
        assert_eq!(
            router.get("a").unwrap().metrics().completed.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        displaced.shutdown();
        // registering a brand-new name returns None
        let mut fresh = Router::new();
        assert!(fresh.register("only", engine(&model)).is_none());
    }

    #[test]
    fn registry_routes_by_name_and_defaults_to_first() {
        use crate::coordinator::InferService;
        let (_, model) = setup();
        let reg = ModelRegistry::new();
        assert!(reg.register("mnist", engine(&model)).is_none());
        assert!(reg.register("alt", engine(&model)).is_none());
        assert_eq!(reg.default_model().as_deref(), Some("mnist"));
        assert_eq!(reg.names(), vec!["mnist", "alt"]);
        let image = img(3);
        let want = model.predict(&image.words);
        // named, defaulted, and trait-dispatched submits all serve
        assert_eq!(
            reg.submit_to(Some("alt"), image.clone(), InferOptions::default())
                .unwrap()
                .wait()
                .unwrap()
                .digit as usize,
            want
        );
        assert_eq!(reg.infer(image.clone()).unwrap().digit as usize, want);
        let err = reg
            .submit_to(Some("nope"), image, InferOptions::default())
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown model"), "{err:#}");
        reg.set_default("alt").unwrap();
        assert_eq!(reg.default_model().as_deref(), Some("alt"));
        assert!(reg.set_default("nope").is_err());
        let report = reg.metrics_report();
        assert!(report.contains("mnist") && report.contains("alt*"), "{report}");
    }

    #[test]
    fn registry_quota_rejects_and_releases() {
        let (_, model) = setup();
        let reg = ModelRegistry::new();
        reg.register_with_quota("m", engine(&model), Some(2));
        // a resolved ticket releases its slot via the observer
        let t = reg.submit_to(Some("m"), img(1), InferOptions::default()).unwrap();
        t.wait().unwrap();
        for _ in 0..200 {
            if reg.inflight("m").unwrap() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(reg.inflight("m").unwrap(), 0);
        // hold two unresolved tickets: the third submit is refused with a
        // quota-typed message and the engine ledger still balances
        let _t1 = reg.submit_to(Some("m"), img(2), InferOptions::default()).unwrap();
        let _t2 = reg.submit_to(Some("m"), img(3), InferOptions::default()).unwrap();
        let err = reg
            .submit_to(Some("m"), img(4), InferOptions::default())
            .unwrap_err();
        assert!(format!("{err:#}").contains("quota exceeded"), "{err:#}");
        let eng = reg.engine("m").unwrap();
        ModelRegistry::drain(&eng, std::time::Duration::from_secs(5)).unwrap();
        let m = eng.metrics();
        let submitted = m.submitted.load(std::sync::atomic::Ordering::SeqCst);
        let completed = m.completed.load(std::sync::atomic::Ordering::SeqCst);
        let rejected = m.rejected.load(std::sync::atomic::Ordering::SeqCst);
        assert_eq!(submitted, completed + rejected);
        assert_eq!(rejected, 1, "exactly the quota refusal");
    }

    #[test]
    fn registry_swap_hands_back_old_engine_and_reroutes() {
        let (_, model) = setup();
        let reg = Arc::new(ModelRegistry::new());
        reg.register("m", engine(&model));
        reg.infer(img(1)).unwrap();
        assert!(reg.swap("unregistered", engine(&model)).is_err());
        let old = reg.swap("m", engine(&model)).unwrap();
        assert_eq!(old.metrics().completed.load(std::sync::atomic::Ordering::Relaxed), 1);
        ModelRegistry::drain(&old, std::time::Duration::from_secs(5)).unwrap();
        drop(old);
        // new engine serves; generation is visible in the report
        reg.infer(img(2)).unwrap();
        assert!(reg.metrics_report().contains("gen=1"), "{}", reg.metrics_report());
        // and the off-thread build path completes the whole protocol
        let model2 = model.clone();
        reg.hot_swap("m", move || {
            Ok(Engine::builder()
                .native(&model2)
                .kernel(Kernel::Scalar)
                .workers(1)
                .batcher(BatcherConfig::default())
                .build()?)
        })
        .join()
        .unwrap()
        .unwrap();
        assert!(reg.metrics_report().contains("gen=2"), "{}", reg.metrics_report());
        reg.infer(img(3)).unwrap();
    }

    #[test]
    fn least_queue_serves_all() {
        let (router, model) = setup();
        for seed in 0..20 {
            let image = img(seed);
            let r = router.route_least_queue(image.clone()).unwrap();
            assert_eq!(r.digit as usize, model.predict(&image.words));
        }
        // both engines must have seen traffic counters (routing totals add up)
        let total: u64 = ["a", "b"]
            .iter()
            .map(|n| {
                router.get(n).unwrap().metrics().completed
                    .load(std::sync::atomic::Ordering::Relaxed)
            })
            .sum();
        assert_eq!(total, 20);
        let report = router.metrics_report();
        assert!(report.contains("a:") && report.contains("b:"));
    }
}
