//! The single-queue serving core: worker threads draining a shared queue
//! through the dynamic batcher into one backend, with per-request response
//! channels wrapped in [`super::request::Ticket`]s.
//!
//! No async runtime exists offline, so this is a classic std-thread design:
//! an injector mutex guards the queue; workers park on a condvar with the
//! batcher's deadline as the wait timeout.  A `Coordinator` owns one
//! backend; [`super::engine::Engine`] is the **only** public construction
//! path (`Engine::builder().shared(backend)` builds one of these), and the
//! [`super::router::Router`] composes several engines.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::backend::InferBackend;
use super::batcher::{decide, BatcherConfig, DrainDecision};
use super::metrics::Metrics;
use super::pool::{execute_batch, Pending, RestartPolicy};
use super::request::{Failure, InferOptions, InferRequest, InferResponse, Ticket};
use crate::bnn::packing::Packed;

/// Default backpressure bound: submits fail once this many requests are
/// queued.  Override per engine with `Engine::builder().queue_cap(..)`,
/// `[coordinator] queue_cap` in config files, or `--queue-cap` on the CLI.
pub const DEFAULT_QUEUE_CAP: usize = 100_000;

struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    shutdown: AtomicBool,
    cfg: BatcherConfig,
    queue_cap: usize,
    restart: RestartPolicy,
    /// Workers still draining.  When the last supervised worker exhausts
    /// its restart budget, `dead` is raised (under the queue lock) and the
    /// queue is drained with [`Failure::WorkerCrashed`] — a queue nobody
    /// will ever drain must not hang its waiters.
    live_workers: AtomicUsize,
    dead: AtomicBool,
}

/// A coordinator: one backend + N worker threads + metrics.
pub struct Coordinator {
    backend: Arc<dyn InferBackend>,
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn `workers` threads draining into `backend`.  Crate-internal:
    /// the public construction path is `Engine::builder()`.
    pub(crate) fn start(
        backend: Arc<dyn InferBackend>,
        cfg: BatcherConfig,
        workers: usize,
        queue_cap: usize,
    ) -> Result<Self> {
        Self::start_supervised(backend, cfg, workers, queue_cap, RestartPolicy::default())
    }

    /// [`Self::start`] with an explicit worker [`RestartPolicy`].
    pub(crate) fn start_supervised(
        backend: Arc<dyn InferBackend>,
        cfg: BatcherConfig,
        workers: usize,
        queue_cap: usize,
        restart: RestartPolicy,
    ) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(queue_cap >= 1, "queue_cap must be ≥ 1");
        let cfg = BatcherConfig {
            max_batch: cfg.max_batch.min(backend.max_batch()),
            ..cfg
        };
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            cfg,
            queue_cap,
            restart,
            live_workers: AtomicUsize::new(workers.max(1)),
            dead: AtomicBool::new(false),
        });
        let metrics = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for w in 0..workers.max(1) {
            let shared = shared.clone();
            let backend = backend.clone();
            let metrics = metrics.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("bnn-worker-{w}"))
                    .spawn(move || supervise_worker(shared, backend, metrics))
                    .expect("spawn worker"),
            );
        }
        Ok(Self {
            backend,
            shared,
            metrics,
            next_id: AtomicU64::new(1),
            workers: handles,
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Worker threads draining the shared queue.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Enqueue one image with explicit per-request options.
    pub fn submit_with(&self, image: Packed, opts: InferOptions) -> Result<Ticket> {
        // width check at the door: a mismatched image must never reach the
        // queue, where it would fail everything co-batched with it (books:
        // counted as submitted AND rejected, same as a backend rejection)
        if let Some(want) = self.backend.expected_bits() {
            if image.n_bits != want {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("image has {} bits, backend expects {want}", image.n_bits);
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            // dead-core check under the queue lock (the last worker raises
            // the flag and drains under the same lock, so no request can
            // slip into a queue nobody will drain)
            if self.shared.dead.load(Ordering::SeqCst) {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!(
                    "every worker crashed and exhausted its restart budget — engine is dead"
                );
            }
            if q.len() >= self.shared.queue_cap {
                // every arrival counts as submitted, so the books keep
                // `submitted == completed + rejected` on every path
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!(
                    "queue full ({} requests, cap {})",
                    q.len(),
                    self.shared.queue_cap
                );
            }
            q.push_back(Pending {
                req: InferRequest::with_opts(id, image, opts),
                reply: tx,
            });
        }
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.cv.notify_one();
        Ok(Ticket::new(id, rx, self.metrics.clone()))
    }

    // Inherent mirrors of the `InferService` defaults (so callers don't
    // need the trait in scope) — one implementation, in the trait.

    /// Enqueue one image; returns its [`Ticket`].
    pub fn submit(&self, image: Packed) -> Result<Ticket> {
        super::InferService::submit(self, image)
    }

    /// Blocking classify.
    pub fn infer(&self, image: Packed) -> Result<InferResponse> {
        super::InferService::infer(self, image)
    }

    /// Submit many, wait for all (order of responses matches submissions).
    pub fn infer_many(&self, images: Vec<Packed>) -> Result<Vec<InferResponse>> {
        super::InferService::infer_many(self, images)
    }

    /// Stop workers (drains nothing further; in-flight batches finish).
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Supervisor wrapper around [`worker_loop`], mirroring the pool's
/// `supervise_shard_worker`: panics restart the worker (fresh arenas)
/// under the [`RestartPolicy`], counting `worker_restarts`.  Because all
/// workers drain one shared queue, a single dead worker only shrinks
/// capacity; the queue itself is declared dead — and drained with
/// [`Failure::WorkerCrashed`] — only when the *last* live worker exhausts
/// its budget.
fn supervise_worker(shared: Arc<Shared>, backend: Arc<dyn InferBackend>, metrics: Arc<Metrics>) {
    let consecutive = AtomicU32::new(0);
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            worker_loop(&shared, backend.as_ref(), &metrics, &consecutive)
        }));
        match run {
            Ok(()) => return, // clean shutdown
            Err(_) => {
                let crashes = consecutive.fetch_add(1, Ordering::Relaxed) + 1;
                if crashes > shared.restart.max_restarts {
                    retire_worker(&shared, &metrics, crashes);
                    return;
                }
                metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(shared.restart.backoff_for(crashes));
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

/// Permanently retire one worker.  The last one to go marks the core dead
/// and resolves the queue with typed failures (counted `rejected`).
fn retire_worker(shared: &Shared, metrics: &Metrics, crashes: u32) {
    let mut q = shared.queue.lock().unwrap();
    let left = shared.live_workers.fetch_sub(1, Ordering::SeqCst) - 1;
    eprintln!("[coordinator] worker crashed {crashes}× consecutively and stays down ({left} left)");
    if left > 0 {
        return;
    }
    shared.dead.store(true, Ordering::SeqCst);
    let n = q.len() as u64;
    metrics.rejected.fetch_add(n, Ordering::Relaxed);
    for p in q.drain(..) {
        let _ = p.reply.send(Err(Failure::WorkerCrashed));
    }
    eprintln!("[coordinator] no workers left — queue drained ({n} requests resolved worker-crashed)");
}

fn worker_loop(
    shared: &Shared,
    backend: &dyn InferBackend,
    metrics: &Metrics,
    consecutive: &AtomicU32,
) {
    // Per-worker arenas (see `pool::execute_batch`): reused across batches
    // so the steady-state path is allocation-free; rebuilt fresh on every
    // supervised (re)start.
    let mut scratch = super::backend::InferScratch::default();
    let mut logits = super::backend::LogitsBuf::new();
    loop {
        // Decide under the lock, execute outside it.
        let batch: Vec<Pending> = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match decide(q.len(), q.front().map(|p| p.req.enqueued_at), &shared.cfg, Instant::now()) {
                    DrainDecision::Launch(n) => break q.drain(..n).collect(),
                    DrainDecision::Wait(d) => {
                        let (guard, _) = shared.cv.wait_timeout(q, d).unwrap();
                        q = guard;
                    }
                    DrainDecision::Idle => {
                        let (guard, _) = shared
                            .cv
                            .wait_timeout(q, std::time::Duration::from_millis(50))
                            .unwrap();
                        q = guard;
                    }
                }
            }
        };

        execute_batch(backend, None, metrics, batch, &mut scratch, &mut logits);
        consecutive.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::model_from_sign_rows;
    use crate::bnn::packing::pack_bits_u64;
    use crate::coordinator::backend::NativeBackend;
    use crate::util::prng::Xoshiro256;
    use std::time::Duration;

    fn tiny_model(seed: u64) -> crate::bnn::BnnModel {
        let mut rng = Xoshiro256::new(seed);
        let dims = [784usize, 128, 64, 10];
        let mut spec = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let rows: Vec<Vec<i8>> = (0..w[1])
                .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            spec.push((rows, (li + 2 < dims.len()).then(|| vec![0i32; w[1]])));
        }
        model_from_sign_rows(spec).unwrap()
    }

    fn imgs(n: usize, seed: u64) -> Vec<Packed> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| {
                let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
                Packed {
                    words: pack_bits_u64(&bits),
                    n_bits: 784,
                }
            })
            .collect()
    }

    #[test]
    fn serves_and_matches_direct_inference() {
        let model = tiny_model(31);
        let backend = Arc::new(NativeBackend::new(model.clone()));
        let coord = Coordinator::start(
            backend,
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
            },
            2,
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        assert_eq!(coord.workers(), 2);
        let images = imgs(50, 32);
        let responses = coord.infer_many(images.clone()).unwrap();
        assert_eq!(responses.len(), 50);
        for (img, r) in images.iter().zip(&responses) {
            assert_eq!(r.digit as usize, model.predict(&img.words), "req {}", r.id);
            assert_eq!(r.logits, model.logits(&img.words));
            assert!(r.batch_size >= 1 && r.batch_size <= 16);
        }
        // no request lost or duplicated
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 50);
        assert_eq!(coord.metrics.completed.load(Ordering::Relaxed), 50);
        coord.shutdown();
    }

    #[test]
    fn batching_actually_batches_under_load() {
        let model = tiny_model(33);
        let backend = Arc::new(NativeBackend::new(model));
        let coord = Coordinator::start(
            backend,
            BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(2),
            },
            1,
            DEFAULT_QUEUE_CAP,
        )
        .unwrap();
        // burst-submit then collect: expect mean batch > 1
        let tickets: Vec<Ticket> = imgs(64, 34)
            .into_iter()
            .map(|img| coord.submit(img).unwrap())
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        assert!(
            coord.metrics.mean_batch_size() > 1.5,
            "mean batch {}",
            coord.metrics.mean_batch_size()
        );
        coord.shutdown();
    }

    #[test]
    fn per_request_options_shape_the_response() {
        let model = tiny_model(37);
        let backend = Arc::new(NativeBackend::new(model.clone()));
        let coord =
            Coordinator::start(backend, BatcherConfig::default(), 1, DEFAULT_QUEUE_CAP).unwrap();
        let img = imgs(1, 38).pop().unwrap();
        let want = model.logits(&img.words);

        // digit-only: logits suppressed, digit still correct
        let r = coord
            .submit_with(img.clone(), InferOptions::digits_only())
            .unwrap()
            .wait()
            .unwrap();
        assert!(r.logits.is_empty() && r.top_k.is_empty());
        assert_eq!(r.digit as usize, model.predict(&img.words));

        // top-3 agrees with the shared selection helper
        let r = coord
            .submit_with(img.clone(), InferOptions::default().with_top_k(3))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.top_k, crate::coordinator::request::top_k_i32(&want, 3));
        assert_eq!(r.top_k[0].0, r.digit);
        assert_eq!(r.logits, want);
        coord.shutdown();
    }

    #[test]
    fn shutdown_terminates_workers() {
        let model = tiny_model(35);
        let backend = Arc::new(NativeBackend::new(model));
        let coord =
            Coordinator::start(backend, BatcherConfig::default(), 4, DEFAULT_QUEUE_CAP).unwrap();
        coord.shutdown(); // must not hang
    }

    #[test]
    fn all_workers_dead_resolves_everything_typed() {
        // single-queue analogue of the pool's kill-worker test: a backend
        // that can never execute must resolve every waiter with the typed
        // worker-crashed failure and fail fast once both workers are gone
        struct AlwaysPanic;
        impl InferBackend for AlwaysPanic {
            fn name(&self) -> &'static str {
                "always-panic"
            }
            fn max_batch(&self) -> usize {
                8
            }
            fn infer_batch(
                &self,
                _images: &[&Packed],
                _scratch: &mut crate::coordinator::backend::InferScratch,
                _out: &mut crate::coordinator::backend::LogitsBuf,
            ) -> Result<()> {
                panic!("test: injected worker panic");
            }
        }
        let coord = Coordinator::start_supervised(
            Arc::new(AlwaysPanic),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(10),
            },
            2,
            DEFAULT_QUEUE_CAP,
            RestartPolicy {
                max_restarts: 1,
                base_backoff: Duration::from_micros(10),
                max_backoff: Duration::from_micros(100),
            },
        )
        .unwrap();
        let mut waited_typed = 0u64;
        let mut failed_fast = 0u64;
        for img in imgs(24, 39) {
            match coord.submit(img) {
                Ok(t) => {
                    let e = t.wait().unwrap_err();
                    assert!(format!("{e}").contains("worker crashed"), "{e}");
                    waited_typed += 1;
                }
                Err(e) => {
                    assert!(format!("{e}").contains("worker crashed"), "{e}");
                    failed_fast += 1;
                }
            }
        }
        assert!(waited_typed >= 1);
        assert!(failed_fast >= 1, "dead engine must fail fast eventually");
        let m = &coord.metrics;
        // budget 1 restart × 2 workers
        assert_eq!(m.worker_restarts.load(Ordering::Relaxed), 2);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 24);
        assert_eq!(m.completed.load(Ordering::Relaxed), 0);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 24, "ledger balances");
        coord.shutdown();
    }
}
