//! Readiness-polled async wire server — the high-fanout replacement for the
//! thread-per-connection [`super::WireServer`] accept path (ROADMAP item 2).
//!
//! One event-loop thread multiplexes every connection through a vendored
//! readiness poller (`netpoll`: epoll on Linux, portable `poll(2)` fallback):
//!
//! - **Per-connection state machines** assemble v1/v2 frames from partial
//!   reads (magic-sniffed, same framing as the blocking server, shared
//!   validation via [`super::wire::parse_v2_header`] so the two servers
//!   cannot drift).
//! - **Submit-and-continue**: a parsed frame is submitted to the
//!   [`InferService`] immediately (one burst per v2 batch frame, same as the
//!   blocking path) and the loop moves on; [`Ticket`]s park in a
//!   per-connection reply queue that preserves response order.
//! - **Write-side buffering**: responses append to a per-connection write
//!   buffer flushed as the socket accepts bytes, with poller interest
//!   re-registered (read/write) as buffers fill and drain.
//! - **Admission control** rides the engine's queue-cap ledger: a submit
//!   refused with "queue full" surfaces to the peer as a typed
//!   [`WireStatus::Overloaded`] frame while the engine counts it `rejected`,
//!   so `submitted == completed + rejected (+ cancelled)` still balances
//!   under overload.  A connection cap bounds fds; per-connection in-flight
//!   caps stop one peer from buying the whole queue.
//! - **Idle read timeout**: a connection stalled *mid-frame* past
//!   [`super::WireServerConfig::idle_timeout`] gets a typed
//!   [`WireStatus::Timeout`] frame and is dropped — a slow-loris client
//!   costs one poller slot for a bounded time, never a blocked thread.
//!   Idleness *between* frames is free (that's the point of readiness
//!   polling).
//!
//! Protocol errors poison the connection: the typed error frame is queued
//! *behind* earlier pending replies (never reordered past them), reading
//! stops, and the connection closes once the error has flushed — byte-alike
//! with the blocking server's answer-then-drop behavior.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use netpoll::{Events, Interest, Poller};

use super::metrics::Metrics;
use super::request::InferOptions;
use super::wire::{
    arm_deadline, check_model_name_len, encode_error, encode_error_v2, encode_response,
    encode_response_v2, parse_model_name, parse_v2_header, payload_bytes, submit_error_status,
    unpack_payload, Dispatch, WireItem, WireServerConfig, WireStatus, FEAT_DEADLINE, FEAT_MODEL,
    IMAGE_BITS, MAGIC_REQ, MAGIC_REQ_V2, PAYLOAD_BYTES,
};
use super::router::ModelRegistry;
use super::InferService;
use crate::bnn::packing::Packed;

/// Images one connection may have in the engine at once before the loop
/// stops *reading* from it (backpressure through TCP flow control, not
/// memory growth).  Matches the wire-frame batch limit so a single maximal
/// v2 frame always fits.
const MAX_INFLIGHT_PER_CONN: usize = 4096;

/// Busy-poll iterations (with `yield_now`) while replies are in flight
/// before falling back to 1 ms blocking waits — keeps reply latency low
/// without starving engine workers on small hosts.
const SPIN_LIMIT: u32 = 64;

const LISTENER_TOKEN: usize = 0;

// ---------------------------------------------------------------------------
// frame parsing (incremental)

/// Outcome of one parse attempt against the connection's read buffer.
enum Parsed {
    /// Not enough buffered bytes for a full frame.
    NeedMore,
    /// A complete v1 request.
    V1(Packed),
    /// A complete v2 request.
    V2 {
        id: u64,
        features: u8,
        top_k: u8,
        opts: InferOptions,
        /// [`FEAT_MODEL`] name section; `None` ⇒ the default model.
        model: Option<String>,
        images: Vec<Packed>,
    },
    /// Protocol error: answer `status` (v2-form iff `v2`) and poison.
    Bad { v2: bool, id: u64, status: WireStatus },
}

/// Try to parse one frame from `buf`; returns `(bytes_consumed, outcome)`.
/// `bytes_consumed` is nonzero only for complete frames — `Bad` outcomes
/// consume nothing because the connection is torn down anyway.
fn try_parse(buf: &[u8]) -> (usize, Parsed) {
    let Some(&magic) = buf.first() else {
        return (0, Parsed::NeedMore);
    };
    match magic {
        MAGIC_REQ => {
            if buf.len() < 3 {
                return (0, Parsed::NeedMore);
            }
            let len = u16::from_le_bytes([buf[1], buf[2]]) as usize;
            if len != PAYLOAD_BYTES {
                return (
                    0,
                    Parsed::Bad {
                        v2: false,
                        id: 0,
                        status: WireStatus::BadLength,
                    },
                );
            }
            let total = 3 + len;
            if buf.len() < total {
                return (0, Parsed::NeedMore);
            }
            (total, Parsed::V1(unpack_payload(&buf[3..total], IMAGE_BITS)))
        }
        MAGIC_REQ_V2 => {
            if buf.len() < 17 {
                return (0, Parsed::NeedMore);
            }
            let head: [u8; 16] = buf[1..17].try_into().unwrap();
            let h = match parse_v2_header(&head) {
                Ok(h) => h,
                Err(e) => {
                    return (
                        0,
                        Parsed::Bad {
                            v2: true,
                            id: e.id.unwrap_or(0),
                            status: e.status,
                        },
                    )
                }
            };
            // the FEAT_MODEL name section sits between the head and the
            // payloads, so the frame's total size isn't known until its
            // length byte arrives — validate it as soon as it does
            let (payload_off, model) = if h.features & FEAT_MODEL != 0 {
                let Some(&name_len) = buf.get(17) else {
                    return (0, Parsed::NeedMore);
                };
                if let Err(e) = check_model_name_len(name_len as usize) {
                    return (
                        0,
                        Parsed::Bad {
                            v2: true,
                            id: h.id,
                            status: e.status,
                        },
                    );
                }
                let name_end = 18 + name_len as usize;
                if buf.len() < name_end {
                    return (0, Parsed::NeedMore);
                }
                match parse_model_name(&buf[18..name_end]) {
                    Ok(name) => (name_end, Some(name)),
                    Err(e) => {
                        return (
                            0,
                            Parsed::Bad {
                                v2: true,
                                id: h.id,
                                status: e.status,
                            },
                        )
                    }
                }
            } else {
                (17, None)
            };
            // the FEAT_DEADLINE budget (4 LE bytes, µs remaining) follows
            // the name section; it is armed against *this* clock as soon as
            // the section is complete, so queueing before parse already
            // counts against the budget
            let mut opts = h.opts();
            let payload_off = if h.features & FEAT_DEADLINE != 0 {
                let end = payload_off + 4;
                let Some(budget) = buf.get(payload_off..end) else {
                    return (0, Parsed::NeedMore);
                };
                let budget = u32::from_le_bytes(budget.try_into().unwrap());
                opts.deadline = Some(arm_deadline(budget, Instant::now()));
                end
            } else {
                payload_off
            };
            let pb = payload_bytes(h.n_bits);
            let total = payload_off + h.n_images * pb;
            if buf.len() < total {
                return (0, Parsed::NeedMore);
            }
            let images = (0..h.n_images)
                .map(|i| {
                    let off = payload_off + i * pb;
                    unpack_payload(&buf[off..off + pb], h.n_bits)
                })
                .collect();
            (
                total,
                Parsed::V2 {
                    id: h.id,
                    features: h.features,
                    top_k: h.top_k,
                    opts,
                    model,
                    images,
                },
            )
        }
        _ => (
            0,
            Parsed::Bad {
                v2: false,
                id: 0,
                status: WireStatus::BadMagic,
            },
        ),
    }
}

// ---------------------------------------------------------------------------
// per-connection state

/// One submitted image's lifecycle inside a pending reply.
enum Slot {
    Waiting(super::request::Ticket),
    Done(super::request::InferResponse),
    Failed(WireStatus),
}

/// A response owed to the peer, in request order.
enum PendingReply {
    V1 {
        slot: Slot,
    },
    V2 {
        id: u64,
        features: u8,
        top_k: u8,
        slots: Vec<Slot>,
    },
    /// A typed error frame (protocol error or idle timeout), queued in
    /// order behind earlier replies.
    Err { v2: bool, id: u64, status: WireStatus },
}

struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    /// Flushed prefix of `wbuf` (compacted lazily).
    wpos: usize,
    pending: VecDeque<PendingReply>,
    /// `Slot::Waiting` count across `pending` (backpressure gauge).
    inflight: usize,
    last_activity: Instant,
    interest: Interest,
    /// Protocol error queued: stop reading, close once flushed.
    poisoned: bool,
    eof: bool,
    /// Unrecoverable socket error: close immediately.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            inflight: 0,
            last_activity: Instant::now(),
            interest: Interest::READ,
            poisoned: false,
            eof: false,
            dead: false,
        }
    }

    /// Drain the socket into `rbuf`; returns true if any bytes arrived.
    fn do_read(&mut self, scratch: &mut [u8]) -> bool {
        let mut progress = false;
        loop {
            match self.stream.read(scratch) {
                Ok(0) => {
                    self.eof = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&scratch[..n]);
                    self.last_activity = Instant::now();
                    progress = true;
                    if n < scratch.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        progress
    }

    /// Write as much buffered response data as the socket accepts.
    fn flush(&mut self) {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > 32 * 1024 {
            // large flushed prefix: compact so the buffer can't grow
            // unboundedly under sustained partial writes
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
    }

    fn flushed(&self) -> bool {
        self.wpos == self.wbuf.len()
    }

    /// What poller interest this connection wants right now.
    fn desired_interest(&self) -> Interest {
        Interest {
            read: !self.eof && !self.poisoned && self.inflight < MAX_INFLIGHT_PER_CONN,
            write: !self.flushed(),
        }
    }

    fn should_close(&self) -> bool {
        if self.dead {
            return true;
        }
        // poisoned: close once the error frame is out.  EOF: close once
        // every already-read frame has been answered and flushed (half-close
        // support — `pending` empty implies no in-flight tickets).
        (self.poisoned || self.eof) && self.pending.is_empty() && self.flushed()
    }
}

/// Submit one image; a refusal becomes an immediately-resolved failed slot
/// with the typed status (the engine counted it `rejected`).
fn submit_one(dispatch: &Dispatch, model: Option<&str>, img: Packed, opts: InferOptions) -> Slot {
    match dispatch.submit(model, img, opts) {
        Ok(t) => Slot::Waiting(t),
        Err(e) => Slot::Failed(submit_error_status(&e)),
    }
}

/// Parse every complete frame in `rbuf` and submit it, respecting the
/// per-connection in-flight cap.  Returns true on any progress.
fn parse_and_submit(conn: &mut Conn, dispatch: &Dispatch) -> bool {
    let mut progress = false;
    let mut consumed_total = 0usize;
    while !conn.poisoned && conn.inflight < MAX_INFLIGHT_PER_CONN {
        let (consumed, parsed) = try_parse(&conn.rbuf[consumed_total..]);
        match parsed {
            Parsed::NeedMore => break,
            Parsed::V1(img) => {
                consumed_total += consumed;
                // v1 responses carry only the digit: the top-1-only path
                // keeps the serve loop allocation-free (same as blocking)
                let slot = submit_one(dispatch, None, img, InferOptions::digits_only());
                conn.inflight += matches!(slot, Slot::Waiting(_)) as usize;
                conn.pending.push_back(PendingReply::V1 { slot });
                progress = true;
            }
            Parsed::V2 {
                id,
                features,
                top_k,
                opts,
                model,
                images,
            } => {
                consumed_total += consumed;
                // submit the whole frame before waiting on anything (one
                // burst for the dynamic batcher), never short-circuiting:
                // a mid-frame refusal still submits the rest, mirroring
                // the blocking server's ledger semantics
                let slots: Vec<Slot> = images
                    .into_iter()
                    .map(|img| submit_one(dispatch, model.as_deref(), img, opts))
                    .collect();
                conn.inflight += slots.iter().filter(|s| matches!(s, Slot::Waiting(_))).count();
                conn.pending.push_back(PendingReply::V2 {
                    id,
                    features,
                    top_k,
                    slots,
                });
                progress = true;
            }
            Parsed::Bad { v2, id, status } => {
                conn.pending.push_back(PendingReply::Err { v2, id, status });
                conn.poisoned = true;
                progress = true;
                break;
            }
        }
    }
    if consumed_total > 0 {
        conn.rbuf.drain(..consumed_total);
    }
    progress
}

/// Poll a reply's waiting slots; returns whether the whole reply is
/// resolved.  `resolved_now` counts Waiting → resolved transitions (the
/// caller decrements `inflight`).  Each resolution feeds the server's own
/// latency/queue-wait histograms, so `summary_line()` shows real
/// percentiles under async serving — the blocking server gets this for
/// free from the engine, the event loop must book it per resolved slot.
fn poll_reply(reply: &mut PendingReply, resolved_now: &mut usize, metrics: &Metrics) -> bool {
    let poll_slot = |slot: &mut Slot, resolved_now: &mut usize| -> bool {
        if let Slot::Waiting(t) = slot {
            match t.try_poll() {
                Ok(Some(r)) => {
                    *resolved_now += 1;
                    metrics.record_queue_wait(r.queue_wait_ns);
                    metrics.record_latency(r.latency_ns);
                    *slot = Slot::Done(r);
                }
                Ok(None) => return false,
                Err(e) => {
                    // typed failure (worker crash, deadline shed) or a
                    // dropped ticket channel — map to the wire status the
                    // blocking server would answer with
                    *resolved_now += 1;
                    *slot = Slot::Failed(submit_error_status(&e));
                }
            }
        }
        true
    };
    match reply {
        PendingReply::Err { .. } => true,
        PendingReply::V1 { slot } => poll_slot(slot, resolved_now),
        PendingReply::V2 { slots, .. } => {
            let mut all = true;
            for slot in slots.iter_mut() {
                all &= poll_slot(slot, resolved_now);
            }
            all
        }
    }
}

fn latency_us(ns: u64) -> u32 {
    (ns / 1000).min(u32::MAX as u64) as u32
}

/// Encode a fully-resolved reply; returns the frame bytes and how many
/// images it served OK (for the `served` counter).
fn encode_reply(reply: PendingReply) -> (Vec<u8>, u64) {
    match reply {
        PendingReply::Err { v2, id, status } => {
            let bytes = if v2 {
                encode_error_v2(id, status)
            } else {
                encode_error(status).to_vec()
            };
            (bytes, 0)
        }
        PendingReply::V1 { slot } => match slot {
            // the v1 digit field is one byte: a >255-class argmax gets a
            // typed refusal, never a wrapped digit (same as the blocking
            // server — v2 carries the u16)
            Slot::Done(r) if r.digit > u8::MAX as u16 => {
                (encode_error(WireStatus::TooLarge).to_vec(), 0)
            }
            Slot::Done(r) => (
                encode_response(r.digit as u8, latency_us(r.latency_ns)).to_vec(),
                1,
            ),
            Slot::Failed(status) => (encode_error(status).to_vec(), 0),
            Slot::Waiting(_) => unreachable!("encode_reply on an unresolved v1 slot"),
        },
        PendingReply::V2 {
            id,
            features,
            top_k,
            slots,
        } => {
            // the first failure decides the typed status for the whole
            // frame (same all-or-nothing contract as the blocking server)
            let first_failure = slots.iter().find_map(|s| match s {
                Slot::Failed(st) => Some(*st),
                _ => None,
            });
            if let Some(status) = first_failure {
                return (encode_error_v2(id, status), 0);
            }
            let items: Vec<WireItem> = slots
                .into_iter()
                .enumerate()
                .map(|(i, s)| match s {
                    Slot::Done(r) => WireItem {
                        id: id.wrapping_add(i as u64),
                        digit: r.digit,
                        latency_us: latency_us(r.latency_ns),
                        logits: r.logits,
                        top_k: r.top_k,
                    },
                    _ => unreachable!("encode_reply on an unresolved v2 slot"),
                })
                .collect();
            match encode_response_v2(id, WireStatus::Ok, features, top_k, &items) {
                Ok(frame) => {
                    let n = items.len() as u64;
                    (frame, n)
                }
                // e.g. a model with more classes than the wire carries
                Err(_) => (encode_error_v2(id, WireStatus::TooLarge), 0),
            }
        }
    }
}

/// Resolve-and-encode as many in-order replies as are ready.
fn pump(conn: &mut Conn, served: &AtomicU64, metrics: &Metrics) -> bool {
    let mut progress = false;
    loop {
        let mut resolved_now = 0usize;
        let ready = match conn.pending.front_mut() {
            None => break,
            Some(reply) => poll_reply(reply, &mut resolved_now, metrics),
        };
        conn.inflight -= resolved_now;
        if !ready {
            break;
        }
        let reply = conn.pending.pop_front().unwrap();
        let (bytes, ok_images) = encode_reply(reply);
        conn.wbuf.extend_from_slice(&bytes);
        if ok_images > 0 {
            served.fetch_add(ok_images, Ordering::Relaxed);
        }
        progress = true;
    }
    progress
}

// ---------------------------------------------------------------------------
// the server

/// A running readiness-polled TCP server bound to a serving engine.
///
/// Same two wire protocols on one port as [`super::WireServer`], same
/// response bytes (modulo the measured latency field), thousands of
/// connections on one thread.
pub struct AsyncWireServer {
    pub addr: std::net::SocketAddr,
    /// Which poller backend the event loop runs on ("epoll" or "poll").
    pub poll_backend: &'static str,
    stop: Arc<AtomicBool>,
    /// Images served OK (a v2 batch frame counts once per image).
    pub served: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
    loop_thread: Option<std::thread::JoinHandle<()>>,
}

impl AsyncWireServer {
    /// Bind `addr` and serve through `service` with the default policy.
    pub fn start<S: InferService + 'static>(addr: &str, service: Arc<S>) -> Result<AsyncWireServer> {
        Self::start_with(addr, service, WireServerConfig::default())
    }

    /// [`Self::start`] with an explicit connection cap / idle timeout.
    pub fn start_with<S: InferService + 'static>(
        addr: &str,
        service: Arc<S>,
        cfg: WireServerConfig,
    ) -> Result<AsyncWireServer> {
        Self::start_dispatch(addr, Dispatch::Single(service), cfg)
    }

    /// Serve a [`ModelRegistry`]: v2 frames route by their
    /// [`FEAT_MODEL`] name, nameless frames (and all of v1) go to the
    /// registry's default model.
    pub fn start_registry(addr: &str, registry: Arc<ModelRegistry>) -> Result<AsyncWireServer> {
        Self::start_dispatch(addr, Dispatch::Registry(registry), WireServerConfig::default())
    }

    /// [`Self::start_registry`] with an explicit connection policy.
    pub fn start_registry_with(
        addr: &str,
        registry: Arc<ModelRegistry>,
        cfg: WireServerConfig,
    ) -> Result<AsyncWireServer> {
        Self::start_dispatch(addr, Dispatch::Registry(registry), cfg)
    }

    fn start_dispatch(
        addr: &str,
        dispatch: Dispatch,
        cfg: WireServerConfig,
    ) -> Result<AsyncWireServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        // poller + listener registration happen before the spawn so setup
        // errors surface to the caller instead of a dead thread
        let poller = Poller::new().context("creating the readiness poller")?;
        let poll_backend = poller.backend_name();
        {
            use std::os::unix::io::AsRawFd;
            poller
                .register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ)
                .context("registering the listener")?;
        }
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let metrics = Arc::new(Metrics::default());
        let t_stop = stop.clone();
        let t_served = served.clone();
        let t_metrics = metrics.clone();
        let loop_thread = std::thread::Builder::new()
            .name("bnn-wire-async".into())
            .spawn(move || {
                event_loop(listener, poller, dispatch, cfg, t_stop, t_served, t_metrics);
            })?;
        Ok(AsyncWireServer {
            addr: local,
            poll_backend,
            stop,
            served,
            metrics,
            loop_thread: Some(loop_thread),
        })
    }

    /// Connection gauges (`conn_accepted`/`conn_open`/`conn_closed`).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.loop_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AsyncWireServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.loop_thread.take() {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_lines)]
fn event_loop(
    listener: TcpListener,
    poller: Poller,
    dispatch: Dispatch,
    cfg: WireServerConfig,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
) {
    use std::os::unix::io::AsRawFd;

    let mut conns: HashMap<usize, Conn> = HashMap::new();
    let mut events = Events::with_capacity(1024);
    let mut next_token = LISTENER_TOKEN + 1;
    let mut scratch = vec![0u8; 64 * 1024];
    let mut spins: u32 = 0;
    let idle_timeout = cfg.idle_timeout.max(Duration::from_millis(1));
    let sweep_every = (idle_timeout / 4).clamp(Duration::from_millis(10), Duration::from_millis(500));
    let mut last_sweep = Instant::now();
    let mut close_list: Vec<usize> = Vec::new();

    while !stop.load(Ordering::SeqCst) {
        let any_inflight = conns.values().any(|c| !c.pending.is_empty());
        // Replies in flight: poll hot (yield per spin so engine workers on
        // small hosts still run), then back off to 1 ms blocking waits.
        // Fully idle: sleep long; accepts and readable sockets wake us.
        let timeout = if any_inflight {
            if spins < SPIN_LIMIT {
                Duration::ZERO
            } else {
                Duration::from_millis(1)
            }
        } else {
            Duration::from_millis(25)
        };
        let n_events = match poller.wait(&mut events, Some(timeout)) {
            Ok(n) => n,
            Err(_) => break,
        };
        if any_inflight && n_events == 0 && spins < SPIN_LIMIT {
            std::thread::yield_now();
        }

        let mut progress = n_events > 0;

        for ev in &events {
            if ev.token == LISTENER_TOKEN {
                // drain the accept queue (level-triggered, but cheap)
                loop {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            metrics.conn_accepted.fetch_add(1, Ordering::SeqCst);
                            if conns.len() >= cfg.max_conns {
                                // over the cap: best-effort typed refusal
                                // (7 bytes fit a fresh send buffer), close
                                let _ = stream.write_all(&encode_error(WireStatus::Overloaded));
                                metrics.conn_closed.fetch_add(1, Ordering::SeqCst);
                                continue;
                            }
                            if stream.set_nonblocking(true).is_err() {
                                metrics.conn_closed.fetch_add(1, Ordering::SeqCst);
                                continue;
                            }
                            stream.set_nodelay(true).ok();
                            let token = next_token;
                            next_token += 1;
                            if poller.register(stream.as_raw_fd(), token, Interest::READ).is_err() {
                                metrics.conn_closed.fetch_add(1, Ordering::SeqCst);
                                continue;
                            }
                            metrics.conn_open.fetch_add(1, Ordering::SeqCst);
                            conns.insert(token, Conn::new(stream));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(_) => break,
                    }
                }
                continue;
            }
            let Some(conn) = conns.get_mut(&ev.token) else {
                continue; // already closed this pass
            };
            if ev.readable && conn.do_read(&mut scratch) {
                progress |= parse_and_submit(conn, &dispatch);
            }
            if ev.writable {
                conn.flush();
            }
        }

        // resolve-and-encode ready replies on every connection, then flush
        // opportunistically (most responses go out without waiting for a
        // writable event)
        for conn in conns.values_mut() {
            if !conn.pending.is_empty() && pump(conn, &served, &metrics) {
                progress = true;
            }
            if !conn.flushed() {
                conn.flush();
            }
        }

        // idle sweep: connections stalled mid-frame past the timeout get a
        // typed Timeout frame and close; ones wedged on an unflushable
        // write buffer are cut off
        if last_sweep.elapsed() >= sweep_every {
            last_sweep = Instant::now();
            for conn in conns.values_mut() {
                if conn.poisoned || conn.dead || conn.eof {
                    continue;
                }
                if conn.last_activity.elapsed() < idle_timeout {
                    continue;
                }
                if !conn.flushed() {
                    // peer stopped reading and writing: nothing more to say
                    conn.dead = true;
                } else if !conn.rbuf.is_empty() && conn.pending.is_empty() {
                    // stalled mid-frame (slow-loris): typed timeout, poison
                    let v2 = conn.rbuf[0] == MAGIC_REQ_V2;
                    conn.pending.push_back(PendingReply::Err {
                        v2,
                        id: 0,
                        status: WireStatus::Timeout,
                    });
                    conn.poisoned = true;
                    pump(conn, &served, &metrics);
                    conn.flush();
                }
            }
        }

        // finalize: re-register interest where it changed, close what's done
        close_list.clear();
        for (&token, conn) in conns.iter_mut() {
            if conn.should_close() {
                close_list.push(token);
                continue;
            }
            let want = conn.desired_interest();
            if want != conn.interest {
                if poller.modify(conn.stream.as_raw_fd(), token, want).is_ok() {
                    conn.interest = want;
                } else {
                    conn.dead = true;
                    close_list.push(token);
                }
            }
        }
        for token in close_list.drain(..) {
            if let Some(conn) = conns.remove(&token) {
                let _ = poller.deregister(conn.stream.as_raw_fd());
                metrics.conn_open.fetch_sub(1, Ordering::SeqCst);
                metrics.conn_closed.fetch_add(1, Ordering::SeqCst);
                progress = true;
            }
        }

        if progress {
            spins = 0;
        } else {
            spins = spins.saturating_add(1);
        }
    }
    // shutdown: every still-open connection closes now so the gauge books
    // balance after the loop exits
    for (_, conn) in conns.drain() {
        let _ = poller.deregister(conn.stream.as_raw_fd());
        metrics.conn_open.fetch_sub(1, Ordering::SeqCst);
        metrics.conn_closed.fetch_add(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v1_frame(seed: u64) -> (Packed, Vec<u8>) {
        let mut rng = crate::util::prng::Xoshiro256::new(seed);
        let bits: Vec<u8> = (0..IMAGE_BITS).map(|_| rng.bool() as u8).collect();
        let img = Packed::from_bits(&bits);
        let frame = super::super::wire::encode_request(&img).unwrap();
        (img, frame)
    }

    #[test]
    fn try_parse_needs_full_v1_frame() {
        let (img, frame) = v1_frame(7);
        for cut in 0..frame.len() {
            let (consumed, parsed) = try_parse(&frame[..cut]);
            assert_eq!(consumed, 0, "cut {cut}");
            assert!(matches!(parsed, Parsed::NeedMore), "cut {cut}");
        }
        let (consumed, parsed) = try_parse(&frame);
        assert_eq!(consumed, frame.len());
        match parsed {
            Parsed::V1(p) => assert_eq!(p.words, img.words),
            _ => panic!("complete v1 frame did not parse"),
        }
    }

    #[test]
    fn try_parse_rejects_bad_magic_and_bad_v1_length() {
        let (_, mut frame) = v1_frame(8);
        frame[0] = 0x5A;
        match try_parse(&frame).1 {
            Parsed::Bad { v2, id, status } => {
                assert!(!v2);
                assert_eq!(id, 0);
                assert_eq!(status, WireStatus::BadMagic);
            }
            _ => panic!("bad magic accepted"),
        }
        let (_, mut frame) = v1_frame(9);
        frame[1] = (PAYLOAD_BYTES as u8).wrapping_add(1);
        match try_parse(&frame).1 {
            Parsed::Bad { v2, status, .. } => {
                assert!(!v2);
                assert_eq!(status, WireStatus::BadLength);
            }
            _ => panic!("bad v1 length accepted"),
        }
    }

    #[test]
    fn try_parse_v2_roundtrip_and_trailing_bytes_survive() {
        let mut rng = crate::util::prng::Xoshiro256::new(11);
        let images: Vec<Packed> = (0..3)
            .map(|_| {
                let bits: Vec<u8> = (0..65).map(|_| rng.bool() as u8).collect();
                Packed::from_bits(&bits)
            })
            .collect();
        let opts = InferOptions::default().with_top_k(2);
        let mut frame =
            super::super::wire::encode_request_v2(&images, 42, opts).unwrap();
        let frame_len = frame.len();
        frame.extend_from_slice(&[MAGIC_REQ, 0xFF]); // next frame's prefix
        let (consumed, parsed) = try_parse(&frame);
        assert_eq!(consumed, frame_len, "must not consume the next frame's bytes");
        match parsed {
            Parsed::V2 {
                id,
                opts: parsed_opts,
                images: parsed_images,
                ..
            } => {
                assert_eq!(id, 42);
                assert_eq!(parsed_opts, opts);
                assert_eq!(parsed_images.len(), 3);
                for (a, b) in parsed_images.iter().zip(images.iter()) {
                    assert_eq!(a.words, b.words);
                    assert_eq!(a.n_bits, b.n_bits);
                }
            }
            _ => panic!("complete v2 frame did not parse"),
        }
        // every strict prefix of the v2 frame is NeedMore, never Bad
        for cut in 0..frame_len {
            let (c, p) = try_parse(&frame[..cut]);
            assert_eq!(c, 0, "cut {cut}");
            assert!(matches!(p, Parsed::NeedMore), "cut {cut}");
        }
    }

    #[test]
    fn try_parse_v2_deadline_section_arms_a_fresh_deadline() {
        let img = {
            let bits: Vec<u8> = (0..64).map(|i| (i % 5 == 0) as u8).collect();
            Packed::from_bits(&bits)
        };
        let opts = InferOptions::default().with_budget(Duration::from_millis(250));
        // composed with a model name: the budget section sits *after* the
        // name and before the payloads
        let frame = super::super::wire::encode_request_v2_for(
            std::slice::from_ref(&img),
            13,
            opts,
            Some("mnist-b"),
        )
        .unwrap();
        // every strict prefix — including cuts inside the 4-byte budget —
        // is NeedMore, never Bad, never a short consume
        for cut in 0..frame.len() {
            let (c, p) = try_parse(&frame[..cut]);
            assert_eq!(c, 0, "cut {cut}");
            assert!(matches!(p, Parsed::NeedMore), "cut {cut}");
        }
        match try_parse(&frame) {
            (c, Parsed::V2 { id, opts, model, images, .. }) => {
                assert_eq!(c, frame.len());
                assert_eq!(id, 13);
                assert_eq!(model.as_deref(), Some("mnist-b"));
                assert_eq!(images[0].words, img.words);
                let deadline = opts.deadline.expect("deadline not armed");
                let remaining = deadline.saturating_duration_since(Instant::now());
                // re-armed against this clock from the relative budget:
                // strictly less than sent (encode/parse took time), nonzero
                // (the budget was roomy); 260 ms headroom absorbs the two
                // separate Instant::now() calls
                assert!(remaining > Duration::ZERO, "{remaining:?}");
                assert!(remaining <= Duration::from_millis(260), "{remaining:?}");
            }
            _ => panic!("deadline-bearing v2 frame did not parse"),
        }
        // nameless deadline frame: section directly after the 16-byte head
        let frame = super::super::wire::encode_request_v2(
            std::slice::from_ref(&img),
            14,
            InferOptions::default().with_budget(Duration::from_millis(100)),
        )
        .unwrap();
        match try_parse(&frame) {
            (c, Parsed::V2 { id, opts, model, .. }) => {
                assert_eq!(c, frame.len());
                assert_eq!(id, 14);
                assert!(model.is_none());
                assert!(opts.deadline.is_some());
            }
            _ => panic!("nameless deadline frame did not parse"),
        }
    }

    #[test]
    fn try_parse_v2_header_errors_echo_the_id() {
        // 0 images: BadLength with the client id echoed
        let img = {
            let bits: Vec<u8> = (0..64).map(|i| (i % 2) as u8).collect();
            Packed::from_bits(&bits)
        };
        let mut frame =
            super::super::wire::encode_request_v2(&[img], 99, InferOptions::default()).unwrap();
        frame[11] = 0; // n_images lo
        frame[12] = 0; // n_images hi
        match try_parse(&frame).1 {
            Parsed::Bad { v2, id, status } => {
                assert!(v2);
                assert_eq!(id, 99);
                assert_eq!(status, WireStatus::BadLength);
            }
            _ => panic!("zero-image v2 frame accepted"),
        }
    }

    #[test]
    fn try_parse_v2_model_section_incremental() {
        let img = {
            let bits: Vec<u8> = (0..64).map(|i| (i % 3 == 0) as u8).collect();
            Packed::from_bits(&bits)
        };
        let frame = super::super::wire::encode_request_v2_for(
            std::slice::from_ref(&img),
            7,
            InferOptions::default(),
            Some("mnist-b"),
        )
        .unwrap();
        // every strict prefix — including cuts inside the name section —
        // is NeedMore, never Bad, never a short consume
        for cut in 0..frame.len() {
            let (c, p) = try_parse(&frame[..cut]);
            assert_eq!(c, 0, "cut {cut}");
            assert!(matches!(p, Parsed::NeedMore), "cut {cut}");
        }
        match try_parse(&frame) {
            (c, Parsed::V2 { id, model, images, .. }) => {
                assert_eq!(c, frame.len());
                assert_eq!(id, 7);
                assert_eq!(model.as_deref(), Some("mnist-b"));
                assert_eq!(images[0].words, img.words);
            }
            _ => panic!("named v2 frame did not parse"),
        }
        // a corrupt name length is a typed error with the id echoed
        let mut bad = frame.clone();
        bad[17] = 0;
        match try_parse(&bad).1 {
            Parsed::Bad { v2, id, status } => {
                assert!(v2);
                assert_eq!(id, 7);
                assert_eq!(status, WireStatus::BadLength);
            }
            _ => panic!("empty model name accepted"),
        }
        let mut bad = frame;
        bad[18] = 0xFF; // "m" → invalid UTF-8 lead byte
        match try_parse(&bad).1 {
            Parsed::Bad { v2, id, status } => {
                assert!(v2);
                assert_eq!(id, 7);
                assert_eq!(status, WireStatus::BadLength);
            }
            _ => panic!("non-UTF-8 model name accepted"),
        }
    }
}
