//! Chaos-injection backend: deterministic fault injection for exercising
//! the serving stack's failure paths (DESIGN.md §Fault tolerance).
//!
//! [`ChaosBackend`] wraps any [`InferBackend`] and, on a seeded
//! pseudo-random subset of `infer_batch` calls, injects one of four fault
//! kinds instead of (or around) the delegated call:
//!
//! | fault            | what the serving stack must survive                |
//! |------------------|----------------------------------------------------|
//! | [`FaultKind::Error`]      | `infer_batch` returns `Err` — the designed failure path |
//! | [`FaultKind::Panic`]      | the worker thread panics mid-batch — supervision territory |
//! | [`FaultKind::Latency`]    | the call stalls for the configured spike, then succeeds |
//! | [`FaultKind::WrongShape`] | the logits arena comes back with the wrong row count |
//!
//! The fault plan is a **pure function of `(seed, call index)`** — two runs
//! with the same seed inject the same faults at the same call indices
//! regardless of thread interleaving, so chaos soaks are reproducible and
//! a failure seed can be replayed.  The call index is a process-wide
//! atomic: with N worker replicas sharing one `Arc<ChaosBackend>`, which
//! *worker* eats a given fault varies run to run, but the fault *sequence*
//! does not.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::backend::{InferBackend, InferScratch, LogitsBuf};
use crate::bnn::packing::Packed;
use crate::util::prng::SplitMix64;

/// One injectable fault (see the module table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `infer_batch` bails with a typed chaos error.
    Error,
    /// The call panics (unwinds) — exercises worker supervision.
    Panic,
    /// The call sleeps for [`ChaosConfig::spike`], then delegates normally
    /// — exercises deadline sheds and batching under latency spikes.
    Latency,
    /// Delegates, then mis-sizes the logits arena (one extra zero row) —
    /// exercises the batch executor's shape guard.
    WrongShape,
}

impl FaultKind {
    /// Every kind, in the order the picker indexes them.
    pub const ALL: [FaultKind; 4] = [
        FaultKind::Error,
        FaultKind::Panic,
        FaultKind::Latency,
        FaultKind::WrongShape,
    ];

    /// Short name (logs/reports).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Error => "error",
            FaultKind::Panic => "panic",
            FaultKind::Latency => "latency",
            FaultKind::WrongShape => "wrong-shape",
        }
    }
}

/// Seeded fault plan: which calls fault, and how.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Plan seed — same seed, same plan.
    pub seed: u64,
    /// Per-call fault probability in `[0, 1]`.
    pub rate: f64,
    /// Kinds eligible for injection; empty disables injection entirely.
    pub kinds: Vec<FaultKind>,
    /// Stall duration for [`FaultKind::Latency`] faults.
    pub spike: Duration,
}

impl ChaosConfig {
    /// All fault kinds enabled with a 2 ms latency spike.
    pub fn new(seed: u64, rate: f64) -> Self {
        Self {
            seed,
            rate,
            kinds: FaultKind::ALL.to_vec(),
            spike: Duration::from_millis(2),
        }
    }

    /// Restrict the plan to `kinds` (builder-style).
    pub fn with_kinds(mut self, kinds: &[FaultKind]) -> Self {
        self.kinds = kinds.to_vec();
        self
    }

    /// Override the latency-spike duration (builder-style).
    pub fn with_spike(mut self, spike: Duration) -> Self {
        self.spike = spike;
        self
    }

    /// The fault (if any) this plan injects at call `call` — pure, so
    /// tests and replay tooling can enumerate the plan without running it.
    pub fn fault_for(&self, call: u64) -> Option<FaultKind> {
        if self.kinds.is_empty() || self.rate <= 0.0 {
            return None;
        }
        if self.rate < 1.0 {
            // compare a uniform u64 hash against the rate threshold
            let threshold = (self.rate * u64::MAX as f64) as u64;
            if SplitMix64::new(self.seed ^ call).next_u64() >= threshold {
                return None;
            }
        }
        // second, independent hash picks the kind among the enabled ones
        let pick = SplitMix64::new(self.seed.rotate_left(17) ^ call.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .next_u64();
        Some(self.kinds[(pick % self.kinds.len() as u64) as usize])
    }
}

/// An [`InferBackend`] decorator injecting the configured fault plan.
/// Clean calls delegate untouched — logits are bit-identical to the
/// wrapped backend's.
pub struct ChaosBackend {
    inner: Arc<dyn InferBackend>,
    cfg: ChaosConfig,
    calls: AtomicU64,
    injected: AtomicU64,
}

impl ChaosBackend {
    pub fn new(inner: Arc<dyn InferBackend>, cfg: ChaosConfig) -> Self {
        Self {
            inner,
            cfg,
            calls: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }

    /// `infer_batch` calls seen so far (clean + faulted).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// The plan this backend runs.
    pub fn config(&self) -> &ChaosConfig {
        &self.cfg
    }
}

impl InferBackend for ChaosBackend {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn expected_bits(&self) -> Option<usize> {
        self.inner.expected_bits()
    }

    fn infer_batch(
        &self,
        images: &[&Packed],
        scratch: &mut InferScratch,
        out: &mut LogitsBuf,
    ) -> Result<()> {
        let call = self.calls.fetch_add(1, Ordering::SeqCst);
        let Some(fault) = self.cfg.fault_for(call) else {
            return self.inner.infer_batch(images, scratch, out);
        };
        self.injected.fetch_add(1, Ordering::SeqCst);
        match fault {
            FaultKind::Error => anyhow::bail!("chaos: injected backend error (call {call})"),
            FaultKind::Panic => panic!("chaos: injected worker panic (call {call})"),
            FaultKind::Latency => {
                std::thread::sleep(self.cfg.spike);
                self.inner.infer_batch(images, scratch, out)
            }
            FaultKind::WrongShape => {
                self.inner.infer_batch(images, scratch, out)?;
                // one extra zero row: rows() no longer matches the batch,
                // which the executor's shape guard must catch
                out.reset(images.len() + 1, out.stride().max(1));
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::model_from_sign_rows;
    use crate::bnn::packing::pack_bits_u64;
    use crate::coordinator::backend::NativeBackend;
    use crate::util::prng::Xoshiro256;

    fn tiny_model(seed: u64) -> crate::bnn::BnnModel {
        let mut rng = Xoshiro256::new(seed);
        let dims = [784usize, 32, 10];
        let mut spec = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let rows: Vec<Vec<i8>> = (0..w[1])
                .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            let thr = (li + 2 < dims.len()).then(|| vec![0i32; w[1]]);
            spec.push((rows, thr));
        }
        model_from_sign_rows(spec).unwrap()
    }

    fn image(seed: u64) -> Packed {
        let mut rng = Xoshiro256::new(seed);
        let bits: Vec<u8> = (0..784).map(|_| rng.bool() as u8).collect();
        Packed {
            words: pack_bits_u64(&bits),
            n_bits: 784,
        }
    }

    #[test]
    fn fault_plan_is_deterministic_and_rate_bounded() {
        let cfg = ChaosConfig::new(0xC4A05, 0.05);
        let plan: Vec<Option<FaultKind>> = (0..20_000).map(|c| cfg.fault_for(c)).collect();
        let replay: Vec<Option<FaultKind>> = (0..20_000).map(|c| cfg.fault_for(c)).collect();
        assert_eq!(plan, replay, "same seed must give the same plan");
        let faults = plan.iter().flatten().count();
        // 5% of 20k = 1000 expected; a uniform hash stays well inside ±50%
        assert!((500..1500).contains(&faults), "fault count {faults}");
        // every enabled kind shows up at this sample size
        for kind in FaultKind::ALL {
            assert!(
                plan.iter().flatten().any(|f| *f == kind),
                "kind {kind:?} never drawn"
            );
        }
        // a different seed gives a different plan
        let other = ChaosConfig::new(0xC4A06, 0.05);
        assert_ne!(
            plan,
            (0..20_000).map(|c| other.fault_for(c)).collect::<Vec<_>>()
        );
        // degenerate rates
        let never = ChaosConfig::new(1, 0.0);
        assert!((0..1000).all(|c| never.fault_for(c).is_none()));
        let always = ChaosConfig::new(1, 1.0);
        assert!((0..1000).all(|c| always.fault_for(c).is_some()));
        let disabled = ChaosConfig::new(1, 1.0).with_kinds(&[]);
        assert!((0..1000).all(|c| disabled.fault_for(c).is_none()));
    }

    #[test]
    fn clean_calls_delegate_bit_identically() {
        let model = tiny_model(3);
        let plain = NativeBackend::new(model.clone());
        let chaos = ChaosBackend::new(Arc::new(NativeBackend::new(model)), ChaosConfig::new(9, 0.0));
        let img = image(7);
        let want = plain.infer_logits(std::slice::from_ref(&img)).unwrap();
        let got = chaos.infer_logits(std::slice::from_ref(&img)).unwrap();
        assert_eq!(want, got);
        assert_eq!(chaos.calls(), 1);
        assert_eq!(chaos.injected(), 0);
    }

    #[test]
    fn each_fault_kind_injects_its_failure_mode() {
        let model = tiny_model(4);
        let img = image(8);
        let imgs = [&img];
        let mk = |kinds: &[FaultKind]| {
            ChaosBackend::new(
                Arc::new(NativeBackend::new(model.clone())),
                ChaosConfig::new(5, 1.0)
                    .with_kinds(kinds)
                    .with_spike(Duration::from_micros(50)),
            )
        };
        let mut scratch = InferScratch::default();
        let mut out = LogitsBuf::new();

        let e = mk(&[FaultKind::Error])
            .infer_batch(&imgs, &mut scratch, &mut out)
            .unwrap_err();
        assert!(format!("{e:#}").contains("chaos: injected"), "{e:#}");

        let b = mk(&[FaultKind::Panic]);
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut scratch = InferScratch::default();
            let mut out = LogitsBuf::new();
            let _ = b.infer_batch(&imgs, &mut scratch, &mut out);
        }));
        assert!(p.is_err(), "panic fault must unwind");

        let b = mk(&[FaultKind::Latency]);
        b.infer_batch(&imgs, &mut scratch, &mut out).unwrap();
        assert_eq!(out.rows(), 1, "latency fault still answers correctly");

        let b = mk(&[FaultKind::WrongShape]);
        b.infer_batch(&imgs, &mut scratch, &mut out).unwrap();
        assert_eq!(out.rows(), 2, "wrong-shape fault mis-sizes the arena");
        assert_eq!(b.calls(), 1);
        assert_eq!(b.injected(), 1);
    }
}
