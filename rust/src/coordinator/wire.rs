//! Wire protocol + TCP server — the paper's §5 future-work I/O path
//! ("external image input, such as from a UART interface …, while
//! UART-based output can provide digit predictions to external systems").
//!
//! Framing (byte-oriented, UART-friendly — works unchanged over a serial
//! link):
//!
//! ```text
//!   request :  0xB1  len_lo len_hi  payload[len]      len = 98 (784 bits)
//!   response:  0xB2  digit  status  lat[4 LE, µs]     status 0 = OK
//!   error   :  0xBE  code   0x00    0x00000000
//! ```
//!
//! Payload is the binarized image, bit *i* at byte `i/8` bit `i%8`
//! (LSB-first — the same order as the packed words).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::InferService;
use crate::bnn::packing::Packed;

pub const MAGIC_REQ: u8 = 0xB1;
pub const MAGIC_RESP: u8 = 0xB2;
pub const MAGIC_ERR: u8 = 0xBE;
pub const IMAGE_BITS: usize = 784;
pub const PAYLOAD_BYTES: usize = IMAGE_BITS.div_ceil(8); // 98

/// Encode a packed image as a request frame.
pub fn encode_request(image: &Packed) -> Vec<u8> {
    assert_eq!(image.n_bits, IMAGE_BITS);
    let bits = image.to_bits();
    let mut payload = vec![0u8; PAYLOAD_BYTES];
    for (i, &b) in bits.iter().enumerate() {
        payload[i / 8] |= b << (i % 8);
    }
    let mut frame = Vec::with_capacity(3 + PAYLOAD_BYTES);
    frame.push(MAGIC_REQ);
    frame.extend_from_slice(&(PAYLOAD_BYTES as u16).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

/// Decode a request payload into a packed image.
pub fn decode_payload(payload: &[u8]) -> Result<Packed> {
    if payload.len() != PAYLOAD_BYTES {
        bail!("payload {} bytes, expected {PAYLOAD_BYTES}", payload.len());
    }
    let bits: Vec<u8> = (0..IMAGE_BITS)
        .map(|i| (payload[i / 8] >> (i % 8)) & 1)
        .collect();
    Ok(Packed::from_bits(&bits))
}

/// A parsed response frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireResponse {
    pub digit: u8,
    pub status: u8,
    pub latency_us: u32,
}

pub fn encode_response(digit: u8, latency_us: u32) -> [u8; 7] {
    let l = latency_us.to_le_bytes();
    [MAGIC_RESP, digit, 0, l[0], l[1], l[2], l[3]]
}

pub fn encode_error(code: u8) -> [u8; 7] {
    [MAGIC_ERR, code, 0, 0, 0, 0, 0]
}

pub fn decode_response(frame: &[u8; 7]) -> Result<WireResponse> {
    match frame[0] {
        MAGIC_RESP => Ok(WireResponse {
            digit: frame[1],
            status: frame[2],
            latency_us: u32::from_le_bytes([frame[3], frame[4], frame[5], frame[6]]),
        }),
        MAGIC_ERR => bail!("server error code {}", frame[1]),
        m => bail!("bad response magic {m:#x}"),
    }
}

/// A running TCP server bound to a coordinator.
pub struct WireServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    pub served: Arc<AtomicU64>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl WireServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve requests through any
    /// [`InferService`] (single-queue [`super::Coordinator`] or sharded
    /// [`super::WorkerPool`]).
    pub fn start<S: InferService + 'static>(addr: &str, service: Arc<S>) -> Result<WireServer> {
        let service: Arc<dyn InferService> = service;
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let t_stop = stop.clone();
        let t_served = served.clone();
        let handle = std::thread::Builder::new()
            .name("bnn-wire-accept".into())
            .spawn(move || {
                while !t_stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let service = service.clone();
                            let served = t_served.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, service, served);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(WireServer {
            addr: local,
            stop,
            served,
            accept_thread: Some(handle),
        })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    mut stream: TcpStream,
    coord: Arc<dyn InferService>,
    served: Arc<AtomicU64>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    loop {
        let mut header = [0u8; 3];
        match stream.read_exact(&mut header) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e.into()),
        }
        if header[0] != MAGIC_REQ {
            stream.write_all(&encode_error(1))?;
            bail!("bad request magic {:#x}", header[0]);
        }
        let len = u16::from_le_bytes([header[1], header[2]]) as usize;
        if len != PAYLOAD_BYTES {
            stream.write_all(&encode_error(2))?;
            bail!("bad payload length {len}");
        }
        let mut payload = vec![0u8; len];
        stream.read_exact(&mut payload)?;
        match decode_payload(&payload).and_then(|img| coord.infer(img)) {
            Ok(resp) => {
                let us = (resp.latency_ns / 1000).min(u32::MAX as u64) as u32;
                stream.write_all(&encode_response(resp.digit, us))?;
                served.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => stream.write_all(&encode_error(3))?,
        }
    }
}

/// Blocking client for tests/tools.
pub struct WireClient {
    stream: TcpStream,
}

impl WireClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<WireClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(WireClient { stream })
    }

    pub fn classify(&mut self, image: &Packed) -> Result<WireResponse> {
        self.stream.write_all(&encode_request(image))?;
        let mut frame = [0u8; 7];
        self.stream.read_exact(&mut frame)?;
        decode_response(&frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn image(seed: u64) -> Packed {
        let mut rng = Xoshiro256::new(seed);
        let bits: Vec<u8> = (0..IMAGE_BITS).map(|_| rng.bool() as u8).collect();
        Packed::from_bits(&bits)
    }

    #[test]
    fn frame_roundtrip() {
        let img = image(1);
        let frame = encode_request(&img);
        assert_eq!(frame[0], MAGIC_REQ);
        assert_eq!(frame.len(), 3 + PAYLOAD_BYTES);
        let decoded = decode_payload(&frame[3..]).unwrap();
        assert_eq!(decoded.words, img.words);
    }

    #[test]
    fn response_roundtrip() {
        let f = encode_response(7, 123_456);
        let r = decode_response(&f).unwrap();
        assert_eq!(r, WireResponse { digit: 7, status: 0, latency_us: 123_456 });
        assert!(decode_response(&encode_error(3)).is_err());
        assert!(decode_response(&[0u8; 7]).is_err());
    }

    #[test]
    fn bad_payload_rejected() {
        assert!(decode_payload(&[0u8; 10]).is_err());
    }

    #[test]
    fn tcp_end_to_end() {
        use crate::bnn::model::model_from_sign_rows;
        use crate::coordinator::{BatcherConfig, Coordinator, NativeBackend};

        let mut rng = Xoshiro256::new(5);
        let dims = [784usize, 128, 64, 10];
        let mut spec = Vec::new();
        for (li, w) in dims.windows(2).enumerate() {
            let rows: Vec<Vec<i8>> = (0..w[1])
                .map(|_| (0..w[0]).map(|_| if rng.bool() { 1 } else { -1 }).collect())
                .collect();
            spec.push((rows, (li + 2 < dims.len()).then(|| vec![0i32; w[1]])));
        }
        let model = model_from_sign_rows(spec).unwrap();
        let coord = Arc::new(
            Coordinator::start(
                Arc::new(NativeBackend::new(model.clone())),
                BatcherConfig::default(),
                1,
            )
            .unwrap(),
        );
        let server = WireServer::start("127.0.0.1:0", coord).unwrap();
        let mut client = WireClient::connect(server.addr).unwrap();
        for seed in 0..5 {
            let img = image(seed);
            let r = client.classify(&img).unwrap();
            assert_eq!(r.digit as usize, model.predict(&img.words), "seed {seed}");
            assert_eq!(r.status, 0);
        }
        assert_eq!(server.served.load(Ordering::Relaxed), 5);
        server.shutdown();
    }

    #[test]
    fn tcp_end_to_end_over_worker_pool() {
        use crate::bnn::model::random_model;
        use crate::coordinator::{BatcherConfig, Kernel, WorkerPool};

        let model = random_model(&[784, 128, 64, 10], 6);
        let pool = Arc::new(
            WorkerPool::native(&model, 2, Kernel::default(), BatcherConfig::default()).unwrap(),
        );
        let server = WireServer::start("127.0.0.1:0", pool.clone()).unwrap();
        let mut client = WireClient::connect(server.addr).unwrap();
        for seed in 10..14 {
            let img = image(seed);
            let r = client.classify(&img).unwrap();
            assert_eq!(r.digit as usize, model.predict(&img.words), "seed {seed}");
            assert_eq!(r.status, 0);
        }
        assert_eq!(server.served.load(Ordering::Relaxed), 4);
        server.shutdown();
    }
}
